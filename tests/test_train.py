"""Training substrate tests: convergence, gradient compression with error
feedback, checkpoint/restart determinism, lossy checkpoints, fault
recovery."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke
from repro.ckpt import checkpoint as CKPT
from repro.data.tokens import make_data_iter
from repro.train import grad_compress as GC
from repro.train import loop as LOOP
from repro.train import optimizer as OPT
from repro.train import train_step as TS

CFG = get_smoke("granite-3-2b")
KEY = jax.random.PRNGKey(0)


def _step(compress=None, microbatches=1, lr=3e-3):
    return jax.jit(TS.make_train_step(
        CFG, OPT.AdamWConfig(lr=lr, warmup_steps=10),
        microbatches=microbatches, compress=compress))


def test_loss_decreases():
    state = TS.init_state(CFG, KEY)
    step = _step()
    it = make_data_iter(CFG, batch=8, seq=64)
    first = last = None
    for i in range(30):
        state, m = step(state, it(i % 4))  # few batches -> memorizable
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.3, (first, last)


def test_microbatching_matches_full_batch():
    """Grad accumulation must equal the single big batch (linearity)."""
    state = TS.init_state(CFG, KEY)
    it = make_data_iter(CFG, batch=8, seq=32)
    batch = it(0)
    s1, m1 = _step(microbatches=1)(state, batch)
    s4, m4 = _step(microbatches=4)(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    l1 = jax.tree.leaves(s1.params)[0].astype(jnp.float32)
    l4 = jax.tree.leaves(s4.params)[0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l4),
                               rtol=2e-2, atol=2e-4)


def test_compressed_training_converges():
    """int8 + error feedback training tracks uncompressed training."""
    it = make_data_iter(CFG, batch=8, seq=64)

    def run(compress):
        state = TS.init_state(CFG, KEY, compress=compress is not None)
        step = _step(compress=compress)
        for i in range(25):
            state, m = step(state, it(i % 4))
        return float(m["loss"])

    plain = run(None)
    comp = run(GC.CompressConfig(enabled=True, gate_ratio=0.0))
    assert abs(comp - plain) < 0.5, (plain, comp)


def test_int8_roundtrip_error_small():
    g = jax.random.normal(KEY, (4096,)) * 0.01
    codes, scales = GC.quantize_int8(g)
    deq = GC.dequantize_int8(codes, scales, g.shape)
    # block-wise int8: relative error ~ 1/127 of the block max
    assert float(jnp.max(jnp.abs(deq - g))) <= float(jnp.max(jnp.abs(g))) / 100


def test_predicted_cr_gate_sane():
    sparse = jnp.zeros((8192,)).at[::64].set(1.0)   # very compressible
    dense = jax.random.normal(KEY, (8192,))
    cr_sparse = float(GC.predicted_cr_int8(sparse))
    cr_dense = float(GC.predicted_cr_int8(dense))
    assert cr_sparse > cr_dense
    assert cr_dense >= 3.5                           # int8 alone gives ~4x


def test_checkpoint_restart_bitwise():
    d = tempfile.mkdtemp()
    try:
        it = make_data_iter(CFG, batch=4, seq=32)
        step = _step()
        lc = LOOP.LoopConfig(total_steps=8, ckpt_every=4, ckpt_dir=d)
        s0 = TS.init_state(CFG, KEY)
        sA, resA = LOOP.run(CFG, s0, step, it, lc)
        # restart from step 4 (fresh state object) and continue to 8
        shutil.rmtree(f"{d}/step_00000008")
        lcB = LOOP.LoopConfig(total_steps=8, ckpt_every=4, ckpt_dir=d)
        sB, resB = LOOP.run(CFG, TS.init_state(CFG, KEY), step, it, lcB)
        a = jax.tree.leaves(sA.params)[0].astype(jnp.float32)
        b = jax.tree.leaves(sB.params)[0].astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_failure_recovery_completes():
    d = tempfile.mkdtemp()
    try:
        it = make_data_iter(CFG, batch=4, seq=32)
        step = _step()
        lc = LOOP.LoopConfig(total_steps=10, ckpt_every=3, ckpt_dir=d,
                             failure_prob=0.2, failure_seed=5)
        mk = lambda: TS.init_state(CFG, KEY)
        state, res = LOOP.run_with_recovery(CFG, mk, step, it, lc)
        assert res.restarts >= 1
        assert 9 in res.losses                  # reached the final step
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_lossy_checkpoint_policy():
    d = tempfile.mkdtemp()
    try:
        state = TS.init_state(CFG, KEY)
        pol = CKPT.LossyPolicy(enabled=True, rel_eb=1e-4, min_size=4096)
        man = CKPT.save(d, 0, state.params, pol)
        lossy = [k for k, t in man["tensors"].items() if t["codec"] != "raw"]
        raw = [k for k, t in man["tensors"].items() if t["codec"] == "raw"]
        assert lossy and raw                     # policy splits by size
        restored = CKPT.load(d, 0, state.params)
        for k, t in man["tensors"].items():
            if t["codec"] != "raw":
                assert t["achieved_cr"] > 1.0
        # error bounded by rel_eb * range per tensor
        flat_o = CKPT._leaf_paths(state.params)
        flat_r = CKPT._leaf_paths(restored)
        for k in lossy:
            o = np.asarray(flat_o[k], np.float32)
            r = np.asarray(flat_r[k], np.float32)
            rng = o.max() - o.min()
            # rel_eb bound + bf16 re-cast ulp (bf16 params stored via f32)
            slack = 1.1e-4 * rng + np.max(np.abs(o)) * 2.0 ** -8
            assert np.max(np.abs(o - r)) <= slack, k
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_async_checkpointer():
    d = tempfile.mkdtemp()
    try:
        state = TS.init_state(CFG, KEY)
        ck = CKPT.AsyncCheckpointer(d)
        ck.submit(1, state.params)
        ck.wait()
        ck.close()
        assert CKPT.latest_step(d) == 1
    finally:
        shutil.rmtree(d, ignore_errors=True)
