"""Batched fused featurization engine: kernel oracles + regression vs the
looped per-(slice, eb) path (Pallas runs in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predictors as P
from repro.data import gaussian, scientific


@pytest.fixture(scope="module")
def slices():
    return scientific.field_slices("miranda-vx", count=5, n=96)


@pytest.fixture(scope="module")
def eb_grid(slices):
    rng = float(jnp.max(slices) - jnp.min(slices))
    # injective-binning regime: every histogram/sort path is exact here
    return [r * rng for r in (1e-4, 1e-3, 1e-2, 1e-1)]


# ------------------------------------------------------------- batched gram
@pytest.mark.parametrize("shape", [(3, 128, 128), (4, 96, 130), (2, 300, 180)])
def test_gram_batched_matches_per_slice(shape):
    from repro.kernels.gram import ops, ref
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    got = ops.gram_batched(x, transpose=True)
    want = jnp.stack([ref.gram_xtx(s) for s in x])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-3)


def test_gram_batched_xxt():
    from repro.kernels.gram import ops, ref
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 100, 250))
    got = ops.gram_batched(x, transpose=False)
    want = jnp.stack([ref.gram_xxt(s) for s in x])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-3)


# ------------------------------------------------------------ multi-eps qent
def test_qent_sweep_kernel_matches_bincount(slices, eb_grid):
    """Fused multi-eps kernel vs an np.bincount oracle, per (slice, eb)."""
    from repro.kernels.qent import ops
    flat = np.asarray(slices.reshape(slices.shape[0], -1))
    got = np.asarray(ops.quantized_entropy_sweep(
        jnp.asarray(flat), jnp.asarray(eb_grid, jnp.float32),
        num_bins=65536))
    for s in range(flat.shape[0]):
        for i, eps in enumerate(eb_grid):
            codes = np.floor(flat[s] / eps).astype(np.int64)
            counts = np.bincount(codes - codes.min())
            p = counts[counts > 0] / counts.sum()
            expect = float(-(p * np.log2(p)).sum())
            assert abs(got[s, i] - expect) < 1e-4, (s, i, got[s, i], expect)


def test_qent_sweep_kernel_matches_hashed_ref(slices):
    """In the colliding regime the kernel must equal the hashed oracle."""
    from repro.kernels.qent import ops, ref
    flat = slices.reshape(slices.shape[0], -1)
    rng = float(jnp.max(slices) - jnp.min(slices))
    epss = jnp.asarray([1e-5 * rng, 1e-3 * rng], jnp.float32)
    got = ops.quantized_entropy_sweep(flat, epss, num_bins=4096)
    want = ref.quantized_entropy_sweep(flat, np.asarray(epss), bins=4096)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_qent_sweep_jnp_matches_single(slices, eb_grid):
    """Sort-based sweep equals the scalar histogram path per (slice, eb)."""
    got = np.asarray(P.quantized_entropy_sweep(slices, jnp.asarray(eb_grid)))
    for s in range(slices.shape[0]):
        for i, eps in enumerate(eb_grid):
            want = float(P.quantized_entropy(slices[s], eps))
            assert abs(got[s, i] - want) < 1e-4, (s, i, got[s, i], want)


# ----------------------------------------------------------- features sweep
def test_features_sweep_matches_looped(slices, eb_grid):
    """(k, e, 2) sweep tensor == looped features_2d per (slice, eb)."""
    sweep = np.asarray(P.features_sweep(slices, jnp.asarray(eb_grid)))
    for s in range(slices.shape[0]):
        for i, eps in enumerate(eb_grid):
            want = np.asarray(P.features_2d(slices[s], eps))
            np.testing.assert_allclose(sweep[s, i], want, rtol=1e-5,
                                       atol=1e-4)


def test_features_sweep_kernel_route_consistent(slices):
    # error bounds where the 4096-bin hash is injective (code range < 4096)
    rng = float(jnp.max(slices) - jnp.min(slices))
    eb_grid = [r * rng for r in (1e-3, 1e-2, 1e-1)]
    cfg_j = P.PredictorConfig(use_kernels=False, qent_bins=4096)
    cfg_k = P.PredictorConfig(use_kernels=True, qent_bins=4096)
    f_j = P.features_sweep(slices, jnp.asarray(eb_grid), cfg_j)
    f_k = P.features_sweep(slices, jnp.asarray(eb_grid), cfg_k)
    np.testing.assert_allclose(np.asarray(f_j), np.asarray(f_k),
                               rtol=1e-4, atol=1e-4)


def test_svd_trunc_batch_matches_scalar(slices):
    got = np.asarray(P.svd_trunc_batch(slices))
    want = np.asarray([float(P.svd_trunc(s)) for s in slices])
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_features_sweep_finite_on_constant_slices():
    x = jnp.ones((3, 64, 64))
    f = P.features_sweep(x, [1e-3, 1e-2])
    assert bool(jnp.all(jnp.isfinite(f)))


# -------------------------------------------------------------- slice cache
def test_slice_cache_prefetch_and_memo(slices, eb_grid):
    cache = P.features_2d_cached(slices[0])
    pre = cache.prefetch(jnp.asarray(eb_grid))
    assert pre.shape == (len(eb_grid), 2)
    for i, eps in enumerate(eb_grid):
        np.testing.assert_allclose(np.asarray(cache(eps)),
                                   np.asarray(pre[i]), atol=1e-6)
        want = np.asarray(P.features_2d(slices[0], eps))
        np.testing.assert_allclose(np.asarray(cache(eps)), want, rtol=1e-5,
                                   atol=1e-4)


def test_engine_single_eb_column(slices, eb_grid):
    from repro.core import pipeline as PL
    eng = P.get_engine()
    col = eng.features(slices, eb_grid[1])
    sweep = eng.sweep(slices, jnp.asarray(eb_grid))
    np.testing.assert_allclose(np.asarray(col), np.asarray(sweep[:, 1, :]),
                               atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(PL.featurize_slices(slices, eb_grid[1])),
        np.asarray(col), atol=1e-6)
