"""Distribution tests in a subprocess with 8 virtual devices.

The main pytest process must keep the default single CPU device (jax locks
the device count at first init), so every sharded scenario runs in a child
interpreter with XLA_FLAGS set before importing jax.
"""
import pytest

from _child import run_child


def test_sharded_train_step_runs():
    out = run_child("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_smoke
        from repro.dist import sharding as S
        from repro.models import model as M, params as PRM
        from repro.train import train_step as TS
        from repro.data.tokens import make_data_iter

        cfg = get_smoke("granite-8b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with S.use_mesh(mesh):
            state = TS.init_state(cfg, jax.random.PRNGKey(0))
            shard = PRM.param_specs(M.param_table(cfg), mesh)
            state = TS.TrainState(
                jax.device_put(state.params, shard),
                state.opt._replace(mu=jax.device_put(state.opt.mu, shard),
                                   nu=jax.device_put(state.opt.nu, shard)),
                None)
            step = jax.jit(TS.make_train_step(cfg, microbatches=2))
            it = make_data_iter(cfg, batch=4, seq=32)
            state, m = step(state, it(0))
            state, m = step(state, it(1))
            print("LOSS", float(m["loss"]))
    """)
    assert "LOSS" in out


def test_logical_sharding_divisibility_fallback():
    out = run_child("""
        import jax
        from repro.dist import sharding as S
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with S.use_mesh(mesh):
            # 20 heads on a 4-way model axis -> shards (20 % 4 == 0)
            s1 = S.spec_for((8, 20, 64), ("batch", "model", None))
            # 25 heads -> falls back to replication
            s2 = S.spec_for((8, 25, 64), ("batch", "model", None))
            print("S1", s1)
            print("S2", s2)
    """)
    assert "S1 PartitionSpec('data', 'model', None)" in out
    assert "S2 PartitionSpec('data', None, None)" in out


def test_compressed_pod_allreduce_matches_mean():
    out = run_child("""
        import functools
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist.collectives import compressed_pod_allreduce

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 512)) * 0.01

        def podwise(xs):
            return compressed_pod_allreduce(xs[0][None] * 0 + xs, "pod")

        f = jax.shard_map(lambda a: compressed_pod_allreduce(a, "pod"),
                          mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                          axis_names=frozenset({"pod"}))
        y = f(x)
        want = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
        err = float(jnp.max(jnp.abs(y - want)))
        rel = err / float(jnp.max(jnp.abs(want)))
        print("REL", rel)
        assert rel < 0.05, rel
    """)
    assert "REL" in out


def test_elastic_remesh():
    out = run_child("""
        import jax, jax.numpy as jnp
        from repro.dist import sharding as S, fault as F
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        axes = {"w": ("fsdp", "model")}
        with S.use_mesh(mesh):
            placed = F.remesh_state(tree, axes, mesh)
        small = F.shrink_mesh(mesh, "data", 2)
        with S.use_mesh(small):
            replaced = F.remesh_state(placed, axes, small)
        assert replaced["w"].sharding.mesh.shape["data"] == 2
        import numpy as np
        np.testing.assert_array_equal(np.asarray(replaced["w"]),
                                      np.asarray(tree["w"]))
        print("REMESH OK")
    """)
    assert "REMESH OK" in out


def test_podsync_mode_compiles_and_runs():
    out = run_child("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_smoke
        from repro.dist import sharding as S
        from repro.models import model as M, params as PRM
        from repro.train import train_step as TS
        from repro.train.grad_compress import CompressConfig
        from repro.data.tokens import make_data_iter

        cfg = get_smoke("granite-3-2b")
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        with S.use_mesh(mesh):
            state = TS.stack_for_podsync(
                TS.init_state(cfg, jax.random.PRNGKey(0), compress=True), 2)
            step = jax.jit(TS.make_train_step(
                cfg, microbatches=1, mode="podsync", mesh=mesh,
                compress=CompressConfig(enabled=True, gate_ratio=0.0)))
            it = make_data_iter(cfg, batch=4, seq=32)
            state, m = step(state, it(0))
            print("PODSYNC LOSS", float(m["loss"]))
    """)
    assert "PODSYNC LOSS" in out
