"""Sweep-service tests: coalescing correctness (bit-equal to serial
dispatch, mixed slice shapes), cross-request cache hit/eviction semantics,
deadline flush, in-batch dedup, and the dist.sweep scatter-back path.

Runs on a single device (tier-1) and under the multi-device CI job
(XLA_FLAGS=--xla_force_host_platform_device_count=8), where the coalesced
launches shard over the mesh.
"""
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import compressors as C
from repro.core import pipeline as PL, predictors as P, usecases as UC
from repro.data import scientific
from repro.dist import sweep as DS
from repro.serve.sweep_service import (
    FeatureCache, ServiceConfig, SweepService, _eps_bucket, _row_bucket,
    slice_digest)


@pytest.fixture(scope="module")
def setup():
    slices = scientific.field_slices("scale-u", count=16, n=96)
    rng = float(jnp.max(slices) - jnp.min(slices))
    ebs = [1e-5 * rng, 1e-4 * rng, 1e-3 * rng, 1e-2 * rng]
    gm = UC.EbGridModel.train(slices[:10], "zfp", ebs)
    eps = ebs[2]
    models = {}
    for name in ("zfp", "bitgrooming"):
        comp = C.get(name)
        crs = jnp.asarray([comp.cr(s, eps) for s in slices[:10]])
        models[name] = PL.CRPredictor.train(slices[:10], crs, eps)
    return slices, ebs, gm, eps, models


def test_coalesced_bitequal_serial_mixed_shapes(setup):
    """N concurrent mixed requests (two slice shapes) == N serial calls."""
    slices, ebs, gm, eps, models = setup
    small = scientific.field_slices("scale-u", count=2, seed=3, n=64)
    test = slices[12]

    # serial references (today's per-request dispatch)
    s_uc1 = UC.find_error_bound_for_cr(gm, test, 6.0)
    s_uc2 = UC.best_compressor(models, test, eps)
    s_feat = np.asarray(P.features_sweep(slices[13:15], ebs))
    s_feat_small = np.asarray(P.features_sweep(small, [eps]))

    with SweepService(ServiceConfig(max_wait_ms=50.0)) as svc:
        futs = [svc.submit_find_eb(gm, test, 6.0),
                svc.submit_best_compressor(models, test, eps),
                svc.submit_featurize(slices[13:15], ebs),
                svc.submit_featurize(small, [eps])]
        c_uc1, c_uc2, c_feat, c_feat_small = [
            f.result(timeout=120) for f in futs]
        stats = svc.stats()

    assert c_uc1 == s_uc1
    assert c_uc2[0] == s_uc2[0] and c_uc2[1] == s_uc2[1]
    assert np.array_equal(c_feat, s_feat)
    assert np.array_equal(c_feat_small, s_feat_small)
    # two shape groups -> exactly two coalesced launches for the batch
    assert stats["launches"] == 2
    # 1 UC1 slice (UC2 deduped onto it) + 2 featurize + 2 small = 5 rows
    assert stats["rows_launched"] == 5


def test_concurrent_clients_bitequal(setup):
    """Requests submitted from many client threads at once match serial."""
    slices, ebs, gm, eps, models = setup
    tests = [slices[11], slices[12], slices[13]]
    targets = [4.0, 6.0, 9.0]
    serial = [UC.find_error_bound_for_cr(gm, x, t)
              for x, t in zip(tests, targets)]
    serial += [UC.best_compressor(models, x, eps) for x in tests]

    results = [None] * 6
    with SweepService(ServiceConfig(max_wait_ms=50.0)) as svc:
        def uc1(i):
            results[i] = svc.find_eb(gm, tests[i], targets[i])

        def uc2(i):
            results[3 + i] = svc.best_compressor(models, tests[i], eps)

        threads = [threading.Thread(target=uc1, args=(i,)) for i in range(3)]
        threads += [threading.Thread(target=uc2, args=(i,)) for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert results == serial


def test_cache_admission_transitions(setup):
    """Default policy: a digest is admitted on its SECOND sighting, so a
    one-shot cold field never occupies the cache, a once-repeated field
    pays one extra launch, and from the third request on it is served
    with zero launches."""
    slices, ebs, gm, eps, models = setup
    test = slices[11]
    with SweepService(ServiceConfig(max_wait_ms=5.0)) as svc:
        first = svc.find_eb(gm, test, 6.0)              # sighting 1: cold
        launches = svc.launches
        assert launches >= 1
        assert svc.stats()["cache"]["entries"] == 0     # one-shot: not cached
        assert svc.stats()["cache"]["admissions_denied"] >= 1
        second = svc.find_eb(gm, test, 6.0)             # sighting 2: admits
        assert svc.launches == launches + 1
        assert second == first
        assert svc.stats()["cache"]["entries"] == 1
        third = svc.find_eb(gm, test, 6.0)              # hot: pure cache
        assert svc.launches == launches + 1
        assert third == first
        # UC2 at a grid eb on the same field also rides the cache
        svc.best_compressor(models, test, eps)
        assert svc.launches == launches + 1
        assert svc.stats()["cache"]["hits"] >= len(ebs) + 1


def test_cache_admit_first_touch_config(setup):
    """cache_admit_after=1 restores first-touch caching: the second
    request on a field is already launch-free."""
    slices, ebs, gm, eps, models = setup
    test = slices[11]
    scfg = ServiceConfig(max_wait_ms=5.0, cache_admit_after=1)
    with SweepService(scfg) as svc:
        first = svc.find_eb(gm, test, 6.0)
        launches = svc.launches
        second = svc.find_eb(gm, test, 6.0)
        assert svc.launches == launches
        assert second == first


def test_cache_concurrent_requests_admit_in_one_batch(setup):
    """In-batch sightings count: a field arriving with simultaneous
    requests is admitted on its very first (deduplicated) launch."""
    slices, ebs, gm, eps, models = setup
    test = slices[12]
    with SweepService(ServiceConfig(max_wait_ms=200.0,
                                    max_batch_slices=64)) as svc:
        f1 = svc.submit_find_eb(gm, test, 6.0)
        f2 = svc.submit_best_compressor(models, test, eps)
        f1.result(timeout=120), f2.result(timeout=120)
        stats = svc.stats()
        assert stats["launches"] == 1                   # deduped
        assert stats["cache"]["entries"] == 1           # ... and admitted
        # third request is served from the cache, zero launches
        svc.find_eb(gm, test, 6.0)
        assert svc.launches == 1


def test_dedup_within_batch(setup):
    slices, ebs, gm, eps, models = setup
    x = slices[14]
    with SweepService(ServiceConfig(max_wait_ms=200.0,
                                    max_batch_slices=64)) as svc:
        # same slice content from two different requests in one batch
        f1 = svc.submit_featurize(np.asarray(x)[None], ebs)
        f2 = svc.submit_featurize(np.asarray(x)[None], ebs)
        r1, r2 = f1.result(timeout=120), f2.result(timeout=120)
        stats = svc.stats()
    assert np.array_equal(r1, r2)
    assert stats["launches"] == 1
    assert stats["rows_launched"] == 1          # deduplicated before launch


def test_deadline_flush_single_pending_request(setup):
    slices, ebs, gm, eps, models = setup
    scfg = ServiceConfig(max_batch_slices=64, max_wait_ms=30.0)
    with SweepService(scfg) as svc:
        fut = svc.submit_featurize(slices[11:12], [ebs[0]])
        # nothing else arrives: the deadline must flush the lone request
        out = fut.result(timeout=120)
        stats = svc.stats()
    assert out.shape == (1, 1, 2)
    assert stats["batches"] == 1 and stats["launches"] == 1
    assert np.array_equal(out, np.asarray(
        P.features_sweep(slices[11:12], [ebs[0]])))


def test_submit_after_close_raises(setup):
    slices, ebs, gm, eps, models = setup
    svc = SweepService(ServiceConfig(max_wait_ms=1.0))
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit_featurize(slices[11:12], [ebs[0]])


def test_feature_cache_admission_policy_unit():
    """FeatureCache-level admission: puts are denied until the digest has
    admit_after sightings; sighting bookkeeping is bounded and cleared on
    admission."""
    row = np.zeros(2, np.float32)
    cache = FeatureCache(max_bytes=1 << 20, admit_after=2)
    key = ("cold", None)
    assert cache.record_sighting(key) == 1
    assert cache.put(key, 1.0, row) is False            # under-sighted
    assert cache.get(key, 1.0) is None
    assert cache.stats()["admissions_denied"] == 1
    assert cache.record_sighting(key) == 2
    assert cache.put(key, 1.0, row) is True             # second sighting
    assert cache.get(key, 1.0) is not None
    assert cache.stats()["pending_sightings"] == 0      # cleared on admit
    # admitted digests keep accepting new eps rows without re-sighting
    assert cache.put(key, 2.0, row) is True
    # in-batch multi-request sighting (n=2) admits immediately
    key2 = ("hot", None)
    assert cache.record_sighting(key2, n=2) == 2
    assert cache.put(key2, 1.0, row) is True
    # the sighting ring is bounded: old cold digests fall off
    small = FeatureCache(max_bytes=1 << 20, admit_after=2, seen_capacity=2)
    for i in range(5):
        small.record_sighting((f"d{i}", None))
    assert small.stats()["pending_sightings"] == 2


def test_feature_cache_lru_eviction():
    row = np.zeros(2, np.float32)
    overhead = FeatureCache.ENTRY_OVERHEAD + FeatureCache.ROW_BYTES
    cache = FeatureCache(max_bytes=2 * overhead)      # fits two entries
    ka, kb, kc = ("a", None), ("b", None), ("c", None)
    cache.put(ka, 1.0, row)
    cache.put(kb, 1.0, row)
    assert cache.get(ka, 1.0) is not None             # touch A: B is LRU
    cache.put(kc, 1.0, row)                           # evicts B
    assert cache.get(kb, 1.0) is None
    assert cache.get(ka, 1.0) is not None
    assert cache.get(kc, 1.0) is not None
    assert cache.evictions == 1
    stats = cache.stats()
    assert stats["entries"] == 2
    assert stats["bytes"] <= 2 * overhead


def test_feature_cache_never_evicts_last_written():
    cache = FeatureCache(max_bytes=1)                 # below one entry
    cache.put(("a", None), 1.0, np.zeros(2, np.float32))
    assert cache.get(("a", None), 1.0) is not None    # still served


def test_slice_digest_f32_canonical():
    x64 = np.random.default_rng(0).standard_normal((8, 8))
    assert slice_digest(x64) == slice_digest(x64.astype(np.float32))
    assert slice_digest(x64) != slice_digest(x64.T.copy())
    # shape participates: same bytes, different shape -> different digest
    assert slice_digest(x64) != slice_digest(x64.reshape(4, 16))


def test_buckets():
    assert [_row_bucket(k) for k in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert [_eps_bucket(e) for e in (1, 5, 6, 7, 33)] == [1, 6, 6, 8, 48]


def test_sweep_padded_and_scatter(setup):
    slices, ebs, gm, eps, models = setup
    stack = slices[10:13]                             # k=3
    epss = np.asarray(ebs, np.float32)
    ref = np.asarray(P.features_sweep(stack, epss, sharded=False))
    out = DS.sweep_padded(stack, epss, k_pad=8)
    assert out.shape == (8, len(ebs), 2)
    assert np.array_equal(np.asarray(out)[:3], ref)   # pad rows after real
    blocks = DS.scatter_requests(out, [1, 2])
    assert np.array_equal(blocks[0], ref[:1])
    assert np.array_equal(blocks[1], ref[1:3])
    with pytest.raises(ValueError):
        DS.scatter_requests(out, [9])                 # more rows than exist
    with pytest.raises(ValueError):
        DS.sweep_padded(stack, epss, k_pad=2)         # k_pad below batch


def test_sweep_padded_sharded_matches_single_device(setup):
    """Under a multi-device mesh the padded gather=False launch keeps
    bit-equality with the single-device engine row for row."""
    slices, ebs, gm, eps, models = setup
    if len(jax.devices()) < 2:
        pytest.skip("single-device host")
    from repro.launch import mesh as M
    mesh = M.make_sweep_mesh()
    ext = len(jax.devices())
    stack = scientific.field_slices("scale-u", count=ext, seed=7, n=96)
    epss = np.asarray(ebs, np.float32)
    ref = np.asarray(P.features_sweep(stack, epss, sharded=False))
    out = DS.sweep_padded(stack, epss, k_pad=ext, mesh=mesh)
    assert np.array_equal(np.asarray(out), ref)
    # ragged batch: real rows of a padded sharded launch still match
    ragged = stack[:ext - 1]
    out2 = np.asarray(DS.sweep_padded(ragged, epss, k_pad=ext, mesh=mesh))
    assert np.array_equal(out2[:ext - 1], ref[:ext - 1])


def test_eps_union_rows_bitequal(setup):
    """Per-eps results are independent: a row featurized at an eb union
    equals the same row featurized at each eb alone (what in-batch eps
    unioning relies on)."""
    slices, ebs, gm, eps, models = setup
    stack = slices[10:11]
    union = np.asarray(ebs, np.float32)
    full = np.asarray(P.features_sweep(stack, union))
    for i, e in enumerate(union):
        alone = np.asarray(P.features_sweep(stack, [e]))
        assert np.array_equal(full[:, i:i + 1], alone)


def test_submit_validation(setup):
    """Malformed requests fail at submit time (a worker-side failure would
    poison the whole coalesced batch) and eps<=0 is rejected on every
    sweep_padded route."""
    slices, ebs, gm, eps, models = setup
    with SweepService(ServiceConfig(max_wait_ms=1.0)) as svc:
        # volumes are first-class, but the data rank must match the
        # models' training ndim (gm/models here are 2-D-trained)
        with pytest.raises(ValueError):
            svc.submit_find_eb(gm, slices[10:12], 6.0)      # 3-D data
        with pytest.raises(ValueError):
            svc.submit_best_compressor(models, slices[10:12], eps)
        with pytest.raises(ValueError):
            svc.submit_featurize(slices[10], ebs)           # 2-D stack
        with pytest.raises(ValueError):
            svc.submit_featurize(slices[10:12], [])         # no ebs
    with pytest.raises(ValueError):
        DS.sweep_padded(slices[10:12], [0.0])               # eps <= 0
    with pytest.raises(ValueError):
        DS.sweep_padded(slices[10:12], [-1e-3], k_pad=8)


def test_cached_rows_are_owned_copies(setup):
    """Cache rows must not be views pinning the whole batch result."""
    slices, ebs, gm, eps, models = setup
    with SweepService(ServiceConfig(max_wait_ms=1.0)) as svc:
        svc.featurize(slices[10:11], ebs)
        svc.featurize(slices[10:11], ebs)     # second sighting -> admitted
        [entry] = list(svc.cache._entries.values())
        for row in entry.values():
            assert row.base is None
