"""Harness-level per-test hard timeout.

The chaos suite (``test_fault.py``) exercises deliberately-broken
collectives; a regression there shows up as a *hang*, not a failure.
``pytest-timeout`` is not a dependency of this repo, so when
``REPRO_TEST_TIMEOUT_S`` is set (CI sets it for the multi-device job)
every test runs under a SIGALRM that turns a wedged test into a loud
failure.  Unset (the default for local runs), this is a no-op.
SIGALRM only interrupts the main thread, so child-process reaping in
``_child.run_procs`` still gets to clean up via its own timeouts.
"""
import os
import signal

import pytest


@pytest.fixture(autouse=True)
def _hard_timeout(request):
    budget = float(os.environ.get("REPRO_TEST_TIMEOUT_S", "0") or 0)
    if budget <= 0 or os.name == "nt" or not hasattr(signal, "SIGALRM"):
        yield
        return

    def _expired(signum, frame):
        raise TimeoutError(
            f"test exceeded REPRO_TEST_TIMEOUT_S={budget:.0f}s "
            f"(hung collective?): {request.node.nodeid}")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.setitimer(signal.ITIMER_REAL, budget)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)
