"""UC1 (target-CR search) and UC2 (best-compressor selection) tests."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import compressors as C
from repro.core import pipeline as PL, usecases as UC
from repro.data import scientific


@pytest.fixture(scope="module")
def setup():
    slices = scientific.field_slices("scale-u", count=18, n=128)
    rng = float(jnp.max(slices) - jnp.min(slices))
    ebs = [1e-5 * rng, 1e-4 * rng, 1e-3 * rng, 1e-2 * rng]
    return slices, ebs, rng


def test_uc1_finds_error_bound(setup):
    slices, ebs, rng = setup
    gm = UC.EbGridModel.train(slices[:14], "sz2", ebs)
    test = slices[16]
    target = 6.0
    eps, pred_cr = UC.find_error_bound_for_cr(gm, test, target)
    true_cr = C.get("sz2").cr(test, eps)
    assert abs(true_cr - target) / target < 0.30, (eps, pred_cr, true_cr)


def test_uc1_fewer_compressor_runs_than_exhaustive(setup):
    slices, ebs, rng = setup
    test = slices[16]
    _, _, runs = UC.find_error_bound_exhaustive(
        "sz2", test, 6.0, ebs[0], ebs[-1])
    # the model-driven path runs the compressor 0 times at query time
    assert runs >= 4


def test_uc2_ranks_best_compressor(setup):
    slices, ebs, rng = setup
    eps = ebs[2]
    names = ["sz2", "zfp", "mgard", "bitgrooming"]
    models = {}
    for n in names:
        comp = C.get(n)
        crs = jnp.asarray([comp.cr(s, eps) for s in slices[:14]])
        models[n] = PL.CRPredictor.train(slices[:14], crs, eps)
    agree = 0
    for i in (14, 15, 16, 17):
        best_pred, preds = UC.best_compressor(models, slices[i], eps)
        best_true, crs = UC.best_compressor_exhaustive(names, slices[i], eps)
        # predicted winner achieves >= 90% of the true best CR
        if crs[best_pred] >= 0.9 * crs[best_true]:
            agree += 1
    assert agree >= 3, agree


def test_ebgrid_monotone_interpolation(setup):
    slices, ebs, rng = setup
    gm = UC.EbGridModel.train(slices[:14], "zfp", ebs)
    test = slices[16]
    crs = [gm.predict(test, e) for e in
           np.logspace(np.log10(ebs[0]), np.log10(ebs[-1]), 9)]
    # CR(eps) should be (weakly) increasing along the eb sweep
    violations = sum(1 for a, b in zip(crs, crs[1:]) if b < a * 0.95)
    assert violations <= 1, crs
