"""Docs-layer guards: the README/docs the CI docs-smoke job executes
must exist, extract cleanly, and point at real code.

The quickstart is *executed* by the docs-smoke CI job (via
``tools/extract_quickstart.py``); here we keep the cheap invariants in
tier-1 so a README edit cannot silently break the extraction or drift
from the codebase.
"""
import importlib.util
import os
import re

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _read(*parts) -> str:
    with open(os.path.join(ROOT, *parts)) as f:
        return f.read()


def _load_extractor():
    spec = importlib.util.spec_from_file_location(
        "extract_quickstart",
        os.path.join(ROOT, "tools", "extract_quickstart.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_readme_quickstart_extracts_and_compiles():
    readme = _read("README.md")
    snippet = _load_extractor().extract(readme)
    # the snippet CI executes must at least be valid python that drives
    # the public pipeline API
    compile(snippet, "README.md", "exec")
    for needle in ("field_slices", "CRPredictor.train", "model.predict",
                   "make_sweep_mesh", "features_sweep"):
        assert needle in snippet, f"quickstart lost its {needle} step"


def test_readme_covers_required_sections():
    readme = _read("README.md")
    # architecture map must name every package the map claims to cover
    for pkg in ("core", "kernels", "dist", "serve", "launch",
                "compressors", "data"):
        assert os.path.isdir(os.path.join(ROOT, "src", "repro", pkg)), pkg
        assert f"{pkg}/" in readme, f"architecture map lost {pkg}/"
    # install + tier-1 command from pyproject
    assert 'pip install -e ".[test,zstd]"' in readme
    assert "pytest" in readme
    # benchmark table rows must reference results some benchmark module
    # actually writes (results/ itself is a generated, gitignored dir,
    # so existence-on-disk cannot be the check in a fresh checkout)
    writers = ""
    bench_dir = os.path.join(ROOT, "benchmarks")
    for fn in os.listdir(bench_dir):
        if fn.endswith(".py"):
            writers += _read("benchmarks", fn)
    for ref in re.findall(r"`(BENCH_\w+\.json|bench_\w+\.json|"
                          r"fig\d+_\w+\.json)`", readme):
        assert f'"{ref[:-len(".json")]}"' in writers, \
            f"README benchmark table references {ref}, which no " \
            "benchmark writes via common.save_json"


def test_docs_reference_real_code():
    serving = _read("docs", "serving.md")
    for sym in ("max_batch_slices", "max_wait_ms", "cache_bytes",
                "cache_admit_after", "sweep_padded", "scatter_requests",
                "dist_init", "serve()",
                # servable-method platform vocabulary
                "ServableMethod", "kv_gate", "max_live_batches",
                "min_wait_ms", "adapt_window", "batch_buckets",
                "warmup_spec"):
        assert sym in serving, f"serving.md lost {sym}"
    mapping = _read("docs", "paper_mapping.md")
    svc = _read("src", "repro", "serve", "sweep_service.py")
    for sym in ("quantized_entropy", "svd_trunc", "hosvd_trunc_batch",
                "find_error_bound_for_cr", "best_compressor",
                "bench_3d", "EbGridModel", "ServableMethod",
                "default_registry", "kv_gate",
                # streaming advisor rows (UC1/UC2 at dataset scale)
                "stream_features", "launch.advise"):
        assert sym in mapping, f"paper_mapping.md lost {sym}"
    # the knobs the serving doc teaches must exist on ServiceConfig
    from repro.serve.sweep_service import ServiceConfig
    cfg = ServiceConfig()
    for knob in ("max_batch_slices", "max_wait_ms", "cache_bytes",
                 "cache_admit_after", "max_eps_per_launch",
                 "min_wait_ms", "adapt_window", "max_live_batches",
                 "post_workers"):
        assert hasattr(cfg, knob)
    assert "broadcast_one_to_all" in svc  # the fabric serving.md describes


def test_method_platform_modules_expose_documented_api():
    """The symbols serving.md/paper_mapping.md teach for the method
    layer must exist in the new modules."""
    method = _read("src", "repro", "serve", "method.py")
    for sym in ("class ServableMethod", "def pre_process",
                "def post_process", "def warmup_spec", "batch_buckets",
                "class SweepLauncher", "class Int8CRLauncher",
                "class KVGateMethod", "class QualityLauncher",
                "class QualityMethod", "class FindSettingMethod"):
        assert sym in method, f"method.py lost {sym}"
    registry = _read("src", "repro", "serve", "registry.py")
    for sym in ("def default_registry", "def register",
                "def launcher_id"):
        assert sym in registry, f"registry.py lost {sym}"
    from repro.serve.registry import default_registry
    assert default_registry().names() == (
        "featurize", "find_eb", "best_compressor", "kv_gate", "advise",
        "find_setting", "quality")


def test_streaming_doc_references_real_code():
    """docs/streaming.md must keep teaching the symbols the streaming
    layer actually exports, and the README must link it."""
    doc = _read("docs", "streaming.md")
    for sym in ("DatasetSource", "MemmapSource", "NpzSource",
                "GeneratorSource", "StreamingDigest", "StreamConfig",
                "stream_features", "stream_dataset", "budget_bytes",
                "prefetch", "max_in_flight", "process_local",
                "make_dataset.py", "repro.launch.advise",
                "submit_advise", "harmonic", "BENCH_stream"):
        assert sym in doc, f"streaming.md lost {sym}"
    # the doc's vocabulary must exist in code
    from repro.core import stream as ST
    from repro.data import source as SRC
    for mod, names in ((SRC, ("DatasetSource", "MemmapSource", "NpzSource",
                              "GeneratorSource", "StreamingDigest",
                              "open_dataset", "write_dataset")),
                       (ST, ("StreamConfig", "stream_features",
                             "stream_dataset"))):
        for name in names:
            assert hasattr(mod, name), f"{mod.__name__} lost {name}"
    from repro.serve.sweep_service import SweepService
    assert hasattr(SweepService, "submit_advise")
    assert hasattr(SweepService, "advise")
    assert "docs/streaming.md" in _read("README.md")


def test_quality_doc_references_real_code():
    """docs/quality.md must keep teaching the symbols the quality layer
    actually exports, and the README must link it."""
    doc = _read("docs", "quality.md")
    for sym in ("quality_sweep", "features_sweep", "quality=True",
                "find_setting", "QualityTable", "JointSetting",
                "submit_quality", "submit_find_setting", "--psnr-floor",
                "det_log10", "DEFAULT_TILE", "PSNR_CAP",
                "BENCH_quality.json"):
        assert sym in doc, f"quality.md lost {sym}"
    # the doc's vocabulary must exist in code
    from repro.core import predictors as P
    from repro.core import usecases as UC
    from repro.kernels import quality as Q
    for mod, names in ((P, ("quality_sweep", "features_sweep")),
                       (UC, ("find_setting", "QualityTable",
                             "JointSetting")),
                       (Q, ("quality_sweep", "DEFAULT_TILE", "PSNR_CAP",
                            "NRMSE_CAP"))):
        for name in names:
            assert hasattr(mod, name), f"{mod.__name__} lost {name}"
    from repro.serve.sweep_service import SweepService
    for name in ("submit_quality", "submit_find_setting", "quality",
                 "find_setting"):
        assert hasattr(SweepService, name)
    assert "docs/quality.md" in _read("README.md")
    assert "psnr-floor" in _read("src", "repro", "launch", "advise.py")


def test_performance_doc_references_real_code():
    perf = _read("docs", "performance.md")
    for sym in ("repro.kernels.tune", "TuneConfig", "REPRO_TUNED_DIR",
                "--xla-preset", "bench_tune", "BENCH_tune",
                "measured_stream_bw", "BACKEND_HW",
                "vmem_compare_budget", "invalidate_table_cache",
                "apply_preset", "merge_flag_strings", "donate_argnums"):
        assert sym in perf, f"performance.md lost {sym}"
    # the knobs/presets the doc teaches must exist
    from repro.kernels import tune as KT
    from repro.launch import xla_flags as XF
    for name in ("cpu", "tpu", "gpu", "none"):
        assert name in XF.PRESETS
    assert hasattr(KT.TuneConfig(), "use_table")
    # the committed baseline the doc (and the default load path) relies on
    assert os.path.exists(os.path.join(
        ROOT, "src", "repro", "kernels", "tuned", "cpu.json"))
    # README links the doc
    assert "docs/performance.md" in _read("README.md")


def test_paper_mapping_paths_exist():
    mapping = _read("docs", "paper_mapping.md")
    for path in re.findall(r"`((?:core|kernels|dist|serve|launch|data|"
                           r"compressors)/[\w./]+\.py)`", mapping):
        assert os.path.exists(
            os.path.join(ROOT, "src", "repro", path)), \
            f"paper_mapping.md references missing {path}"
