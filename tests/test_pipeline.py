"""End-to-end CR-prediction pipeline tests (the paper's headline claims at
reduced scale): MedAPE within bounds, predictor complementarity, 3-D path."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import compressors as C
from repro.core import pipeline as PL, predictors as P, regression as R
from repro.data import gaussian, scientific


@pytest.fixture(scope="module")
def miranda():
    slices = scientific.field_slices("miranda-vx", count=24, n=128)
    rng = float(jnp.max(slices) - jnp.min(slices))
    eps = 1e-3 * rng
    feats = np.asarray(PL.featurize_slices(slices, eps))
    return slices, eps, feats


@pytest.mark.parametrize("comp", ["sz2", "zfp", "mgard", "bitgrooming"])
def test_medape_within_paper_bounds(comp, miranda):
    """Paper section 4.3: median percentage error < 12% across compressors."""
    slices, eps, feats = miranda
    c = C.get(comp)
    crs = np.asarray([c.cr(s, eps) for s in slices])
    res = PL.kfold_evaluate(feats, crs, model="spline", k=8)
    assert res.medape < 12.0, (comp, res)


def test_spline_no_worse_than_linear_on_average(miranda):
    slices, eps, feats = miranda
    c = C.get("sz2")
    crs = np.asarray([c.cr(s, eps) for s in slices])
    spl = PL.kfold_evaluate(feats, crs, model="spline", k=8)
    lin = PL.kfold_evaluate(feats, crs, model="linear", k=8)
    assert spl.medape < lin.medape * 2.0  # spline is competitive


def test_predictor_complementarity(miranda):
    """Using both predictors must beat svd-only and qent-only models
    (paper Fig. 4 / 'key findings' of section 3.1)."""
    slices, eps, feats = miranda
    c = C.get("sz2")
    crs = np.asarray([c.cr(s, eps) for s in slices])
    both = PL.kfold_evaluate(feats, crs, model="linear", k=6).medape
    for drop in (0, 1):
        f1 = feats.copy()
        f1[:, drop] = 0.0
        one = PL.kfold_evaluate(f1, crs, model="linear", k=6).medape
        assert both <= one * 1.5, (drop, both, one)


def test_gaussian_type1_accuracy():
    """Paper section 4.1: Gaussian samples are the proof of concept."""
    slices = gaussian.sample_batch(1, count=16, n=128)
    eps = 1e-3
    feats = np.asarray(PL.featurize_slices(slices, eps))
    c = C.get("zfp")
    crs = np.asarray([c.cr(s, eps) for s in slices])
    res = PL.kfold_evaluate(feats, crs, model="spline", k=8)
    assert res.medape < 10.0, res


def test_cr_predictor_object_roundtrip(miranda):
    slices, eps, _ = miranda
    c = C.get("zfp")
    crs = jnp.asarray([c.cr(s, eps) for s in slices])
    pred = PL.CRPredictor.train(slices[:20], crs[:20], eps)
    out = np.asarray(pred.predict(slices[20:]))
    ape = 100 * np.abs(out - np.asarray(crs[20:])) / np.asarray(crs[20:])
    assert np.median(ape) < 20.0, ape


def test_3d_hosvd_features():
    vols = jnp.stack([scientific.volume("qmcpack", shape=(16, 48, 48), seed=s)
                      for s in range(6)])
    eps = 1e-2
    feats = jnp.stack([P.features_3d(v, eps) for v in vols])
    assert bool(jnp.all(jnp.isfinite(feats)))
    assert feats.shape == (6, 2)
