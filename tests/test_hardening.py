"""Regression tests for the UC1/UC2 hardening fixes: zero-iteration
bisection, non-positive model outputs, eps validation, int32 code
saturation, empty UC2 model dicts, and q-ent boundary-eps oracles."""
from typing import NamedTuple

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import predictors as P, usecases as UC
from repro.data import scientific


@pytest.fixture(scope="module")
def setup():
    slices = scientific.field_slices("scale-u", count=8, n=64)
    rng = float(jnp.max(slices) - jnp.min(slices))
    ebs = [1e-4 * rng, 1e-3 * rng, 1e-2 * rng]
    gm = UC.EbGridModel.train(slices[:6], "sz2", ebs)
    return slices, ebs, rng, gm


# ------------------------------------------------- UC1 zero-iteration search
def test_find_error_bound_zero_iters_returns_finite(setup):
    """max_iters=0 used to NameError on the unbound loop variables."""
    slices, ebs, rng, gm = setup
    eps, cr = UC.find_error_bound_for_cr(gm, slices[7], 6.0, max_iters=0)
    assert np.isfinite(eps) and np.isfinite(cr)
    assert ebs[0] <= eps <= ebs[-1]


def test_find_error_bound_zero_iters_matches_exhaustive_convention(setup):
    """Like find_error_bound_exhaustive, the degenerate search reports the
    upper bracket probe."""
    slices, ebs, rng, gm = setup
    target = 6.0   # strictly between cr(lo) and cr(hi) for this field
    cache = P.get_engine(gm.cfg).cached(slices[7])
    cr_lo = gm.predict(slices[7], ebs[0], cache)
    cr_hi = gm.predict(slices[7], ebs[-1], cache)
    assert cr_lo < target < cr_hi, "fixture drifted: pick a bracketed target"
    eps, cr = UC.find_error_bound_for_cr(gm, slices[7], target, max_iters=0)
    assert eps == ebs[-1] and cr == pytest.approx(cr_hi)


# ------------------------------------------------ non-positive model outputs
class _ConstModel(NamedTuple):
    """Stand-in regression whose prediction is a constant (possibly
    degenerate) value; NamedTuple so predict_fast can trace it."""
    level: jnp.ndarray

    def predict(self, feats):
        return jnp.broadcast_to(self.level, (feats.shape[0],))


def _degenerate_grid_model(levels, ebs):
    from repro.core.pipeline import CRPredictor
    models = [CRPredictor(_ConstModel(jnp.float32(v)), float(e))
              for v, e in zip(levels, ebs)]
    return UC.EbGridModel(np.asarray(ebs, np.float64), models, "degenerate")


def test_predict_clamps_nonpositive_model_output():
    """A regression extrapolating to CR <= 0 must not feed np.log a
    non-positive value (NaN would poison every bisection comparison)."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(32, 32)),
                    jnp.float32)
    gm = _degenerate_grid_model([-2.0, 0.0], [1e-3, 1e-1])
    mid = float(np.exp(0.5 * (np.log(1e-3) + np.log(1e-1))))
    for eps in (1e-3, mid, 1e-1):
        cr = gm.predict(x, eps)
        assert np.isfinite(cr) and cr > 0, (eps, cr)


def test_bisection_never_compares_nan():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(32, 32)),
                    jnp.float32)
    gm = _degenerate_grid_model([-1.0, jnp.nan], [1e-3, 1e-1])
    eps, cr = UC.find_error_bound_for_cr(gm, x, 5.0, max_iters=8)
    assert np.isfinite(eps) and np.isfinite(cr)


def test_clamp_keeps_inf_above_any_target():
    """+inf must clamp to the ceiling (it means 'CR far above target'),
    not to the floor -- otherwise bisection walks the wrong direction."""
    assert UC._clamp_cr(float("inf")) == UC._CR_CEIL
    assert UC._clamp_cr(float("nan")) == UC._CR_FLOOR
    assert UC._clamp_cr(-3.0) == UC._CR_FLOOR
    assert UC._clamp_cr(2.5) == 2.5
    x = jnp.asarray(np.random.default_rng(3).normal(size=(32, 32)),
                    jnp.float32)
    gm = _degenerate_grid_model([2.0, jnp.inf], [1e-3, 1e-1])
    # target above cr(lo)=2 and below the (clamped) cr(hi): the search
    # must keep probing inside the bracket, not return hi claiming a hit
    eps, cr = UC.find_error_bound_for_cr(gm, x, 5.0, max_iters=4)
    assert np.isfinite(cr) and 1e-3 <= eps <= 1e-1


# ----------------------------------------------------------- eps validation
def test_quantized_codes_rejects_nonpositive_eps():
    x = jnp.ones((8,))
    with pytest.raises(ValueError, match="positive"):
        P.quantized_codes(x, 0.0)
    with pytest.raises(ValueError, match="positive"):
        P.quantized_codes(x, -1e-3)
    with pytest.raises(ValueError, match="positive"):
        P.quantized_entropy(x, 0.0)


def test_quantized_entropy_sweep_rejects_nonpositive_eps():
    x = jnp.ones((2, 64))
    with pytest.raises(ValueError, match="positive"):
        P.quantized_entropy_sweep(x, jnp.asarray([1e-3, 0.0]))
    with pytest.raises(ValueError, match="positive"):
        P.features_sweep(jnp.ones((2, 16, 16)), [-1.0])
    from repro.kernels.qent import ops as qent_ops
    with pytest.raises(ValueError, match="positive"):
        qent_ops.quantized_entropy_sweep(x, jnp.asarray([0.0]))


def test_slice_cache_rejects_nonpositive_eps():
    cache = P.features_2d_cached(jnp.ones((16, 16)))
    with pytest.raises(ValueError, match="positive"):
        cache(0.0)


def test_eps_validation_stays_jit_traceable():
    """Validation must skip traced error bounds even when they arrive
    wrapped in a list (engine.features builds [eps]) -- the pre-PR entry
    points were jit-traceable and must stay so."""
    import jax

    f = jax.jit(lambda x, e: P.get_engine().features(x, e))
    out = f(jnp.ones((2, 16, 16)), jnp.float32(1e-2))
    assert out.shape == (2, 2)
    g = jax.jit(lambda x, e: P.features_sweep(x, [e, 2 * e], sharded=False))
    assert g(jnp.ones((2, 16, 16)), jnp.float32(1e-2)).shape == (2, 2, 2)


# ------------------------------------------------------ int32 code overflow
def test_quantized_codes_saturate_instead_of_wrapping():
    x = jnp.asarray([1e30, -1e30, 1.0], jnp.float32)
    codes = np.asarray(P.quantized_codes(x, 1e-6))
    # wrapped casts would flip sign; saturation preserves the ordering
    assert codes[0] == 2147483520 and codes[1] == -2147483648
    assert codes[0] > codes[2] > codes[1]


def test_qent_sweep_extreme_values_match_saturating_oracle():
    """Sort route with codes beyond int32: must equal the entropy of the
    saturated codes (and stay finite), not a wrapped histogram."""
    rng = np.random.default_rng(2)
    x = np.concatenate([rng.normal(size=62), [1e30, -1e30]])
    x = jnp.asarray(x[None], jnp.float32)
    eps = 1e-6
    got = float(P.quantized_entropy_sweep(x, jnp.asarray([eps]))[0, 0])
    codes = np.clip(np.floor(np.asarray(x[0], np.float64) / eps),
                    -2147483648.0, 2147483520.0).astype(np.int64)
    counts = np.bincount(codes - codes.min())
    p = counts[counts > 0] / counts.sum()
    want = float(-(p * np.log2(p)).sum())
    assert np.isfinite(got)
    assert abs(got - want) < 1e-3, (got, want)


# ------------------------------------------------------------ UC2 empty dict
def test_best_compressor_empty_models_raises():
    x = jnp.ones((16, 16))
    with pytest.raises(ValueError, match="at least one trained model"):
        UC.best_compressor({}, x, 1e-3)


# ------------------------------------------------- q-ent boundary-eps oracle
@pytest.mark.parametrize("rel_eb", [1.0, 2.0, 1.0 / 65535.0])
def test_qent_oracle_at_boundary_eps(rel_eb):
    """Boundary error bounds -- eps spanning the full value range (1-2
    codes) and eps putting the code range exactly at the histogram size --
    against an np.bincount oracle, on both the scalar and sweep paths."""
    slices = scientific.field_slices("miranda-vx", count=2, n=64)
    rng = float(jnp.max(slices) - jnp.min(slices))
    eps = rel_eb * rng
    got_sweep = np.asarray(P.quantized_entropy_sweep(
        slices, jnp.asarray([eps], jnp.float32)))
    for s in range(slices.shape[0]):
        flat = np.asarray(slices[s], np.float64).reshape(-1)
        codes = np.floor(flat / np.float32(eps)).astype(np.int64)
        counts = np.bincount(codes - codes.min())
        p = counts[counts > 0] / counts.sum()
        want = float(-(p * np.log2(p)).sum())
        got_one = float(P.quantized_entropy(slices[s], eps))
        assert abs(got_one - want) < 1e-3, (s, got_one, want)
        assert abs(got_sweep[s, 0] - want) < 1e-3, (s, got_sweep[s, 0], want)


def test_qent_huge_eps_zero_entropy():
    """eps far above the value range: every value lands in one bin (data
    shifted positive so floor() can't straddle the 0/-1 code boundary)."""
    slices = scientific.field_slices("miranda-vx", count=2, n=64)
    slices = slices - jnp.min(slices) + 1.0
    got = np.asarray(P.quantized_entropy_sweep(
        slices, jnp.asarray([1e12], jnp.float32)))
    # telescoping f32 accumulation leaves ~1e-5 of noise around exact 0
    np.testing.assert_allclose(got, 0.0, atol=1e-4)
