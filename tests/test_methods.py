"""Servable-method platform: registry, buckets, adaptive window,
admission control, kv_gate, and the fused engine QDQ path.

Complements ``test_sweep_service.py`` (which pins the pre-refactor
behavior of the three paper methods): everything HERE is specific to the
method registry introduced by the platform refactor -- bucket-ladder
boundary values, per-method warmup coverage, the fourth (``kv_gate``)
method end to end, load-proportional ``RetryAfter`` hints, and the
per-method stats counters.
"""
import time

import numpy as np
import pytest

from repro.core import predictors as P
from repro.serve import method as MM
from repro.serve.registry import MethodRegistry, default_registry
from repro.serve.sweep_service import (RetryAfter, ServiceConfig,
                                       SweepService, _eps_bucket,
                                       _row_bucket)


def _slices(k, n=24, seed=0):
    rng = np.random.default_rng(seed)
    base = np.cumsum(rng.standard_normal((k, n, n)), axis=-1)
    return np.asarray(base, np.float32)


# ------------------------------------------------------------- buckets

def test_row_bucket_boundaries():
    # k=1 and exact powers of two map to themselves; k=pow2+1 doubles
    assert _row_bucket(1) == 1
    assert _row_bucket(2) == 2
    assert _row_bucket(3) == 4
    assert _row_bucket(4) == 4
    assert _row_bucket(5) == 8
    assert _row_bucket(1024) == 1024
    assert _row_bucket(1025) == 2048


def test_eps_bucket_boundaries():
    # every declared bucket maps to itself (exact-boundary values)
    for b in MM._EPS_BUCKETS:
        assert _eps_bucket(b) == b
    assert _eps_bucket(5) == 6
    assert _eps_bucket(31) == 32
    # bucket-cap overflow: past the largest declared bucket the ladder
    # continues in 16-wide steps
    assert _eps_bucket(33) == 48
    assert _eps_bucket(48) == 48
    assert _eps_bucket(49) == 64


def test_method_ladder_pad_and_overflow():
    """A method's explicit batch_buckets pad batches to the smallest
    covering bucket and fall back to the pow2 ladder past the cap."""
    reg = MethodRegistry()
    m = reg.register(MM.FeaturizeMethod(MM.SweepLauncher(),
                                        batch_buckets=(3, 6)))
    with SweepService(ServiceConfig(max_wait_ms=50.0), registry=reg) as svc:
        assert svc._k_pad((m,), 2) == 3
        assert svc._k_pad((m,), 3) == 3
        assert svc._k_pad((m,), 4) == 6
        assert svc._k_pad((m,), 7) == 8          # overflow -> pow2 ladder
        s = _slices(2)
        got = svc.featurize(s, [1e-2])
        ref = np.asarray(P.features_sweep(s, [1e-2], sharded=False))
        assert np.array_equal(got, ref)
        assert svc.stats()["pad_rows"] == 1      # 2 rows padded to 3


def test_unsorted_batch_buckets_rejected():
    with pytest.raises(ValueError, match="sorted"):
        MM.FeaturizeMethod(MM.SweepLauncher(), batch_buckets=(4, 2))
    with pytest.raises(ValueError, match="sorted"):
        MM.FeaturizeMethod(MM.SweepLauncher(), batch_buckets=(2, 2, 4))
    with pytest.raises(ValueError, match="sorted"):
        MM.FeaturizeMethod(MM.SweepLauncher(), batch_buckets=())


# ------------------------------------------------------------- registry

def test_default_registry_shape():
    reg = default_registry()
    assert reg.names() == ("featurize", "find_eb", "best_compressor",
                           "kv_gate", "advise", "find_setting", "quality")
    # the paper methods (and the advisor/UC3 riding their sweeps) share
    # ONE launcher instance (that identity is what makes them coalesce
    # into the same launches)
    sweep = reg.get("featurize").launcher
    assert reg.get("find_eb").launcher is sweep
    assert reg.get("best_compressor").launcher is sweep
    assert reg.get("advise").launcher is sweep
    assert reg.get("find_setting").launcher is sweep
    assert reg.get("kv_gate").launcher is not sweep
    assert reg.get("quality").launcher is not sweep
    # launcher wire ids are assigned in registration order (append-only:
    # sweep=0, int8cr=1, quality=2 is the wire contract)
    assert reg.launcher_id(sweep) == 0
    assert reg.launcher_id(reg.get("kv_gate").launcher) == 1
    assert reg.launcher_id(reg.get("quality").launcher) == 2
    assert reg.launcher(0) is sweep
    assert "featurize" in reg and "nope" not in reg


def test_registry_rejects_duplicates_and_unknowns():
    reg = default_registry()
    with pytest.raises(ValueError, match="already registered"):
        reg.register(MM.FeaturizeMethod(MM.SweepLauncher()))
    with pytest.raises(ValueError, match="kv_gate"):
        reg.get("not-a-method")


def test_submit_unknown_method_raises():
    with SweepService(ServiceConfig(max_wait_ms=5.0)) as svc:
        with pytest.raises(ValueError, match="registered"):
            svc.submit("not-a-method", _slices(1), [1e-2])


# ------------------------------------------------------------- warmup

def test_warmup_covers_all_registered_methods():
    """No-arg warmup compiles every registered method's warmup_spec
    buckets -- both launchers appear in the executable set, and specs
    shared by methods on the same launcher are deduplicated."""
    with SweepService(ServiceConfig(max_wait_ms=5.0)) as svc:
        svc.warmup()
        sigs = svc._executables
        assert {s[1] for s in sigs} == {"sweep", "int8cr", "quality"}
        sweep_sigs = {s for s in sigs if s[1] == "sweep"}
        gate_sigs = {s for s in sigs if s[1] == "int8cr"}
        qual_sigs = {s for s in sigs if s[1] == "quality"}
        # default spec: (32, 32) x 1 eps x buckets {1, 2}; the sweep
        # methods (featurize/UC1/UC2/advise/find_setting) share it, so
        # exactly 2 sweep executables compile
        assert {(s[2], s[3]) for s in sweep_sigs} == \
            {(1, (32, 32)), (2, (32, 32))}
        assert {(s[2], s[3]) for s in gate_sigs} == \
            {(1, (256,)), (2, (256,))}
        assert {(s[2], s[3]) for s in qual_sigs} == \
            {(1, (32, 32)), (2, (32, 32))}
        assert len(sigs) == 6
        assert svc.launches == 0     # warmup launches aren't traffic
        # warmed buckets serve real traffic without new executables
        before = len(svc._executables)
        svc.kv_gate([np.zeros(256, np.float32)])
        assert len(svc._executables) == before


# ------------------------------------------------------------- kv_gate

def test_kv_gate_matches_reference_model():
    """Service-batched kv_gate CRs match per-leaf predicted_cr_int8 on
    the raw (unflattened) leaves, and make identical gate decisions."""
    import jax.numpy as jnp
    from repro.train.grad_compress import predicted_cr_int8

    rng = np.random.default_rng(1)
    leaves = [
        np.asarray(rng.standard_normal((2, 3, 8, 16)), np.float32),
        np.asarray(rng.standard_normal((4, 64)) * 1e-3, np.float32),
        np.zeros((512,), np.float32) + 0.25,     # constant: high CR
    ]
    ref = np.asarray([float(predicted_cr_int8(jnp.asarray(x)))
                      for x in leaves], np.float32)
    with SweepService(ServiceConfig(max_wait_ms=5.0)) as svc:
        got = svc.kv_gate(leaves)
    assert got.shape == (3,)
    # vmapped-batch vs single-leaf reduction order may differ in the
    # last ulp, so compare numerically and on the gate decision
    np.testing.assert_allclose(got, ref, rtol=1e-5)
    assert [g >= 2.5 for g in got] == [r >= 2.5 for r in ref]


def test_kv_gate_dedups_and_coalesces():
    """Identical leaves dedup inside a batch; concurrent kv_gate and
    featurize requests ride the same micro-batch (two launches: one per
    launcher) with zero method-specific branching."""
    leaf = np.asarray(np.random.default_rng(2).standard_normal(128),
                      np.float32)
    with SweepService(ServiceConfig(max_wait_ms=200.0)) as svc:
        f1 = svc.submit_kv_gate([leaf, leaf.copy(), leaf + 1.0])
        f2 = svc.submit_featurize(_slices(2), [1e-2])
        crs = f1.result(timeout=60)
        f2.result(timeout=60)
        assert crs[0] == crs[1]                  # same digest, same row
        st = svc.stats()
        # 3 kv leaves dedup to 2 rows + 2 featurize rows, in exactly one
        # launch per launcher
        assert st["launches"] == 2
        assert st["rows_launched"] == 4
        assert st["batches"] == 1


def test_kv_gate_rejects_empty():
    with SweepService(ServiceConfig(max_wait_ms=5.0)) as svc:
        with pytest.raises(ValueError, match="leaf"):
            svc.submit_kv_gate([])
        with pytest.raises(ValueError, match="empty"):
            svc.submit_kv_gate([np.zeros((0,), np.float32)])


# ------------------------------------------- engine: fused QDQ + service

def _reference_compress(cache, ratio):
    """The pre-refactor per-leaf engine path: separate quantize /
    dequantize calls per gated leaf + device-shape byte metering."""
    import jax
    import jax.numpy as jnp
    from repro.train.grad_compress import (dequantize_int8,
                                           predicted_cr_int8,
                                           quantize_int8)

    leaves, tdef = jax.tree.flatten(cache)
    saved = total = 0
    for i, x in enumerate(leaves):
        if x.dtype not in (jnp.bfloat16, jnp.float32) or x.ndim < 4:
            continue
        cr = float(predicted_cr_int8(x.astype(jnp.float32)))
        total += x.size * x.dtype.itemsize
        if cr >= ratio:
            codes, scales = quantize_int8(x.astype(jnp.float32))
            saved += int(x.size * x.dtype.itemsize -
                         (codes.size + scales.size * 4))
            leaves[i] = dequantize_int8(codes, scales, x.shape, x.dtype)
    return jax.tree.unflatten(tdef, leaves), saved, total


def _kv_cache(seed=3):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return {
        # smooth (low-entropy) leaf: clears the 2.5x gate
        "k": jnp.asarray(np.cumsum(rng.standard_normal((1, 2, 4, 256)),
                                   axis=-1) * 1e-3, jnp.float32),
        # white-noise leaf: fails the gate, stays untouched
        "v": jnp.asarray(rng.standard_normal((1, 2, 4, 256)), jnp.float32),
        # rank-2 leaf: not a KV block, never a candidate
        "aux": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
    }


def test_engine_fused_qdq_bitequal():
    """The fused one-jit quantize-dequantize rewrite produces leaves and
    byte metering bit-equal to the old per-leaf two-call path."""
    import jax
    from repro.serve.engine import Engine, ServeConfig

    cache = _kv_cache()
    scfg = ServeConfig(kv_compress=True, kv_gate_ratio=2.5)
    eng = Engine(None, None, scfg)       # jits are lazy: no model needed
    got = eng._maybe_compress_cache(cache)
    ref, saved, total = _reference_compress(cache, scfg.kv_gate_ratio)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert eng.kv_saved_bytes == saved
    assert eng.kv_total_bytes == total
    assert saved > 0                     # the smooth leaf really gated


def test_engine_gate_through_sweep_service():
    """With sweep_service= attached the engine's gate CRs come from the
    registered kv_gate method; the compressed cache matches the private
    jit engine (gate ratio far from the CR values, so the last-ulp
    launcher difference cannot flip a decision)."""
    import jax
    from repro.serve.engine import Engine, ServeConfig

    cache = _kv_cache(seed=4)
    scfg = ServeConfig(kv_compress=True, kv_gate_ratio=2.5)
    with SweepService(ServiceConfig(max_wait_ms=2.0)) as svc:
        eng = Engine(None, None, scfg, sweep_service=svc)
        got = eng._maybe_compress_cache(cache)
        st = svc.stats()
    ref_eng = Engine(None, None, scfg)
    ref = ref_eng._maybe_compress_cache(cache)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert eng.kv_saved_bytes == ref_eng.kv_saved_bytes
    assert eng.kv_total_bytes == ref_eng.kv_total_bytes
    assert st["methods"]["kv_gate"]["completed"] == 1
    assert st["methods"]["kv_gate"]["rows"] == 2     # the two candidates


# ----------------------------------------------- adaptive window + stats

def test_adaptive_window_shrinks_and_recovers():
    """Deterministic unit drive of the window controller: loaded flushes
    halve toward min_wait_ms, idle flushes grow back to the ceiling."""
    scfg = ServiceConfig(max_wait_ms=8.0, min_wait_ms=0.5)
    with SweepService(scfg) as svc:
        assert svc.stats()["window_ms"] == 8.0
        for want in (4.0, 2.0, 1.0, 0.5, 0.5):
            svc._note_flush(True)
            assert svc._window_ms == want
        assert svc._window_shrinks == 5
        for want in (1.0, 2.0, 4.0, 8.0, 8.0):
            svc._note_flush(False)
            assert svc._window_ms == want
        assert svc.stats()["window_ms"] == 8.0


def test_adaptive_window_disabled_stays_pinned():
    scfg = ServiceConfig(max_wait_ms=8.0, adapt_window=False)
    with SweepService(scfg) as svc:
        for _ in range(4):
            svc._note_flush(True)
        assert svc._window_ms == 8.0
        assert svc.stats()["window_shrinks"] == 0


def test_saturated_traffic_shrinks_window_live():
    """End to end: back-to-back over-cap submissions drive the window
    down from the configured ceiling."""
    scfg = ServiceConfig(max_batch_slices=2, max_wait_ms=50.0,
                         min_wait_ms=0.0)
    with SweepService(scfg) as svc:
        futs = [svc.submit_featurize(_slices(2, seed=s), [1e-2])
                for s in range(4)]
        for f in futs:
            f.result(timeout=60)
        st = svc.stats()
        assert st["window_shrinks"] >= 1
        assert st["window_ms"] < 50.0


def test_per_method_counters():
    with SweepService(ServiceConfig(max_wait_ms=5.0)) as svc:
        svc.featurize(_slices(2), [1e-2, 1e-1])
        svc.kv_gate([np.ones(64, np.float32)])
        m = svc.stats()["methods"]
    assert m["featurize"]["completed"] == 1
    assert m["featurize"]["rows"] == 2
    assert m["featurize"]["p95_ms"] >= m["featurize"]["p50_ms"] > 0
    assert m["kv_gate"]["completed"] == 1
    assert m["kv_gate"]["failed"] == 0


def test_max_live_batches_validated_and_reported():
    with SweepService(ServiceConfig(max_wait_ms=5.0,
                                    max_live_batches=1)) as svc:
        svc.featurize(_slices(1), [1e-2])
        st = svc.stats()
        assert st["live_batches"] == 0           # drained after .result()


# ----------------------------------------------- multi-process kv_gate

def test_kv_gate_across_processes():
    """The launch header's launcher wire id routes a mixed
    kv_gate+featurize batch across the leader/follower fabric: one
    collective launch per launcher, CRs matching the local model."""
    from _child import run_procs

    outs = run_procs("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch import mesh as M
        from repro.serve.sweep_service import ServiceConfig, SweepService
        from repro.train.grad_compress import predicted_cr_int8

        mesh = M.make_sweep_mesh()
        svc = SweepService(ServiceConfig(max_wait_ms=200.0), mesh=mesh)
        rng = np.random.default_rng(0)
        leaves = [
            np.asarray(rng.standard_normal((2, 2, 4, 32)), np.float32),
            np.asarray(np.cumsum(rng.standard_normal(512)) * 1e-3,
                       np.float32),
        ]
        if PID == 0:
            ref = np.asarray(
                [float(predicted_cr_int8(jnp.asarray(x))) for x in leaves],
                np.float32)
            s = np.asarray(rng.standard_normal((3, 32, 32)), np.float32)
            f1 = svc.submit_kv_gate(leaves)
            f2 = svc.submit_featurize(s, [1e-2])
            got = f1.result(timeout=120)
            f2.result(timeout=120)
            np.testing.assert_allclose(got, ref, rtol=1e-5)
            st = svc.stats()
            assert st["launches"] == 2, st["launches"]
            assert st["methods"]["kv_gate"]["completed"] == 1
            svc.close()
            print("KVGATE LEADER OK", flush=True)
        else:
            svc.serve()
            assert svc.launches == 2, svc.launches
            print("KVGATE FOLLOWER OK", flush=True)
    """)
    assert "KVGATE LEADER OK" in outs[0]
    assert "KVGATE FOLLOWER OK" in outs[1]


# ------------------------------------------------- load-aware RetryAfter

def test_retry_after_is_load_proportional():
    """With a measured drain rate the backoff hint scales with queue
    depth instead of parroting the wait window."""
    scfg = ServiceConfig(max_wait_ms=10_000.0, adapt_window=False,
                         max_queue_rows=4)
    svc = SweepService(scfg)
    try:
        # park 40 rows (a single over-wide request is always admitted);
        # nothing flushes for 10s, so the queue depth is stable
        parked = svc.submit_featurize(_slices(40, n=8), [1e-2])
        deadline = time.perf_counter() + 5.0
        while not svc.stats()["queue_rows"] and \
                time.perf_counter() < deadline:
            time.sleep(0.01)
        svc._ema_rows_per_s = 2.0                # recent drain: 2 rows/s
        with pytest.raises(RetryAfter) as ei:
            svc.submit_featurize(_slices(1, n=8), [1e-2])
        # 40 pending rows / 2 rows/s = 20s >> the 10s window floor
        assert ei.value.pending_rows == 40
        assert ei.value.retry_after_s == pytest.approx(20.0)
        # with no drain-rate estimate the hint floors at the window
        svc._ema_rows_per_s = 0.0
        svc._ema_batch_s = 0.0
        with pytest.raises(RetryAfter) as ei:
            svc.submit_featurize(_slices(1, n=8), [1e-2])
        assert ei.value.retry_after_s == pytest.approx(10.0)
        assert svc.stats()["rejected"] == 2
    finally:
        svc.close()                              # drains the parked rows
        parked.result(timeout=120)
