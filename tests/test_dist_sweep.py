"""Sharded featurization sweeps: multi-device vs single-device equivalence.

Like test_dist.py, every multi-device scenario runs in a child interpreter
with XLA_FLAGS set before jax is imported (the main pytest process keeps
whatever device count it started with).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _child import run_child


def test_sharded_sweep_matches_single_device():
    """(k, e, 2) from an 8-device mesh == single-device engine, for a
    divisible k and a non-divisible k (pad + drop)."""
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import predictors as P
        from repro.dist import sharding as S
        from repro.launch import mesh as M
        from repro.data import scientific

        s = scientific.field_slices("miranda-vx", count=16, n=96)
        rng = float(jnp.max(s) - jnp.min(s))
        ebs = jnp.asarray([r * rng for r in (1e-4, 1e-2, 1e-1)], jnp.float32)
        mesh = M.make_sweep_mesh()
        for k in (16, 11):           # 11 does not divide 8: pad to 16
            ref = np.asarray(P.features_sweep(s[:k], ebs, sharded=False))
            with S.use_mesh(mesh):
                got = np.asarray(P.features_sweep(s[:k], ebs))
            assert got.shape == (k, 3, 2), got.shape
            d = float(np.abs(got - ref).max())
            assert d < 1e-5, (k, d)
            print("K", k, "MAXDIFF", d)
    """)
    assert "K 16" in out and "K 11" in out


def test_sharded_out_option_masks_padding():
    out = run_child("""
        import jax, jax.numpy as jnp
        from repro.core import predictors as P
        from repro.dist import sharding as S
        from repro.launch import mesh as M
        from repro.data import scientific

        s = scientific.field_slices("cesm-cloud", count=11, n=64)
        ebs = [1e-3, 1e-2]
        with S.use_mesh(M.make_sweep_mesh()):
            padded = P.features_sweep(s, ebs, gather=False)
            gathered = P.features_sweep(s, ebs)
        assert padded.shape == (16, 2, 2), padded.shape   # 11 -> pad to 16
        assert bool(jnp.all(padded[11:] == 0)), "pad rows not masked"
        assert len(padded.sharding.device_set) == 8, padded.sharding
        import numpy as np
        np.testing.assert_allclose(np.asarray(padded[:11]),
                                   np.asarray(gathered), atol=1e-6)
        print("SHARDED OUT OK")
    """)
    assert "SHARDED OUT OK" in out


def test_engine_and_pipeline_auto_route_under_mesh():
    """The engine/pipeline entry points shard transparently under an
    active mesh, including the Pallas-kernel route, and spec_for resolves
    the logical "slices" axis."""
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import pipeline as PL, predictors as P
        from repro.dist import sharding as S
        from repro.launch import mesh as M
        from repro.data import scientific

        s = scientific.field_slices("miranda-vx", count=8, n=96)
        rng = float(jnp.max(s) - jnp.min(s))
        ebs = jnp.asarray([r * rng for r in (1e-3, 1e-1)], jnp.float32)
        ref_sweep = np.asarray(PL.featurize_sweep(s, ebs))
        ref_feats = np.asarray(PL.featurize_slices(s, float(ebs[0])))
        cfg_k = P.PredictorConfig(use_kernels=True, qent_bins=4096)
        ref_kern = np.asarray(P.features_sweep(s, ebs, cfg_k, sharded=False))
        with S.use_mesh(M.make_sweep_mesh()) as mesh:
            assert S.spec_for((8, 96, 96), ("slices", None, None)) == \
                jax.sharding.PartitionSpec("data", None, None)
            got_sweep = np.asarray(PL.featurize_sweep(s, ebs))
            got_feats = np.asarray(PL.featurize_slices(s, float(ebs[0])))
            got_kern = np.asarray(P.features_sweep(s, ebs, cfg_k))
            # k=1 (the UC1/UC2 per-query shape) must stay on the local
            # path: nothing to parallelize, so no broadcast launch
            one = P.features_sweep(s[:1], ebs)
            assert len(one.sharding.device_set) == 1, one.sharding
            np.testing.assert_allclose(np.asarray(one), ref_sweep[:1],
                                       atol=1e-5)
        np.testing.assert_allclose(got_sweep, ref_sweep, atol=1e-5)
        np.testing.assert_allclose(got_feats, ref_feats, atol=1e-5)
        np.testing.assert_allclose(got_kern, ref_kern, atol=1e-5)
        print("AUTO ROUTE OK")
    """)
    assert "AUTO ROUTE OK" in out


def test_ebgrid_train_under_mesh_matches():
    """EbGridModel.train under a mesh (sharded featurization + local-shard
    CR table) must reproduce the single-device model's predictions."""
    out = run_child("""
        import numpy as np, jax.numpy as jnp
        from repro.core import usecases as UC
        from repro.dist import sharding as S
        from repro.launch import mesh as M
        from repro.data import scientific

        s = scientific.field_slices("scale-u", count=7, n=64)
        rng = float(jnp.max(s) - jnp.min(s))
        ebs = [1e-4 * rng, 1e-3 * rng, 1e-2 * rng]
        gm_ref = UC.EbGridModel.train(s[:6], "sz2", ebs)
        with S.use_mesh(M.make_sweep_mesh()):
            gm_sh = UC.EbGridModel.train(s[:6], "sz2", ebs)
        for eps in (ebs[0], 3e-4 * rng, ebs[-1]):
            a = gm_ref.predict(s[6], eps)
            b = gm_sh.predict(s[6], eps)
            assert abs(a - b) <= 1e-4 * max(abs(a), 1.0), (eps, a, b)
        print("TRAIN OK")
    """, devices=4)
    assert "TRAIN OK" in out


def test_explicit_mesh_argument():
    """Passing mesh= (no use_mesh context) shards too; sharded=True with
    no usable mesh raises."""
    out = run_child("""
        import numpy as np, jax.numpy as jnp
        from repro.core import predictors as P
        from repro.launch import mesh as M
        from repro.data import scientific

        s = scientific.field_slices("miranda-vx", count=6, n=64)
        ebs = [1e-3, 1e-2]
        ref = np.asarray(P.features_sweep(s, ebs, sharded=False))
        got = np.asarray(P.features_sweep(s, ebs, mesh=M.make_sweep_mesh()))
        np.testing.assert_allclose(got, ref, atol=1e-5)
        try:
            P.features_sweep(s, ebs, sharded=True)
        except ValueError as e:
            print("RAISES", "slices" in str(e))
    """)
    assert "RAISES True" in out


# ---------------------------------------------------------------- local-only
# (no subprocess: these exercise the single-device fallbacks in-process)

def test_sharded_helpers_single_device():
    from repro.core import predictors as P
    from repro.dist import sweep as DS

    assert DS.active_sweep_mesh(None) is None
    assert DS._even_bounds(10, 3, 0) == (0, 4)
    assert DS._even_bounds(10, 3, 1) == (4, 7)
    assert DS._even_bounds(10, 3, 2) == (7, 10)
    x = jnp.ones((2, 16, 16))
    # no mesh anywhere: features_sweep_sharded falls back to the engine
    got = DS.features_sweep_sharded(x, [1e-2])
    ref = P.features_sweep(x, [1e-2], sharded=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-7)


def test_training_crs_single_process():
    from repro import compressors as C
    from repro.core import usecases as UC
    from repro.data import scientific
    from repro.dist import sweep as DS

    s = scientific.field_slices("miranda-vx", count=3, n=64)
    ebs = [1e-3, 1e-2]
    comp = C.get("sz2")
    table = DS.training_crs(comp, s, ebs)
    assert table.shape == (3, 2)
    want = np.asarray([[comp.cr(sl, e) for e in ebs] for sl in s])
    np.testing.assert_allclose(table, want, rtol=1e-12)
