"""Streaming dataset sweeps: DatasetSource chunking, incremental
aggregation bit-equality, streaming content digests, and the advisor.

The load-bearing invariant: the chunked driver (``core.stream``) must
produce the EXACT tensor the in-memory ``features_sweep`` produces --
every chunk launches through the same row-independent sweep body, so
chunk boundaries, ragged final chunks, budgets that don't divide k, and
double-buffering must all be invisible in the output bits.  The
multi-process cohort rides ``tests._child.run_procs`` exactly like the
fabric suites.
"""
import json
import os

import numpy as np
import pytest

from _child import run_child, run_procs

from repro.core import predictors as P
from repro.core import stream as ST
from repro.data import scientific
from repro.data import source as SRC

EBS = [1e-4, 1e-3, 1e-2]


def _gen(count=11, n=32, seed=0):
    return SRC.GeneratorSource([SRC.FieldVariable("miranda-vx", count,
                                                  (n,), seed=seed)])


# ---------------------------------------------------------------------------
# DatasetSource backings
# ---------------------------------------------------------------------------


def test_generator_rows_bitequal_field_slices():
    """Chunked generation == slicing the full field_slices stack, bit
    for bit (same key split over the full count, same z schedule)."""
    full = np.asarray(scientific.field_slices("miranda-vx", count=9, n=32))
    for lo, hi in ((0, 9), (2, 5), (8, 9), (3, 3)):
        rows = SRC.generate_field_rows("miranda-vx", 9, lo, hi, n=32)
        assert np.array_equal(rows, full[lo:hi])
    gen = _gen(9, 32)
    assert gen.variables() == ("miranda-vx",)
    assert np.array_equal(gen.read("miranda-vx"), full)
    # chunk iteration covers the variable exactly once, in order
    got = np.concatenate([c for _, c in gen.chunks("miranda-vx", rows=4)])
    assert np.array_equal(got, full)


def test_memmap_and_npz_roundtrip(tmp_path):
    """write_dataset -> open_dataset round-trips both formats; float64
    on disk converts to the identical f32 rows on read."""
    gen = _gen(7, 32)
    ref = gen.read("miranda-vx")
    mm = SRC.write_dataset(str(tmp_path / "ds"), gen, fmt="memmap",
                           dtype="float64", budget_bytes=3 * 32 * 32 * 4)
    ds = SRC.open_dataset(mm)
    assert isinstance(ds, SRC.MemmapSource)
    meta = ds.meta("miranda-vx")
    assert meta.shape == (7, 32, 32) and meta.dtype == "float64"
    assert np.array_equal(ds.read("miranda-vx"), ref)
    assert np.array_equal(ds.read_rows("miranda-vx", 2, 5), ref[2:5])

    nz = SRC.write_dataset(str(tmp_path / "ds2"), gen, fmt="npz",
                           dtype="float32")
    dz = SRC.open_dataset(nz)
    assert isinstance(dz, SRC.NpzSource)
    assert np.array_equal(dz.read("miranda-vx"), ref)


def test_source_validation(tmp_path):
    gen = _gen(5, 32)
    with pytest.raises(ValueError, match="out of range"):
        gen.read_rows("miranda-vx", 0, 6)
    with pytest.raises(ValueError, match="rows= or budget_bytes="):
        list(gen.chunks("miranda-vx"))
    with pytest.raises(ValueError, match="budget must be positive"):
        SRC.rows_per_chunk(gen.meta("miranda-vx"), 0)
    with pytest.raises(FileNotFoundError):
        SRC.MemmapSource(str(tmp_path / "nope"))
    with pytest.raises(ValueError, match="neither"):
        SRC.open_dataset(str(tmp_path / "nope.bin"))
    # a row is the indivisible unit: tiny budgets still make progress
    assert SRC.rows_per_chunk(gen.meta("miranda-vx"), 1) == 1
    with pytest.raises(ValueError, match="shape must be"):
        SRC.FieldVariable("miranda-vx", 3, (4, 4))


# ---------------------------------------------------------------------------
# Chunked-vs-in-memory bit-equality
# ---------------------------------------------------------------------------


def test_stream_bitequal_2d(tmp_path):
    """2-D stack, every chunking regime: budget not dividing k (ragged
    final chunk), single-row chunks, one covering chunk; prefetch on and
    off.  Streamed == in-memory features_sweep, bit for bit."""
    gen = _gen(11, 32)
    path = SRC.write_dataset(str(tmp_path / "ds"), gen, fmt="memmap",
                             dtype="float64", budget_bytes=1 << 20)
    ds = SRC.MemmapSource(path)
    ref = np.asarray(P.features_sweep(ds.read("miranda-vx"), EBS,
                                      sharded=False))
    row = 32 * 32 * 4
    for budget, prefetch in ((4 * row, 2), (4 * row, 0), (1, 2),
                             (100 * row, 1), (3 * row, 3)):
        got = ST.stream_features(
            ds, "miranda-vx", EBS,
            stream=ST.StreamConfig(budget_bytes=budget, prefetch=prefetch))
        assert got.shape == ref.shape
        assert np.array_equal(got, ref), \
            (budget, prefetch, float(np.abs(got - ref).max()))


def test_stream_bitequal_rank4():
    """Rank-4 volume-stack variables chunk over the leading axis exactly
    like slice stacks (HOSVD body, ragged final chunk)."""
    gen = SRC.GeneratorSource(
        [SRC.FieldVariable("qmcpack", 5, (4, 16, 16))])
    name = "qmcpack-vol"
    ref = np.asarray(P.features_sweep(gen.read(name), EBS, sharded=False))
    row = 4 * 16 * 16 * 4
    for rows in (2, 3, 5):
        got = ST.stream_features(
            gen, name, EBS,
            stream=ST.StreamConfig(budget_bytes=rows * row))
        assert np.array_equal(got, ref), rows


def test_stream_engine_entry_and_dataset(tmp_path):
    """The engine's ``stream`` entry point and ``stream_dataset`` (with
    digests) match the direct driver."""
    gen = SRC.GeneratorSource([SRC.FieldVariable("miranda-vx", 6, (32,)),
                               SRC.FieldVariable("qmcpack", 5, (32,))])
    digests = {}
    out = ST.stream_dataset(gen, EBS, digests=digests,
                            stream=ST.StreamConfig(budget_bytes=2 * 32 * 32 * 4))
    from repro.serve.method import slice_digest
    for name in gen.variables():
        full = gen.read(name)
        assert np.array_equal(
            out[name], np.asarray(P.features_sweep(full, EBS, sharded=False)))
        assert digests[name] == slice_digest(full)
    eng = P.get_engine()
    got = eng.stream(gen, "miranda-vx", EBS,
                     stream=ST.StreamConfig(budget_bytes=1 << 14))
    assert np.array_equal(got, out["miranda-vx"])


def test_stream_validation():
    gen = _gen(4, 32)
    with pytest.raises(ValueError, match="budget_bytes must be positive"):
        ST.StreamConfig(budget_bytes=0)
    with pytest.raises(ValueError, match="max_in_flight"):
        ST.StreamConfig(max_in_flight=0)
    with pytest.raises(ValueError, match="error bound"):
        ST.stream_features(gen, "miranda-vx", [0.0])
    # reader-thread failures surface as the caller's exception, not a hang
    class Broken(SRC.DatasetSource):
        def variables(self):
            return ("x",)

        def meta(self, name):
            return SRC.VariableMeta("x", (4, 8, 8), "float32")

        def read_rows(self, name, lo, hi):
            raise RuntimeError("disk on fire")

    with pytest.raises(RuntimeError, match="disk on fire"):
        ST.stream_features(Broken(), "x", EBS,
                           stream=ST.StreamConfig(budget_bytes=1 << 10))


# ---------------------------------------------------------------------------
# Streaming content digest (FeatureCache out-of-core key path)
# ---------------------------------------------------------------------------


def test_streaming_digest_matches_slice_digest():
    """Any chunk split of a variable's rows produces the resident-array
    ``slice_digest`` -- 1-D leaves, 2-D slices, and stacks alike."""
    from repro.serve.method import slice_digest
    rng = np.random.default_rng(0)
    for shape in ((7,), (5, 6), (4, 3, 3), (6, 2, 3, 3)):
        x = rng.normal(size=shape)
        want = slice_digest(x)
        for split in (1, 2, x.shape[0]):
            d = SRC.StreamingDigest()
            for lo in range(0, x.shape[0], split):
                d.update(x[lo:lo + split])
            assert d.digest() == want, (shape, split)
            assert d.rows == x.shape[0]
    # f64 chunks and their f32 round-trip share the digest (the cache
    # contract slice_digest documents)
    x64 = rng.normal(size=(4, 5))
    assert SRC.StreamingDigest().update(x64).digest() == \
        slice_digest(x64.astype(np.float32))
    d = SRC.StreamingDigest()
    with pytest.raises(ValueError, match="before any update"):
        d.digest()
    d.update(np.zeros((2, 3)))
    with pytest.raises(ValueError, match="trailing shape"):
        d.update(np.zeros((2, 4)))


def test_streamed_digest_probes_feature_cache():
    """A digest accumulated from chunked reads of a never-materialized
    volume hits the SAME FeatureCache entries a resident-array
    submission filled -- the out-of-core cache-key path."""
    from repro.serve.method import slice_digest
    from repro.serve.sweep_service import ServiceConfig, SweepService
    vol = np.asarray(scientific.volume("miranda-vx", shape=(6, 16, 16)),
                     np.float32)
    # stream the digest slab by slab (2-row chunks of the volume)
    d = SRC.StreamingDigest()
    for lo in range(0, 6, 2):
        d.update(vol[lo:lo + 2])
    assert d.digest() == slice_digest(vol)
    svc = SweepService(ServiceConfig(max_wait_ms=1.0, cache_admit_after=1))
    try:
        ref = svc.featurize(vol[None], EBS)[0]
        key = (d.digest(), svc.scfg.pcfg)
        rows = [svc.cache.get(key, float(np.float32(e))) for e in EBS]
        assert all(r is not None for r in rows), "streamed digest missed"
        assert np.array_equal(np.stack(rows), np.asarray(ref, np.float32))
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Advisor (library + servable method + CLI)
# ---------------------------------------------------------------------------


def _train_models(stack, ebs, comps=("sz3-interp", "zfp")):
    from repro.core import usecases as UC
    return {c: UC.EbGridModel.train(stack, c, ebs, ndim=2) for c in comps}


def test_advise_method_matches_direct_path():
    """The servable ``advise`` method returns the same CR table the
    direct stream path computes from the same features."""
    from repro.serve.method import AdviseMethod
    from repro.serve.sweep_service import ServiceConfig, SweepService
    stack = np.asarray(scientific.field_slices("miranda-vx", count=6, n=32))
    rng = float(stack.max() - stack.min())
    ebs = [r * rng for r in (1e-3, 1e-2)]
    models = _train_models(stack[:4], ebs)
    feats = np.asarray(P.features_sweep(stack, ebs, sharded=False))
    direct = AdviseMethod.cr_table(models, feats)
    assert direct.shape == (6, 2, 2) and np.all(direct > 0)
    svc = SweepService(ServiceConfig(max_wait_ms=1.0))
    try:
        out = svc.advise(models, stack)
    finally:
        svc.close()
    assert out["compressors"] == tuple(models)
    assert np.array_equal(out["cr"], direct)
    assert np.array_equal(out["ebs"], np.asarray(ebs, np.float64))
    # model-set validation happens at submit time
    bad = dict(models)
    bad["zfp2"] = _train_models(stack[:4], [e * 2 for e in ebs],
                                comps=("zfp",))["zfp"]
    with pytest.raises(ValueError, match="share one eb grid"):
        AdviseMethod.check_models(bad)
    with pytest.raises(ValueError, match="at least one"):
        AdviseMethod.check_models({})


def test_advise_recommendation_logic():
    """eb_for_target interpolates the monotonized curve; recommend picks
    the smallest-eb feasible compressor and flags infeasible targets."""
    from repro.launch import advise as ADV
    ebs = np.asarray([1e-4, 1e-3, 1e-2])
    crs = np.asarray([2.0, 8.0, 32.0])
    eb, cr = ADV.eb_for_target(ebs, crs, 8.0)
    assert eb == pytest.approx(1e-3) and cr == pytest.approx(8.0)
    eb, cr = ADV.eb_for_target(ebs, crs, 16.0)
    assert 1e-3 < eb < 1e-2 and cr == pytest.approx(16.0)
    assert ADV.eb_for_target(ebs, crs, 100.0) is None
    assert ADV.eb_for_target(ebs, crs, 1.0) == (1e-4, 2.0)

    var_cr = np.asarray([[2.0, 8.0, 32.0],      # comp a
                         [4.0, 16.0, 24.0]])    # comp b: better at low eb
    rec = ADV.recommend(("a", "b"), ebs, var_cr, [8.0, 30.0, 100.0])
    assert rec["8"]["compressor"] == "b" and rec["8"]["feasible"]
    assert rec["30"]["compressor"] == "a"
    assert rec["100"]["feasible"] is False and \
        rec["100"]["compressor"] == "a"
    # harmonic aggregation: equal-size rows -> total-bytes CR
    hm = ADV.harmonic_cr(np.asarray([[[2.0]], [[6.0]]]))
    assert hm[0, 0] == pytest.approx(3.0)


def test_advise_cli_end_to_end(tmp_path):
    """make_dataset CLI -> advise CLI (direct and --service) on a small
    two-variable dataset; the JSON report covers every variable/target
    and both routes agree."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "make_dataset", os.path.join(os.path.dirname(__file__), "..",
                                     "tools", "make_dataset.py"))
    mk = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mk)
    ds = mk.main([str(tmp_path / "ds"), "--var", "miranda-vx:8:32",
                  "--var", "qmcpack:6:32", "--dtype", "float64",
                  "--seed", "3"])
    from repro.launch import advise as ADV
    argv = [ds, "--compressors", "sz3-interp,zfp", "--targets", "4,8",
            "--train-rows", "4", "--budget-mb", "0.02", "--mesh", "none",
            "--out", str(tmp_path / "report.json")]
    report = ADV.main(argv)
    with open(tmp_path / "report.json") as f:
        assert json.load(f)["variables"].keys() == \
            report["variables"].keys()
    assert set(report["variables"]) == {"miranda-vx", "qmcpack"}
    for var in report["variables"].values():
        assert set(var["targets"]) == {"4", "8"}
        for rec in var["targets"].values():
            assert rec["compressor"] in ("sz3-interp", "zfp")
            assert rec["eb"] > 0 and rec["predicted_cr"] > 0
    served = ADV.main(argv[:-2] + ["--service"])
    for name in report["variables"]:
        assert served["variables"][name]["targets"] == \
            report["variables"][name]["targets"]
        assert served["variables"][name]["digest"] == \
            report["variables"][name]["digest"]


# ---------------------------------------------------------------------------
# Distributed streaming
# ---------------------------------------------------------------------------


def test_stream_sharded_mesh_bitequal(tmp_path):
    """Single-process 8-device mesh: chunk launches ride the shard_map
    path (k_pad divides the extent) and stay bit-equal to the
    single-device in-memory sweep."""
    gen = _gen(19, 32)
    path = SRC.write_dataset(str(tmp_path / "ds"), gen, fmt="memmap",
                             dtype="float64", budget_bytes=1 << 20)
    run_child(f"""
        import numpy as np
        from repro.core import predictors as P
        from repro.core import stream as ST
        from repro.data import source as SRC
        from repro.launch import mesh as M

        ds = SRC.MemmapSource({str(path)!r})
        ref = np.asarray(P.features_sweep(ds.read("miranda-vx"),
                                          {EBS!r}, sharded=False))
        mesh = M.make_sweep_mesh()
        row = 32 * 32 * 4
        for rows in (8, 5):      # extent-divisible and ragged buckets
            got = ST.stream_features(
                ds, "miranda-vx", {EBS!r}, mesh=mesh,
                stream=ST.StreamConfig(budget_bytes=rows * row))
            assert np.array_equal(got, ref), rows
        print("MESH STREAM BITEXACT", flush=True)
    """, devices=8)


def test_stream_two_process_cohort(tmp_path):
    """The process_local streaming contract: a 2-process cohort streams
    the same chunk schedule, each process reading ONLY its
    process_block rows of every chunk, and both return the full tensor
    bit-equal to the single-device in-memory sweep."""
    gen = SRC.GeneratorSource([SRC.FieldVariable("miranda-vx", 10, (32,)),
                               SRC.FieldVariable("qmcpack", 7, (32,))])
    path = SRC.write_dataset(str(tmp_path / "ds"), gen, fmt="memmap",
                             dtype="float64", budget_bytes=1 << 20)
    outs = run_procs(f"""
        import numpy as np, jax
        from repro.core import predictors as P
        from repro.core import stream as ST
        from repro.data import source as SRC
        from repro.launch import mesh as M

        assert jax.process_count() == NPROCS
        mesh = M.make_sweep_mesh()
        ds = SRC.MemmapSource({str(path)!r})
        row = 32 * 32 * 4
        for name in ("miranda-vx", "qmcpack"):
            ref = np.asarray(P.features_sweep(ds.read(name), {EBS!r},
                                              sharded=False))
            for rows in (4, 10):    # ragged chunks AND k < extent chunks
                got = ST.stream_features(
                    ds, name, {EBS!r}, mesh=mesh,
                    stream=ST.StreamConfig(budget_bytes=rows * row))
                assert got.shape == ref.shape, (got.shape, ref.shape)
                assert np.array_equal(got, ref), (name, rows)
            print(name, "PL-STREAM BITEXACT", flush=True)
        # digests need every byte; process-spanning streams refuse them
        try:
            ST.stream_features(ds, "qmcpack", {EBS!r}, mesh=mesh,
                               digest=SRC.StreamingDigest())
        except ValueError as e:
            assert "single-process" in str(e)
            print("DIGEST GUARD OK", flush=True)
    """, num_procs=2, devices=4)
    for out in outs:
        assert "miranda-vx PL-STREAM BITEXACT" in out
        assert "qmcpack PL-STREAM BITEXACT" in out
        assert "DIGEST GUARD OK" in out
