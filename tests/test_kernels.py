"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import gaussian, scientific

SHAPES_2D = [(128, 128), (256, 384), (300, 500), (96, 96)]


@pytest.fixture(scope="module")
def field():
    return scientific.field_slices("miranda-vx", count=1, n=384)[0]


# ---------------------------------------------------------------------- gram
@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_ref(shape, dtype, field):
    from repro.kernels.gram import ops, ref
    x = field[: shape[0], : shape[1]].astype(dtype)
    got = ops.gram(x, transpose=True)
    want = ref.gram_xtx(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-3)


def test_gram_xxt(field):
    from repro.kernels.gram import ops, ref
    x = field[:100, :300]
    np.testing.assert_allclose(np.asarray(ops.gram(x, transpose=False)),
                               np.asarray(ref.gram_xxt(x)), rtol=2e-5, atol=2e-3)


# ---------------------------------------------------------------------- qent
@pytest.mark.parametrize("n", [2048, 4096, 5000, 65536])
@pytest.mark.parametrize("eps", [1e-3, 1e-2])
def test_qent_matches_ref(n, eps, field):
    from repro.kernels.qent import ops, ref
    x = field.reshape(-1)[:n]
    got = float(ops.quantized_entropy(x, eps))
    want = float(ref.quantized_entropy(x, eps))
    assert abs(got - want) < 1e-4, (got, want)


def test_qent_matches_exact_entropy(field):
    """When the code range fits the bins, hashing is injective -> exact."""
    from repro.kernels.qent import ops
    x = field[:128, :128]
    eps = 5e-3 * float(jnp.max(x) - jnp.min(x))
    codes = np.floor(np.asarray(x).reshape(-1) / eps).astype(np.int64)
    _, counts = np.unique(codes, return_counts=True)
    p = counts / counts.sum()
    expect = float(-(p * np.log2(p)).sum())
    got = float(ops.quantized_entropy(x, eps))
    assert abs(got - expect) < 1e-4


# ------------------------------------------------------------------- lorenzo
@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("eps", [1e-4, 1e-2])
def test_lorenzo_matches_ref(shape, eps, field):
    from repro.kernels.lorenzo import ops, ref
    x = field[: shape[0], : shape[1]]
    got = ops.lorenzo2d(x, eps)
    want = ref.lorenzo2d(x, eps)
    assert bool(jnp.all(got == want))


def test_lorenzo_decodes_within_bound(field):
    from repro.kernels.lorenzo import ops, ref
    from repro.compressors.base import error_bound_slack
    x = field[:256, :256]
    eps = 1e-3
    codes = ops.lorenzo2d(x, eps)
    recon = ref.lorenzo_decode(codes, eps)
    assert float(jnp.max(jnp.abs(recon - x))) <= eps + error_bound_slack(x)


# ----------------------------------------------------------------- zfp_block
@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (100, 200)])
def test_zfp_block_matches_ref(shape, field):
    from repro.kernels.zfp_block import ops, ref
    x = field[: shape[0], : shape[1]]
    coef_k, exp_k = ops.zfp_forward2d(x)
    coef_r, exp_r = ref.zfp_forward2d(x)
    assert coef_k.shape == coef_r.shape
    assert bool(jnp.all(coef_k == coef_r))
    assert bool(jnp.all(exp_k == exp_r))


def test_zfp_lift_roundtrip_error_small():
    """zfp's integer lifting is lossy in the low bits *by design*; the
    round-trip error must stay within a few integer LSBs."""
    from repro.compressors.zfp import fwd_lift4, inv_lift4
    k = jax.random.PRNGKey(0)
    v = jax.random.randint(k, (512, 4, 4), -2 ** 24, 2 ** 24, dtype=jnp.int32)
    w = v
    for ax in (1, 2):
        w = fwd_lift4(w, ax)
    for ax in (2, 1):
        w = inv_lift4(w, ax)
    assert int(jnp.max(jnp.abs(w - v))) <= 16   # few LSBs of 2^24-scale ints


# -------------------------------------------------------- predictor routing
def test_predictors_use_kernels_consistent(field):
    from repro.core import predictors as P
    x = field[:256, :256]
    f0 = P.features_2d(x, 1e-3, P.PredictorConfig(use_kernels=False, qent_bins=4096))
    f1 = P.features_2d(x, 1e-3, P.PredictorConfig(use_kernels=True, qent_bins=4096))
    np.testing.assert_allclose(np.asarray(f0), np.asarray(f1), rtol=1e-4, atol=1e-4)
