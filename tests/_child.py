"""Shared child-interpreter helpers for multi-device/multi-process tests.

The main pytest process must keep the default single CPU device (jax
locks the device count at first init), so every sharded scenario runs in
a child interpreter with XLA_FLAGS set before importing jax.
``run_procs`` extends this to the multi-process fabric: N children join a
``jax.distributed`` coordinator on a free localhost port and run the SAME
body SPMD (``PID``/``NPROCS`` are injected).

Chaos-test extensions: ``kill={pid: after_s}`` SIGKILLs chosen children
on a timer, ``proc_env={pid: {...}}`` injects per-process environment
(e.g. ``REPRO_FAULT_INJECT`` specs for ``repro.dist.faultinject``),
``expect_fail={pid, ...}`` allows chosen children to exit nonzero, and
``external_coordinator=True`` hosts the ``jax.distributed`` coordination
service in its OWN child (so killing any worker -- the leader included
-- leaves the survivors' KV store up).

Port-race hardening: ``free_port()`` closes its probe socket before the
children bind, so a colliding bind is possible.  ``dist_init`` pre-probes
the port and raises a catchable error; the child preamble converts it to
exit code 47, and ``run_procs`` relaunches the whole cohort on a fresh
port (bounded by ``attempts``) instead of failing the test.
"""
import os
import socket
import subprocess
import sys
import textwrap
import threading

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_PORT_RACE_RC = 47


def _env(devices: int, extra: dict = None) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if extra:
        env.update({k: str(v) for k, v in extra.items()})
    return env


def run_child(body: str, devices: int = 8) -> str:
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=_env(devices), capture_output=True, text=True,
                          timeout=560)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def free_port() -> int:
    """A free localhost TCP port for a jax.distributed coordinator."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _preamble(pid: int, num_procs: int, addr: str,
              external_coordinator: bool) -> str:
    if external_coordinator:
        return textwrap.dedent(f"""
            import sys
            PID, NPROCS = {pid}, {num_procs}
            from repro.launch import mesh as _M
            _M.dist_init("{addr}", num_processes=NPROCS, process_id=PID,
                         external_coordinator=True, init_timeout_s=60)
        """)
    return textwrap.dedent(f"""
        import sys
        PID, NPROCS = {pid}, {num_procs}
        from repro.launch import mesh as _M
        try:
            _M.dist_init("{addr}", num_processes=NPROCS, process_id=PID,
                         init_timeout_s=60)
        except RuntimeError as _e:
            if "already in use" in str(_e):
                print(_e, file=sys.stderr)
                sys.exit({_PORT_RACE_RC})
            raise
    """)


_COORD_BODY = """
    import sys, time
    from repro.launch import mesh as _M
    try:
        _svc = _M.serve_coordinator("{addr}", {n}, block=False)
    except RuntimeError as _e:
        print("COORD_FAIL", flush=True)
        print(_e, file=sys.stderr)
        sys.exit({rc})
    print("COORD_UP", flush=True)
    while True:
        time.sleep(3600)
"""


def run_procs(body: str, num_procs: int = 2, devices: int = 4,
              timeout: int = 560, kill: dict = None, env: dict = None,
              proc_env: dict = None, expect_fail=(),
              external_coordinator: bool = False, attempts: int = 3) -> list:
    """Run ``body`` SPMD in ``num_procs`` jax.distributed child processes.

    Each child gets ``devices`` virtual CPU devices and a preamble that
    joins the coordinator (``repro.launch.mesh.dist_init`` with gloo CPU
    collectives) before the body runs; the body sees ``PID`` (process
    index) and ``NPROCS``.  Asserts every child exits 0 -- except pids
    named in ``kill`` (SIGKILLed ``kill[pid]`` seconds after spawn) or
    ``expect_fail`` (any exit status accepted) -- and returns the
    per-process stdouts in process order.  ``env`` adds common extra
    environment; ``proc_env[pid]`` adds per-process extras on top.
    ``external_coordinator=True`` hosts the coordination service in a
    dedicated extra child that no worker death can take down.
    """
    code = textwrap.dedent(body)
    expect_fail = set(expect_fail) | set(kill or ())
    last_report = "(no attempt ran)"
    for _ in range(max(1, attempts)):
        port = free_port()
        addr = f"127.0.0.1:{port}"
        coord, procs, timers = None, [], []
        try:
            if external_coordinator:
                coord = subprocess.Popen(
                    [sys.executable, "-c", textwrap.dedent(
                        _COORD_BODY.format(addr=addr, n=num_procs,
                                           rc=_PORT_RACE_RC))],
                    env=_env(devices), stdout=subprocess.PIPE,
                    stderr=subprocess.PIPE, text=True)
                if coord.stdout.readline().strip() != "COORD_UP":
                    last_report = "coordinator lost the port race"
                    continue                      # fresh port, new cohort
            for pid in range(num_procs):
                procs.append(subprocess.Popen(
                    [sys.executable, "-c",
                     _preamble(pid, num_procs, addr,
                               external_coordinator) + code],
                    env=_env(devices, {**(env or {}),
                                       **((proc_env or {}).get(pid, {}))}),
                    stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                    text=True))
            for pid, after_s in (kill or {}).items():
                t = threading.Timer(float(after_s), procs[pid].kill)
                t.daemon = True
                t.start()
                timers.append(t)
            try:
                outs = [p.communicate(timeout=timeout) for p in procs]
            except subprocess.TimeoutExpired:
                for p in procs:           # a hung collective: reap them all
                    p.kill()
                outs = [p.communicate() for p in procs]
                raise AssertionError(
                    "multi-process children timed out (hung collective?):\n"
                    + "\n".join(f"--- proc {i} ---\n{o}\n{e}"
                                for i, (o, e) in enumerate(outs)))
        finally:
            for t in timers:
                t.cancel()
            if coord is not None:
                coord.kill()
                coord.communicate()
        if any(p.returncode == _PORT_RACE_RC for p in procs):
            last_report = "\n".join(
                f"--- proc {i} (rc={p.returncode}) ---\n{o}\n{e}"
                for i, (p, (o, e)) in enumerate(zip(procs, outs)))
            continue                              # fresh port, new cohort
        report = "\n".join(
            f"--- proc {i} (rc={p.returncode}) ---\n{o}\n{e}"
            for i, (p, (o, e)) in enumerate(zip(procs, outs)))
        assert all(p.returncode == 0 or i in expect_fail
                   for i, p in enumerate(procs)), report
        return [o for o, _ in outs]
    raise AssertionError(
        f"coordinator port kept colliding across {attempts} cohort "
        f"launches:\n{last_report}")
