"""Shared child-interpreter helpers for multi-device/multi-process tests.

The main pytest process must keep the default single CPU device (jax
locks the device count at first init), so every sharded scenario runs in
a child interpreter with XLA_FLAGS set before importing jax.
``run_procs`` extends this to the multi-process fabric: N children join a
``jax.distributed`` coordinator on a free localhost port and run the SAME
body SPMD (``PID``/``NPROCS`` are injected).
"""
import os
import socket
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _env(devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def run_child(body: str, devices: int = 8) -> str:
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=_env(devices), capture_output=True, text=True,
                          timeout=560)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def free_port() -> int:
    """A free localhost TCP port for a jax.distributed coordinator."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_procs(body: str, num_procs: int = 2, devices: int = 4,
              timeout: int = 560) -> list:
    """Run ``body`` SPMD in ``num_procs`` jax.distributed child processes.

    Each child gets ``devices`` virtual CPU devices and a preamble that
    joins the coordinator (``repro.launch.mesh.dist_init`` with gloo CPU
    collectives) before the body runs; the body sees ``PID`` (process
    index) and ``NPROCS``.  Asserts every child exits 0 and returns the
    per-process stdouts in process order.
    """
    port = free_port()
    code = textwrap.dedent(body)
    procs = []
    for pid in range(num_procs):
        preamble = textwrap.dedent(f"""
            PID, NPROCS = {pid}, {num_procs}
            from repro.launch import mesh as _M
            _M.dist_init("127.0.0.1:{port}", num_processes=NPROCS,
                         process_id=PID)
        """)
        procs.append(subprocess.Popen(
            [sys.executable, "-c", preamble + code], env=_env(devices),
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
    try:
        outs = [p.communicate(timeout=timeout) for p in procs]
    except subprocess.TimeoutExpired:
        for p in procs:                   # a hung collective: reap them all
            p.kill()
        outs = [p.communicate() for p in procs]
        raise AssertionError(
            "multi-process children timed out (hung collective?):\n" +
            "\n".join(f"--- proc {i} ---\n{o}\n{e}"
                      for i, (o, e) in enumerate(outs)))
    report = "\n".join(
        f"--- proc {i} (rc={p.returncode}) ---\n{o}\n{e}"
        for i, (p, (o, e)) in enumerate(zip(procs, outs)))
    assert all(p.returncode == 0 for p in procs), report
    return [o for o, _ in outs]
