"""Shared child-interpreter helper for multi-device tests.

The main pytest process must keep the default single CPU device (jax
locks the device count at first init), so every sharded scenario runs in
a child interpreter with XLA_FLAGS set before importing jax.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout
