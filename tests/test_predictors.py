"""Unit + property tests for the paper's statistical predictors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import predictors as P
from repro.data import gaussian


def test_svd_trunc_low_rank_vs_noise():
    """Rank-1 fields need ~1 singular value; white noise needs many."""
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (64, 1))
    lowrank = u @ u.T
    noise = jax.random.normal(key, (64, 64))
    t_low = float(P.svd_trunc(lowrank))
    t_noise = float(P.svd_trunc(noise))
    assert t_low <= 2 / 64 + 1e-6
    assert t_noise > 0.5


def test_svd_trunc_matches_full_svd():
    """Gram-eigh path must agree with an explicit SVD computation."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (40, 30))
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    s = jnp.linalg.svd(xc, compute_uv=False)
    s2 = s ** 2
    cum = jnp.cumsum(s2) / jnp.sum(s2)
    needed = int(1 + jnp.sum(cum < 0.99))
    expect = needed / 30
    assert abs(float(P.svd_trunc(x)) - expect) < 1e-5


def test_correlated_field_lower_trunc():
    """Stronger spatial correlation => lower svd_trunc (paper Fig. 4)."""
    k = jax.random.PRNGKey(2)
    smooth = gaussian.grf_sample(k, 128, 32.0)
    rough = gaussian.grf_sample(k, 128, 2.0)
    assert float(P.svd_trunc(smooth)) < float(P.svd_trunc(rough))


def test_quantized_entropy_eps_monotone():
    """Larger error bound destroys more information => lower q-ent."""
    k = jax.random.PRNGKey(3)
    x = gaussian.grf_sample(k, 128, 8.0)
    ents = [float(P.quantized_entropy(x, e)) for e in (1e-4, 1e-3, 1e-2, 1e-1)]
    assert all(a >= b - 1e-6 for a, b in zip(ents, ents[1:])), ents


def test_quantized_entropy_exact_small_range():
    """Histogram path equals a direct numpy entropy when codes fit bins."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1000,)).astype(np.float32)
    eps = 0.1
    codes = np.floor(x / eps).astype(np.int64)
    _, counts = np.unique(codes, return_counts=True)
    p = counts / counts.sum()
    expect = -(p * np.log2(p)).sum()
    got = float(P.quantized_entropy(jnp.asarray(x), eps))
    assert abs(got - expect) < 1e-4


@settings(max_examples=20, deadline=None)
@given(st.floats(min_value=1e-4, max_value=1.0),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_qent_nonnegative_and_bounded(eps, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 32))
    h = float(P.quantized_entropy(x, eps))
    assert 0.0 <= h <= np.log2(32 * 32) + 1e-5


def test_hosvd_trunc_3d():
    k = jax.random.PRNGKey(4)
    smooth = jnp.broadcast_to(gaussian.grf_sample(k, 32, 16.0), (8, 32, 32))
    noise = jax.random.normal(k, (8, 32, 32))
    assert float(P.hosvd_trunc(smooth)) < float(P.hosvd_trunc(noise))


def test_features_finite_on_constant_slice():
    """Degenerate inputs (sigma=0, qent=0) must not produce inf/nan."""
    x = jnp.ones((64, 64))
    f = P.features_2d(x, 1e-3)
    assert bool(jnp.all(jnp.isfinite(f)))
