"""Multi-process sweep fabric: two jax.distributed CPU processes.

Every multi-process scenario spawns two children (4 virtual devices
each) that join a localhost coordinator via ``tests._child.run_procs``;
the children compare the collective 2x4-device sweep against the local
single-device engine bit for bit.  The mesh-construction edge cases run
in-process or in plain single-process children.
"""
import numpy as np
import pytest

from _child import run_child, run_procs


def test_two_process_sweep_bitexact_2d():
    """2-D slice stacks, divisible (k=8) and ragged (k=5) row counts:
    the 2x4-process sweep == single-device engine, bit for bit, on both
    processes (process_allgather returns the full table everywhere)."""
    outs = run_procs("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import predictors as P
        from repro.dist import sharding as S, sweep as DS
        from repro.launch import mesh as M
        from repro.data import scientific

        assert jax.process_count() == NPROCS
        assert len(jax.devices()) == 8 and jax.local_device_count() == 4
        mesh = M.make_sweep_mesh()
        s = scientific.field_slices("miranda-vx", count=8, n=96)
        rng = float(jnp.max(s) - jnp.min(s))
        ebs = [r * rng for r in (1e-4, 1e-3, 1e-2)]
        for k in (8, 5):          # 5 does not divide 8: pad on last process
            ref = np.asarray(P.features_sweep(s[:k], ebs, sharded=False))
            got = np.asarray(DS.features_sweep_sharded(s[:k], ebs, mesh=mesh))
            assert got.shape == ref.shape, (got.shape, ref.shape)
            assert np.array_equal(got, ref), \
                (k, float(np.abs(got - ref).max()))
            print("K", k, "BITEXACT", flush=True)
        # auto-routing: the engine entry point under use_mesh takes the
        # same multihost path
        with S.use_mesh(mesh):
            auto = np.asarray(P.features_sweep(s, ebs))
        assert np.array_equal(
            auto, np.asarray(P.features_sweep(s, ebs, sharded=False)))
        print("AUTO OK", flush=True)
    """)
    for out in outs:
        assert "K 8 BITEXACT" in out and "K 5 BITEXACT" in out
        assert "AUTO OK" in out


def test_two_process_sweep_bitexact_volumes():
    """Rank-4 volume stacks shard over processes exactly like slices."""
    outs = run_procs("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import predictors as P
        from repro.dist import sweep as DS
        from repro.launch import mesh as M
        from repro.data import scientific

        mesh = M.make_sweep_mesh()
        v = scientific.volume("miranda-vx", shape=(8, 8, 32, 32))
        ebs = [1e-3, 1e-2]
        for k in (8, 3):
            ref = np.asarray(P.features_sweep(v[:k], ebs, sharded=False))
            got = np.asarray(DS.features_sweep_sharded(v[:k], ebs, mesh=mesh))
            assert np.array_equal(got, ref), \
                (k, float(np.abs(got - ref).max()))
            print("VK", k, "BITEXACT", flush=True)
    """)
    for out in outs:
        assert "VK 8 BITEXACT" in out and "VK 3 BITEXACT" in out


def test_process_local_ingestion():
    """Each process feeds ONLY its process_block rows (scale-out
    ingestion); the gathered result equals the identical-global-stack
    contract and the single-device engine."""
    outs = run_procs("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import predictors as P
        from repro.dist import sweep as DS
        from repro.launch import mesh as M
        from repro.data import scientific

        mesh = M.make_sweep_mesh()
        s = np.asarray(scientific.field_slices("scale-u", count=7, n=64))
        ebs = [1e-3, 1e-2]
        lo, hi = DS.process_block(len(s), mesh)
        got = np.asarray(DS.features_sweep_sharded(
            s[lo:hi], ebs, mesh=mesh, process_local=True, global_k=len(s)))
        ref = np.asarray(P.features_sweep(jnp.asarray(s), ebs,
                                          sharded=False))
        assert np.array_equal(got, ref), float(np.abs(got - ref).max())
        # wrong row count raises with the expected block in the message
        try:
            DS.features_sweep_sharded(s[:1], ebs, mesh=mesh,
                                      process_local=True, global_k=len(s))
            assert False, "wrong-sized local block accepted"
        except ValueError as e:
            assert "process_block" in str(e)
            print("BLOCK", lo, hi, "OK", flush=True)
    """)
    for out in outs:
        assert "OK" in out


def test_training_crs_reuses_mesh_processes():
    """training_crs partitions compressor runs over the SAME mesh the
    sweep used: each process compresses only its block, the all-gathered
    table matches the full serial loop."""
    outs = run_procs("""
        import numpy as np, jax
        from repro import compressors as C
        from repro.dist import sweep as DS
        from repro.launch import mesh as M
        from repro.data import scientific

        mesh = M.make_sweep_mesh()
        s = np.asarray(scientific.field_slices("miranda-vx", count=3, n=64))
        ebs = [1e-3, 1e-2]
        comp = C.get("zfp")
        table = DS.training_crs(comp, s, ebs, mesh=mesh)
        want = np.asarray([[comp.cr(sl, e) for e in ebs] for sl in s])
        np.testing.assert_allclose(table, want, rtol=1e-12)
        print("CRS OK", flush=True)
    """)
    for out in outs:
        assert "CRS OK" in out


def test_leader_follower_sweep_service():
    """Process 0 owns the queue and serves requests; process 1 joins the
    collective launches via serve().  Results == serial dispatch; the
    shutdown broadcast releases the follower."""
    outs = run_procs("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import predictors as P, usecases as UC
        from repro.launch import mesh as M
        from repro.data import scientific
        from repro.serve.sweep_service import ServiceConfig, SweepService

        mesh = M.make_sweep_mesh()
        s = scientific.field_slices("scale-u", count=10, n=64)
        rng = float(jnp.max(s) - jnp.min(s))
        ebs = [1e-4 * rng, 1e-3 * rng, 1e-2 * rng]
        scfg = ServiceConfig(max_wait_ms=50.0)
        svc = SweepService(scfg, mesh=mesh)
        if PID == 0:
            assert svc.role == "leader", svc.role
            gm = UC.EbGridModel.train(s[:8], "zfp", ebs)
            ref_eb = UC.find_error_bound_for_cr(gm, s[9], 6.0)
            ref_f = np.asarray(P.features_sweep(s[:8], ebs, sharded=False))
            got_f = svc.featurize(s[:8], ebs)
            assert np.array_equal(got_f, ref_f), \
                float(np.abs(got_f - ref_f).max())
            got_eb = svc.find_eb(gm, s[9], 6.0)
            assert got_eb == ref_eb, (got_eb, ref_eb)
            # followers reject submissions; leaders reject foreign cfgs
            try:
                svc.submit_featurize(s[:2], ebs,
                                     P.PredictorConfig(qent_bins=128))
                assert False, "foreign cfg accepted in multi-process mode"
            except ValueError as e:
                assert "multi-process" in str(e)
            stats = svc.stats()
            assert stats["launches"] >= 2
            svc.close()
            print("LEADER OK", stats["launches"], flush=True)
        else:
            assert svc.role == "follower", svc.role
            try:
                svc.submit_featurize(s[:2], ebs)
                assert False, "follower accepted a submission"
            except RuntimeError as e:
                assert "follower" in str(e)
            svc.serve()        # joins every collective until leader close
            assert svc.launches >= 2
            print("FOLLOWER OK", svc.launches, flush=True)
    """)
    assert "LEADER OK" in outs[0]
    assert "FOLLOWER OK" in outs[1]


def test_uneven_device_shares_across_processes():
    """A mesh over a PREFIX of the global device list splits unevenly
    across processes (4 mesh devices on process 0, 2 on process 1 here);
    per-process ingestion blocks must stay proportional to the devices
    each process contributes -- and the sweep stays bit-exact, including
    ragged k, in both ingestion modes."""
    outs = run_procs("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import predictors as P
        from repro.dist import sweep as DS
        from repro.launch import mesh as M
        from repro.data import scientific

        mesh = M.make_sweep_mesh(6)       # 4 devices from p0, 2 from p1
        s = np.asarray(scientific.field_slices("miranda-vx", count=7, n=64))
        ebs = [1e-3, 1e-2]
        # k=7 -> k_pad=12, 2 rows/device: p0 ingests [0,7)~8 rows worth,
        # p1's block is all-pad
        blocks = {0: (0, 7), 1: (7, 7)}
        assert DS.process_block(7, mesh) == blocks[PID], \
            DS.process_block(7, mesh)
        ref = np.asarray(P.features_sweep(jnp.asarray(s), ebs,
                                          sharded=False))
        got = np.asarray(DS.features_sweep_sharded(s, ebs, mesh=mesh))
        assert np.array_equal(got, ref), float(np.abs(got - ref).max())
        lo, hi = DS.process_block(len(s), mesh)
        loc = np.asarray(DS.features_sweep_sharded(
            s[lo:hi], ebs, mesh=mesh, process_local=True, global_k=len(s)))
        assert np.array_equal(loc, ref), float(np.abs(loc - ref).max())
        print("UNEVEN OK", flush=True)
    """)
    for out in outs:
        assert "UNEVEN OK" in out


# ------------------------------------------------------------- mesh edges

def test_make_sweep_mesh_single_device():
    """A 1-device mesh builds fine and the sweep falls back to the local
    engine (extent 1 -> no sharding)."""
    import jax
    from repro.core import predictors as P
    from repro.dist import sweep as DS
    from repro.launch import mesh as M

    mesh = M.make_sweep_mesh(1)
    assert mesh.devices.shape == (1,)
    assert DS.active_sweep_mesh(mesh) is None       # extent 1: local path
    x = np.ones((2, 16, 16), np.float32)
    got = DS.features_sweep_sharded(x, [1e-2], mesh=mesh)
    ref = P.features_sweep(x, [1e-2], sharded=False)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_make_sweep_mesh_rejects_process_spanning_without_dist():
    """Asking for more devices than the (never-dist_init'ed) runtime has
    raises immediately with the dist_init hint -- no hang."""
    import jax
    from repro.launch import mesh as M

    n = len(jax.devices())
    with pytest.raises(ValueError, match="dist_init"):
        M.make_sweep_mesh(n + 4)
    with pytest.raises(ValueError):
        M.make_sweep_mesh(0)


def test_make_sweep_mesh_non_power_of_two():
    """A 6-device (non-power-of-two) mesh shards a ragged k=7 sweep
    correctly (pad to 12, drop)."""
    out = run_child("""
        import numpy as np, jax
        from repro.core import predictors as P
        from repro.dist import sweep as DS
        from repro.launch import mesh as M
        from repro.data import scientific

        assert len(jax.devices()) == 6
        mesh = M.make_sweep_mesh()
        assert mesh.devices.shape == (6,)
        s = scientific.field_slices("cesm-cloud", count=7, n=64)
        ref = np.asarray(P.features_sweep(s, [1e-3, 1e-2], sharded=False))
        got = np.asarray(DS.features_sweep_sharded(s, [1e-3, 1e-2],
                                                   mesh=mesh))
        assert np.array_equal(got, ref), float(np.abs(got - ref).max())
        print("NP2 OK", flush=True)
    """, devices=6)
    assert "NP2 OK" in out


def test_process_block_single_process_mesh_raises_cleanly():
    """process_local on a one-process mesh is rejected with a clear
    error (instead of wedging a half-joined collective)."""
    from repro.dist import sweep as DS

    x = np.ones((4, 16, 16), np.float32)
    with pytest.raises(ValueError, match="process-spanning"):
        DS.features_sweep_sharded(x, [1e-2], process_local=True, global_k=4)
