"""Batched + sharded 3-D/HOSVD featurization sweeps.

Covers the two reproduced bugs (``hosvd_trunc(const) > 1`` and
``volume()`` silently truncating non-square shapes), the single-
implementation scalar/batch equivalence, the rank-dispatching sweep
engine vs the looped ``features_3d`` baseline (incl. the Pallas-kernel
route), sharded-vs-single-device volume sweeps (child interpreter, 8
virtual devices), and volume requests through the coalescing
``SweepService``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _child import run_child
from repro.core import pipeline as PL, predictors as P, usecases as UC
from repro.data import scientific


@pytest.fixture(scope="module")
def vols():
    return jnp.stack([scientific.volume("qmcpack", shape=(8, 32, 48), seed=s)
                      for s in range(5)])


@pytest.fixture(scope="module")
def eb_grid(vols):
    rng = float(jnp.max(vols) - jnp.min(vols))
    # injective-binning regime: every histogram/sort path is exact here
    return [r * rng for r in (1e-3, 1e-2, 1e-1)]


# ------------------------------------------------------- bug regressions
def test_hosvd_trunc_constant_volume_in_range():
    """A zero-variance mode must yield fraction 1/p, not (1+p)/p: the
    constant volume used to return ~1.17 (> the documented (0, 1])."""
    got = float(P.hosvd_trunc(jnp.ones((8, 16, 16))))
    assert got <= 1.0, got
    # mean over modes of 1/p: (1/8 + 1/16 + 1/16) / 3
    assert abs(got - (1 / 8 + 1 / 16 + 1 / 16) / 3) < 1e-6, got
    batch = np.asarray(P.hosvd_trunc_batch(jnp.ones((2, 8, 16, 16))))
    assert (batch <= 1.0).all(), batch


@pytest.mark.parametrize("shape", [(4, 32, 64), (4, 64, 32), (6, 32, 32),
                                   (3, 16, 48)])
def test_volume_returns_requested_shape(shape):
    """volume((4, 32, 64)) used to come back silently as (4, 32, 32)."""
    v = scientific.volume("qmcpack", shape=shape)
    assert v.shape == shape, (v.shape, shape)


def test_volume_square_values_unchanged_by_fix():
    """Square requests take the exact pre-fix generation path (slabs at
    n = shape[1]), so existing fixtures keep their values."""
    a = scientific.volume("miranda-vx", shape=(4, 32, 32))
    b = scientific.volume("miranda-vx", shape=(4, 32, 48))[:, :, :32]
    assert a.shape == (4, 32, 32)
    assert not bool(jnp.all(a == b))  # wider request really generates wider


# ------------------------------------------------ scalar == batch (hosvd)
def test_hosvd_scalar_is_batch_k1_bitexact(vols):
    """Single implementation: hosvd_trunc(x) == hosvd_trunc_batch(x[None])[0]
    bit-exact, and the batch over k volumes matches the per-volume loop."""
    batch = np.asarray(P.hosvd_trunc_batch(vols))
    for i, v in enumerate(vols):
        scalar = np.asarray(P.hosvd_trunc(v))
        np.testing.assert_array_equal(
            scalar, np.asarray(P.hosvd_trunc_batch(v[None])[0]))
        np.testing.assert_allclose(batch[i], scalar, atol=1e-6)


def test_hosvd_batch_kernel_route(vols):
    jnp_route = np.asarray(P.hosvd_trunc_batch(vols))
    kernel = np.asarray(P.hosvd_trunc_batch(vols, use_kernel=True))
    np.testing.assert_allclose(kernel, jnp_route, atol=1e-5)


# ------------------------------------------------- rank-dispatching sweep
def test_features_sweep_3d_matches_looped(vols, eb_grid):
    """(k, e, 2) volume sweep == looped features_3d per (volume, eb)."""
    sweep = np.asarray(P.features_sweep(vols, jnp.asarray(eb_grid)))
    assert sweep.shape == (vols.shape[0], len(eb_grid), 2)
    for s in range(vols.shape[0]):
        for i, eps in enumerate(eb_grid):
            want = np.asarray(P.features_3d(vols[s], eps))
            np.testing.assert_allclose(sweep[s, i], want, rtol=1e-5,
                                       atol=1e-4)


def test_features_sweep_3d_kernel_route(vols, eb_grid):
    cfg_j = P.PredictorConfig(use_kernels=False, qent_bins=65536)
    cfg_k = P.PredictorConfig(use_kernels=True, qent_bins=65536)
    f_j = P.features_sweep(vols, jnp.asarray(eb_grid), cfg_j)
    f_k = P.features_sweep(vols, jnp.asarray(eb_grid), cfg_k)
    np.testing.assert_allclose(np.asarray(f_j), np.asarray(f_k),
                               rtol=1e-4, atol=1e-4)


def test_features_sweep_3d_finite_on_constant_volumes():
    f = P.features_sweep(jnp.ones((2, 4, 16, 16)), [1e-3, 1e-2])
    assert bool(jnp.all(jnp.isfinite(f)))


def test_slice_cache_on_volume(vols, eb_grid):
    """SliceCache over a (d, m, n) volume: prefetch == sweep row, and the
    HOSVD variance fraction is used (not the 2-D one)."""
    cache = P.get_engine().cached(vols[0])
    pre = np.asarray(cache.prefetch(jnp.asarray(eb_grid)))
    want = np.asarray(P.features_sweep(vols[:1], jnp.asarray(eb_grid))[0])
    np.testing.assert_array_equal(pre, want)
    one = np.asarray(cache(eb_grid[1]))
    np.testing.assert_allclose(one, want[1], atol=1e-6)


# ----------------------------------------------------- pipeline/usecases
def test_cr_predictor_3d_roundtrip(vols, eb_grid):
    """CRPredictor.train/predict ndim=3 route through the engine (no
    Python loop) and match training on precomputed looped features."""
    eps = eb_grid[1]
    crs = jnp.asarray([2.0 + 0.5 * i for i in range(vols.shape[0])])
    pred = PL.CRPredictor.train(vols, crs, eps, ndim=3)
    out = np.asarray(pred.predict(vols))
    feats = jnp.stack([P.features_3d(v, eps) for v in vols])
    want = np.asarray(PL.CRPredictor.train_from_features(
        feats, crs, eps, ndim=3).predict_from_features(feats))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        PL.CRPredictor.train(vols, crs, eps, ndim=2)
    with pytest.raises(ValueError):
        pred.predict(vols[0])


def test_ebgrid_train_3d_uc1_uc2(vols, eb_grid):
    """EbGridModel.train(ndim=3) + UC1/UC2 over the 3-D study set."""
    from repro import compressors as C
    gm = UC.EbGridModel.train(vols, "zfp", eb_grid, ndim=3)
    test = scientific.volume("qmcpack", shape=(8, 32, 48), seed=11)
    cr = gm.predict(test, float(np.sqrt(eb_grid[0] * eb_grid[1])))
    assert np.isfinite(cr) and cr > 0
    eps, pred_cr = UC.find_error_bound_for_cr(gm, test, target_cr=cr)
    assert eb_grid[0] <= eps <= eb_grid[-1]
    models = {n: PL.CRPredictor.train(
        vols, jnp.asarray([C.get(n).cr(v, eb_grid[1]) for v in vols]),
        eb_grid[1], ndim=3) for n in ("zfp", "bitgrooming")}
    best, preds = UC.best_compressor(models, test, eb_grid[1])
    assert best in models and all(np.isfinite(v) for v in preds.values())
    with pytest.raises(ValueError):
        UC.EbGridModel.train(vols, "zfp", eb_grid, ndim=2)
    # data rank must match the models' training ndim (here: 3-D)
    assert gm.ndim == 3
    with pytest.raises(ValueError):
        gm.predict(test[0], eb_grid[1])                  # 2-D to 3-D model
    with pytest.raises(ValueError):
        UC.find_error_bound_for_cr(gm, test[0], target_cr=2.0)
    with pytest.raises(ValueError):
        UC.best_compressor(models, test[0], eb_grid[1])


# ------------------------------------------------------- sharded volumes
def test_sharded_volume_sweep_matches_single_device():
    """(k, e, 2) volume sweep from an 8-device mesh == single-device
    engine, for a divisible k and a non-divisible k (pad + drop)."""
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import predictors as P
        from repro.dist import sharding as S
        from repro.launch import mesh as M
        from repro.data import scientific

        vols = jnp.stack([scientific.volume("qmcpack", shape=(8, 32, 48),
                                            seed=s) for s in range(16)])
        rng = float(jnp.max(vols) - jnp.min(vols))
        ebs = jnp.asarray([r * rng for r in (1e-3, 1e-2, 1e-1)], jnp.float32)
        mesh = M.make_sweep_mesh()
        for k in (16, 11):           # 11 does not divide 8: pad to 16
            ref = np.asarray(P.features_sweep(vols[:k], ebs, sharded=False))
            with S.use_mesh(mesh):
                got = np.asarray(P.features_sweep(vols[:k], ebs))
            assert got.shape == (k, 3, 2), got.shape
            d = float(np.abs(got - ref).max())
            assert d < 1e-5, (k, d)
            print("K", k, "MAXDIFF", d)
        # gather=False keeps the padded result sharded with masked pad
        with S.use_mesh(mesh):
            padded = P.features_sweep(vols[:11], ebs, gather=False)
        assert padded.shape == (16, 3, 2), padded.shape
        assert bool(jnp.all(padded[11:] == 0)), "pad rows not masked"
        assert len(padded.sharding.device_set) == 8, padded.sharding
        np.testing.assert_allclose(
            np.asarray(padded[:11]),
            np.asarray(P.features_sweep(vols[:11], ebs, sharded=False)),
            atol=1e-5)
        print("SHARDED VOLUME OK")
    """)
    assert "K 16" in out and "K 11" in out and "SHARDED VOLUME OK" in out


# --------------------------------------------------------- sweep service
def test_sweep_service_volume_requests_bit_equal(vols, eb_grid):
    """Volume featurize/UC1/UC2 requests through the coalescing service
    == serial dispatch, and hot volumes are served from the cache."""
    from repro.serve.sweep_service import SweepService, ServiceConfig

    gm = UC.EbGridModel.train(vols[:4], "zfp", eb_grid, ndim=3)
    test = scientific.volume("qmcpack", shape=(8, 32, 48), seed=9)
    ref_feats = np.asarray(P.features_sweep(vols, jnp.asarray(eb_grid)))
    ref_eb = UC.find_error_bound_for_cr(gm, test, target_cr=2.0)
    # first-touch admission: this test exercises volume coalescing and
    # cache reuse, not the default second-sighting admission policy
    # (which has its own transitions test in test_sweep_service.py)
    with SweepService(ServiceConfig(max_wait_ms=5.0,
                                    cache_admit_after=1)) as svc:
        # mixed ranks coalesce: one volume stack + one 2-D slice request
        f_vol = svc.submit_featurize(vols, eb_grid)
        f_2d = svc.submit_featurize(np.asarray(vols[:2, 0]), eb_grid)
        f_eb = svc.submit_find_eb(gm, test, target_cr=2.0)
        np.testing.assert_array_equal(f_vol.result(), ref_feats)
        np.testing.assert_array_equal(
            f_2d.result(),
            np.asarray(P.features_sweep(vols[:2, 0], jnp.asarray(eb_grid))))
        assert f_eb.result() == ref_eb
        launches = svc.launches
        # hot volume: repeat UC1 + UC2 are served from the cache
        assert svc.find_eb(gm, test, 2.0) == ref_eb
        models = {"zfp": gm.models[1]}
        best, preds = svc.best_compressor(models, test, eb_grid[1])
        want = UC.best_compressor(models, test, eb_grid[1])
        assert (best, preds) == want
        assert svc.launches == launches, "hot volume re-launched"
        with pytest.raises(ValueError):
            svc.submit_featurize(np.zeros((4, 4)), eb_grid)  # rank-2 stack


# --------------------------------------------------- exact-grid-eb probe
def test_ebgrid_exact_grid_probe_single_eval(monkeypatch):
    """A query eps exactly on an interior grid eb must cost ONE
    predict_fast call (searchsorted used to yield t == 1.0 and two)."""
    slices = scientific.field_slices("qmcpack", count=6, n=48)
    rng = float(jnp.max(slices) - jnp.min(slices))
    ebs = [r * rng for r in (1e-3, 1e-2, 1e-1, 3e-1)]
    gm = UC.EbGridModel.train(slices, "zfp", ebs)
    calls = []
    real = UC.predict_fast
    monkeypatch.setattr(UC, "predict_fast",
                        lambda m, f: calls.append(1) or real(m, f))
    cache = P.get_engine(gm.cfg).cached(slices[0])
    for i in (1, 2):                      # interior grid points
        calls.clear()
        cr = gm.predict(slices[0], float(gm.ebs[i]), cache)
        assert len(calls) == 1, (i, len(calls))
        assert np.isfinite(cr) and cr > 0
    calls.clear()                         # off-grid interior: still two
    gm.predict(slices[0], float(np.sqrt(gm.ebs[1] * gm.ebs[2])), cache)
    assert len(calls) == 2, len(calls)
