"""End-to-end behaviour tests for the whole system: the paper pipeline
driving framework services (checkpointing, serving, gradient sync)."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compressors as C
from repro.configs.base import get_smoke
from repro.core import pipeline as PL
from repro.ckpt import checkpoint as CKPT
from repro.data import scientific
from repro.data.tokens import make_data_iter
from repro.serve.engine import Engine, ServeConfig
from repro.train import train_step as TS, optimizer as OPT

KEY = jax.random.PRNGKey(0)


def test_uc2_driven_lossy_checkpoint():
    """Train briefly, then checkpoint with the paper's UC2 predictor
    choosing the compressor per tensor -- predicted CR recorded."""
    cfg = get_smoke("granite-3-2b")
    state = TS.init_state(cfg, KEY)
    step = jax.jit(TS.make_train_step(cfg, OPT.AdamWConfig(lr=1e-3)))
    it = make_data_iter(cfg, batch=4, seq=32)
    for i in range(5):
        state, _ = step(state, it(i))

    # train tiny per-compressor CR predictors on generic field slices
    slices = scientific.field_slices("miranda-vx", count=12, n=96)
    rng = float(jnp.max(slices) - jnp.min(slices))
    eps = 1e-4 * rng
    predictors = {}
    for name in ("sz3-lorenzo", "zfp"):
        comp = C.get(name)
        crs = jnp.asarray([comp.cr(s, eps) for s in slices])
        predictors[name] = PL.CRPredictor.train(slices, crs, eps)

    d = tempfile.mkdtemp()
    try:
        pol = CKPT.LossyPolicy(enabled=True, rel_eb=1e-4, min_size=4096,
                               predictors=predictors)
        man = CKPT.save(d, 0, state.params, pol)
        lossy = {k: t for k, t in man["tensors"].items()
                 if t["codec"] != "raw"}
        assert lossy
        for k, t in lossy.items():
            assert t["predicted_cr"] is not None
            assert t["codec"] in predictors
        restored = CKPT.load(d, 0, state.params)
        # restored params still train
        state2 = TS.TrainState(restored, state.opt, None)
        state2, m = step(state2, it(6))
        assert bool(jnp.isfinite(m["loss"]))
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_serving_engine_generates():
    cfg = get_smoke("granite-3-2b")
    params = TS.init_state(cfg, KEY).params
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size,
                                          dtype=jnp.int32)}
    out = eng.generate(batch, steps=8)
    assert out.shape == (2, 8)
    assert bool(jnp.all((out >= 0) & (out < cfg.padded_vocab)))


def test_kv_compression_engine_close_to_exact():
    cfg = get_smoke("granite-3-2b")
    params = TS.init_state(cfg, KEY).params
    batch = {"tokens": jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size,
                                          dtype=jnp.int32)}
    plain = Engine(cfg, params, ServeConfig(max_len=64))
    comp = Engine(cfg, params, ServeConfig(max_len=64, kv_compress=True,
                                           kv_gate_ratio=0.0))
    o1 = plain.generate(batch, steps=6)
    o2 = comp.generate(batch, steps=6)
    # int8 KV: most greedy tokens unchanged on a random model
    agree = float(jnp.mean((o1 == o2).astype(jnp.float32)))
    assert agree >= 0.5, agree
    assert comp.kv_total_bytes > 0


def test_paper_pipeline_feeds_gradient_gate():
    """q-ent-based predicted CR orders gradient buckets the same way the
    real zstd-backed coder does (rank agreement on a small set)."""
    from repro.train.grad_compress import predicted_cr_int8
    zstandard = pytest.importorskip("zstandard")
    fields = ["miranda-vx", "nyx-vx", "scale-u"]
    pred, real = [], []
    for f in fields:
        x = scientific.field_slices(f, count=1, n=96)[0]
        g = x / jnp.max(jnp.abs(x))
        pred.append(float(predicted_cr_int8(g)))
        codes = np.round(np.asarray(g) * 127).astype(np.int8)
        real.append(g.size / len(zstandard.ZstdCompressor().compress(
            codes.tobytes())))
    assert np.argsort(pred).tolist() == np.argsort(real).tolist(), (pred, real)


def test_engine_default_scfg_not_shared():
    """Engines built without an explicit ServeConfig must not share one
    mutable default instance."""
    cfg = get_smoke("granite-3-2b")
    params = TS.init_state(cfg, KEY).params
    e1, e2 = Engine(cfg, params), Engine(cfg, params)
    assert e1.scfg is not e2.scfg
    e1.scfg.kv_compress = True
    assert not e2.scfg.kv_compress


def test_kv_gate_batched_matches_per_leaf_reference():
    """The single-sync batched KV gate computes the same tree as the old
    one-host-sync-per-leaf implementation."""
    from repro.train.grad_compress import (predicted_cr_int8, quantize_int8,
                                           dequantize_int8)
    cfg = get_smoke("granite-3-2b")
    params = TS.init_state(cfg, KEY).params
    eng = Engine(cfg, params, ServeConfig(kv_compress=True,
                                          kv_gate_ratio=2.0))
    smooth = (jnp.ones((1, 2, 8, 256), jnp.float32) *
              jnp.linspace(0.0, 1.0, 256))
    noisy = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 8, 256),
                              jnp.float32)
    cache = {"k": smooth, "v": noisy, "pos": jnp.zeros((3,), jnp.int32)}

    out = eng._maybe_compress_cache(cache)

    def ref_leaf(x):
        if x.dtype not in (jnp.bfloat16, jnp.float32) or x.ndim < 4:
            return x
        cr = float(predicted_cr_int8(x.astype(jnp.float32)))
        if cr >= 2.0:
            codes, scales = quantize_int8(x.astype(jnp.float32))
            return dequantize_int8(codes, scales, x.shape, x.dtype)
        return x

    ref = jax.tree.map(ref_leaf, cache)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert eng.kv_total_bytes == smooth.size * 4 + noisy.size * 4
