"""Regression-model tests: JAX solvers vs scipy references + recovery of
known ground-truth relationships."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import regression as R


def _synthetic(n=200, seed=0, noise=0.02):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(n, 2))
    # log(CR) = 1.0 + 0.5 z1 - 0.8 z2 + 0.3 z1 z2
    y = 1.0 + 0.5 * f[:, 0] - 0.8 * f[:, 1] + 0.3 * f[:, 0] * f[:, 1]
    y = y + noise * rng.normal(size=n)
    return jnp.asarray(f), jnp.asarray(np.exp(y))


def test_linear_recovers_coefficients():
    f, cr = _synthetic()
    m = R.LinearCRModel.fit(f, cr)
    # predictors are standardized; on standard-normal features the
    # coefficients should be recovered nearly exactly
    pred = m.predict(f)
    rel = np.abs(np.log(np.asarray(pred)) - np.log(np.asarray(cr)))
    assert float(np.median(rel)) < 0.05


def test_linear_matches_lstsq():
    f, cr = _synthetic(noise=0.1, seed=1)
    m = R.LinearCRModel.fit(f, cr, ridge=0.0)
    z = np.asarray(m.std(f))
    X = np.column_stack([np.ones(len(z)), z, z[:, 0] * z[:, 1]])
    ref, *_ = np.linalg.lstsq(X, np.log(np.asarray(cr)), rcond=None)
    np.testing.assert_allclose(np.asarray(m.coef), ref, rtol=1e-4, atol=1e-5)


def test_spline_fits_nonlinear():
    rng = np.random.default_rng(2)
    f = rng.normal(size=(300, 2))
    y = np.sin(f[:, 0]) + 0.2 * f[:, 1] ** 2
    cr = jnp.asarray(np.exp(y))
    lin = R.LinearCRModel.fit(jnp.asarray(f), cr)
    spl = R.SplineCRModel.fit(jnp.asarray(f), cr)
    err_lin = float(np.mean((np.log(np.asarray(lin.predict(jnp.asarray(f)))) - y) ** 2))
    err_spl = float(np.mean((np.log(np.asarray(spl.predict(jnp.asarray(f)))) - y) ** 2))
    assert err_spl < err_lin * 0.7, (err_spl, err_lin)


def test_ncs_basis_properties():
    """Natural cubic spline basis: linear beyond boundary knots."""
    knots = jnp.asarray([-1.0, 0.0, 1.0])
    x = jnp.asarray([-5.0, -4.0, 4.0, 5.0])
    b = R.ncs_basis(x, knots)
    # second differences of each basis function vanish outside the knots
    left = b[1] - b[0]
    right = b[3] - b[2]
    # linearity: f(-4) - f(-5) == f'(x) * 1 constant slope on each side
    b_mid = R.ncs_basis(jnp.asarray([-4.5, 4.5]), knots)
    np.testing.assert_allclose(np.asarray(b[0] + left * 0.5), np.asarray(b_mid[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(b[2] + right * 0.5), np.asarray(b_mid[1]),
                               rtol=1e-5, atol=1e-5)


def test_lasso_selects_true_predictors():
    rng = np.random.default_rng(3)
    f = rng.normal(size=(150, 2))
    y = 2.0 + 1.0 * f[:, 0] + 0.0 * f[:, 1]          # z2 irrelevant
    cr = jnp.asarray(np.exp(y + 0.01 * rng.normal(size=150)))
    imp = np.asarray(R.lasso_importance(jnp.asarray(f), cr, k=5))
    assert imp[0] > 5 * max(imp[1], 1e-6), imp       # q-ent analog dominates


def test_lasso_fista_matches_ridgeless_ls_at_zero_lambda():
    f, cr = _synthetic(seed=4)
    std = R.Standardizer.fit(f)
    X = np.asarray(R._linear_design(std(f)))
    y = np.log(np.asarray(cr))
    yz = (y - y.mean()) / y.std()
    b = np.asarray(R.lasso_fit(jnp.asarray(X), jnp.asarray(yz),
                               jnp.asarray(0.0), num_iters=4000))
    ref, *_ = np.linalg.lstsq(X, yz, rcond=None)
    np.testing.assert_allclose(b, ref, atol=2e-3)
