"""Per-architecture smoke tests + cache/decode consistency.

Every assigned arch instantiates its reduced config, runs one forward/train
step on CPU, asserts output shapes and finiteness, and checks that the
decode path (KV cache / SSM state / latent cache) reproduces the full
forward to fp32 tolerance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_arch, get_smoke
from repro.models import model as M
from repro.models import causal_lm as CLM

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.encoder_frames, cfg.d_model)).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (3, b, s))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss = M.loss_fn(params, batch, cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert 1.0 < float(loss) < 20.0, (arch, float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    from repro.train import train_step as TS
    cfg = get_smoke(arch)
    state = TS.init_state(cfg, KEY)
    step = jax.jit(TS.make_train_step(cfg, microbatches=2))
    state2, metrics = step(state, _batch(cfg, b=4))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not bool(jnp.all(d0 == d1)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """KV-cache/state decode must equal the dense causal forward (f32)."""
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32",
                              capacity_factor=64.0)  # no MoE drops
    params = jax.tree.map(lambda a: a.astype(jnp.float32),
                          M.init_params(cfg, KEY))
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)
    mp3 = (jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))
           if cfg.family == "vlm" else None)
    if cfg.family == "encdec":
        from repro.models import whisper as WSP
        frames = jax.random.normal(KEY, (b, cfg.encoder_frames, cfg.d_model),
                                   jnp.float32)
        memory = WSP.encode(params, frames, cfg)
        hidden, _ = WSP.decode(params, toks, memory, cfg)
        full_logits = CLM.logits_fn(params, hidden)
        lp, cache = M.prefill(params, {"tokens": toks[:, : s - 1],
                                       "frames": frames}, cfg, max_len=s + 4)
    else:
        hidden = CLM.forward(params, toks, cfg, remat=False,
                             mrope_positions=mp3)
        full_logits = CLM.logits_fn(params, hidden)
        pre = {"tokens": toks[:, : s - 1]}
        if cfg.family == "vlm":
            pre["mrope_positions"] = mp3[:, :, : s - 1]
        lp, cache = M.prefill(params, pre, cfg, max_len=s + 4)
    mp1 = (jnp.full((3, b, 1), s - 1, jnp.int32)
           if cfg.family == "vlm" else None)
    lg, _ = M.decode_step(params, cache, toks[:, s - 1: s], jnp.int32(s - 1),
                          cfg, mrope_positions=mp1)
    err = float(jnp.max(jnp.abs(lg - full_logits[:, s - 1])))
    assert err < 1e-4, (arch, err)


def test_ssd_chunked_equals_sequential():
    from repro.models import ssm as SSM
    key = jax.random.PRNGKey(7)
    B, S, H, P, G, N = 1, 64, 4, 8, 2, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    b_in = jax.random.normal(ks[1], (B, S, G, N)) * 0.3
    c_in = jax.random.normal(ks[2], (B, S, G, N)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
    d = jnp.ones((H,)) * 0.5
    y_c, fin_c = SSM.ssd_forward(x, b_in, c_in, dt, a, d, chunk=16)
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y, state = SSM.ssm_step(x[:, t], b_in[:, t], c_in[:, t], dt[:, t],
                                a, d, state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(jnp.stack(ys, 1)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin_c), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


def test_full_configs_match_assignment():
    """The exact published dims from the assignment table."""
    spec = {
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "mamba2-370m": (48, 1024, 32, 32, 0, 50280),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_arch(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), arch


def test_param_counts_plausible():
    """Full-config parameter counts near the published sizes."""
    expect = {
        "granite-8b": (7e9, 9.5e9),
        "deepseek-v2-236b": (2.1e11, 2.6e11),
        "qwen2-vl-72b": (6.5e10, 8.2e10),
        "mamba2-370m": (3.0e8, 4.6e8),
        "phi3.5-moe-42b-a6.6b": (3.8e10, 4.5e10),
        "hymba-1.5b": (1.1e9, 2.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = M.count_params(get_arch(arch))
        assert lo <= n <= hi, (arch, n)


def test_moe_capacity_dropping_bounded():
    """With cf=1.0 some tokens drop but the output stays finite and close."""
    cfg = dataclasses.replace(get_smoke("phi3.5-moe-42b-a6.6b"),
                              dtype="float32", capacity_factor=1.0)
    params = jax.tree.map(lambda a: a.astype(jnp.float32),
                          M.init_params(cfg, KEY))
    batch = _batch(cfg)
    loss = M.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))


def test_hymba_window_masks_long_context():
    """SWA layers must not attend beyond the window."""
    cfg = dataclasses.replace(get_smoke("hymba-1.5b"), dtype="float32")
    params = jax.tree.map(lambda a: a.astype(jnp.float32),
                          M.init_params(cfg, KEY))
    b, s = 1, 64
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)
    h1 = CLM.forward(params, toks, cfg, remat=False)
    # perturbing a token beyond every window+global reach of the last token
    # changes logits only through global layers; sanity: forward is causal
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    h2 = CLM.forward(params, toks2, cfg, remat=False)
    assert bool(jnp.all(jnp.isclose(h1[:, : s - 1], h2[:, : s - 1],
                                    atol=1e-5)))
