"""Compressor-suite tests: error bounds (the contract), CR sanity, and
scheme behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import compressors as C
from repro.compressors.base import error_bound_slack
from repro.compressors.sz import SZ2, quantize_bounded
from repro.data import gaussian, scientific


FIELDS = ["miranda-vx", "scale-u", "hurricane-u", "cesm-cloud"]


@pytest.fixture(scope="module")
def slices():
    return {f: scientific.field_slices(f, count=1, n=96)[0] for f in FIELDS}


@pytest.mark.parametrize("name", C.STUDY_2D)
@pytest.mark.parametrize("field", FIELDS)
def test_error_bound_held(name, field, slices):
    x = slices[field]
    rng = float(jnp.max(x) - jnp.min(x))
    for eps_rel in (1e-2, 1e-4):
        eps = eps_rel * rng
        err = C.get(name).roundtrip_error(x, eps)
        assert err <= eps + error_bound_slack(x), (name, field, eps_rel, err / eps)


@pytest.mark.parametrize("name", C.STUDY_2D)
def test_cr_monotone_in_eps(name, slices):
    """Looser bounds must compress at least as well (within coder noise)."""
    x = slices["miranda-vx"]
    rng = float(jnp.max(x) - jnp.min(x))
    crs = [C.get(name).cr(x, e * rng) for e in (1e-4, 1e-3, 1e-2)]
    assert crs[0] <= crs[1] * 1.05 and crs[1] <= crs[2] * 1.05, crs


def test_smooth_field_compresses_better():
    k = jax.random.PRNGKey(0)
    smooth = gaussian.grf_sample(k, 128, 32.0)
    rough = gaussian.grf_sample(k, 128, 2.0)
    for name in ("sz2", "zfp", "mgard"):
        c = C.get(name)
        assert c.cr(smooth, 1e-3) > c.cr(rough, 1e-3), name


def test_quantize_bounded_property():
    k = jax.random.PRNGKey(1)
    vals = jax.random.normal(k, (4096,)) * 100.0
    for eps in (1e-3, 1e-1, 3.0):
        q = quantize_bounded(vals, eps)
        recon = q.astype(jnp.float32) * (2.0 * eps)
        slack = float(jnp.max(jnp.abs(vals))) * 2.0 ** -23
        assert float(jnp.max(jnp.abs(vals - recon))) <= eps + slack


def test_sz2_dynamic_selection():
    """Planar data routes blocks to regression; locally-correlated but
    non-planar data routes to Lorenzo (on white noise the plane fit
    legitimately wins -- residual sigma vs Lorenzo's 2 sigma)."""
    sz2 = C.get("sz2")
    ii = jnp.arange(96, dtype=jnp.float32)
    planar = ii[:, None] * 0.7 + ii[None, :] * 0.3
    planar = planar + 0.001 * jax.random.normal(jax.random.PRNGKey(2), planar.shape)
    frac_planar = sz2.regression_fraction(planar, 1e-3)
    wavy = gaussian.grf_sample(jax.random.PRNGKey(3), 96, 4.0)
    frac_wavy = sz2.regression_fraction(wavy, 1e-3)
    assert frac_planar > 0.9, frac_planar
    assert frac_wavy < 0.5, frac_wavy


def test_tthresh_rmse_bound():
    vol = scientific.volume("qmcpack", shape=(24, 48, 48))
    t = C.get("tthresh")
    rng = float(jnp.max(vol) - jnp.min(vol))
    eps = 1e-2 * rng
    rmse = t.roundtrip_error(vol, eps)  # TTHRESH's contract is RMSE
    assert rmse <= eps * 1.05, (rmse, eps)
    assert t.cr(vol, eps) > 1.5


def test_lorenzo_3d_roundtrip():
    vol = scientific.volume("miranda-vx", shape=(16, 32, 32))
    c = C.get("sz3-lorenzo")
    eps = 1e-3 * float(jnp.max(vol) - jnp.min(vol))
    assert c.roundtrip_error(vol, eps) <= eps + error_bound_slack(vol)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from([1e-3, 1e-2]))
def test_zfp_bound_property(seed, eps_rel):
    x = gaussian.grf_sample(jax.random.PRNGKey(seed), 64, 8.0)
    rng = float(jnp.max(x) - jnp.min(x))
    eps = eps_rel * rng
    err = C.get("zfp").roundtrip_error(x, eps)
    assert err <= eps + error_bound_slack(x)


def test_size_accounting_positive():
    x = scientific.field_slices("nyx-vx", count=1, n=64)[0]
    for name in C.STUDY_2D:
        c = C.get(name)
        codes, aux = c.encode(x, 1e-3)
        assert c.size_bytes(codes, aux, 1e-3) > 0
