"""hypothesis, or a deterministic stand-in when it isn't installed.

The fallback turns ``@given(s1, s2, ...)`` into an eager sweep over a
small fixed sample set per strategy — far weaker than real property
testing, but it keeps the suite collecting and the properties exercised
in minimal environments (CI images without hypothesis).

Fallback sampling is DETERMINISTIC: every strategy derives its samples
from a seeded PRNG keyed by the strategy's own parameters, so two runs
(or two machines) sweep identical points.  Each strategy mixes

* the range boundaries (``min``/``max`` — property bugs love edges),
* boundary specials that fit the range (0.0, an f32 subnormal, the
  f32 maximum — the values library code mishandles first), and
* a few seeded random interior points,

capped at six samples per axis so a three-strategy ``@given`` stays
under ~216 cases.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import itertools
    import random

    HAVE_HYPOTHESIS = False

    # boundary specials every float range is probed with (when in range):
    # zero, an f32 subnormal (denormal handling), the f32 max (overflow
    # and inf-adjacent rounding)
    _SPECIALS = (0.0, 1e-40, 3.4028235e38)
    _MAX_SAMPLES = 6

    def _rng(*key) -> random.Random:
        # seeded by the strategy's own parameters: deterministic across
        # runs and machines, but distinct per strategy signature
        return random.Random("repro-hyp:" + repr(key))

    class _Samples:
        def __init__(self, samples):
            self.samples = list(samples)[:_MAX_SAMPLES]

    class _St:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            out = [min_value, max_value]
            out += [s for s in _SPECIALS
                    if min_value < s < max_value and s not in out]
            r = _rng("floats", min_value, max_value)
            while len(out) < _MAX_SAMPLES:
                v = min_value + (max_value - min_value) * r.random()
                if v not in out:
                    out.append(v)
            return _Samples(out)

        @staticmethod
        def integers(min_value, max_value, **_kw):
            out = [min_value, max_value] if max_value > min_value \
                else [min_value]
            r = _rng("integers", min_value, max_value)
            span = max_value - min_value
            for _ in range(4 * _MAX_SAMPLES):
                if len(out) >= min(_MAX_SAMPLES, span + 1):
                    break
                v = min_value + r.randrange(span + 1)
                if v not in out:
                    out.append(v)
            return _Samples(out)

        @staticmethod
        def sampled_from(seq):
            return _Samples(seq)

    st = _St()

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(*strategies):
        # no functools.wraps: pytest must see the zero-arg signature, not
        # the wrapped one (it would demand fixtures for the sample args)
        def deco(fn):
            def wrapper():
                for combo in itertools.product(
                        *[s.samples for s in strategies]):
                    fn(*combo)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
