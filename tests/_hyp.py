"""hypothesis, or a deterministic stand-in when it isn't installed.

The fallback turns ``@given(s1, s2, ...)`` into an eager sweep over a
small fixed sample grid per strategy — far weaker than real property
testing, but it keeps the suite collecting and the properties exercised
in minimal environments (CI images without hypothesis).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import itertools

    HAVE_HYPOTHESIS = False

    class _Samples:
        def __init__(self, samples):
            self.samples = list(samples)

    class _St:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            mid = (min_value + max_value) / 2.0
            return _Samples([min_value, mid, max_value])

        @staticmethod
        def integers(min_value, max_value, **_kw):
            return _Samples([min_value, (min_value + max_value) // 2, max_value])

        @staticmethod
        def sampled_from(seq):
            return _Samples(seq)

    st = _St()

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(*strategies):
        # no functools.wraps: pytest must see the zero-arg signature, not
        # the wrapped one (it would demand fixtures for the sample args)
        def deco(fn):
            def wrapper():
                for combo in itertools.product(
                        *[s.samples for s in strategies]):
                    fn(*combo)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
