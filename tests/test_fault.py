"""Fault tolerance: dist.fault primitives, the fault-injection harness,
admission control, and multi-process chaos scenarios.

The chaos tests run real ``jax.distributed`` cohorts via
``_child.run_procs`` and break them deliberately -- SIGKILL mid-launch
via ``repro.dist.faultinject`` (armed through ``REPRO_FAULT_INJECT`` in
``proc_env``), wedged peers, leader death under an external
coordinator, double faults -- and assert the service's contract: every
outstanding future completes bit-equal to the single-device engine (or
fails with a typed ``FabricError``), recovery is bounded by
``launch_timeout_s``, survivors shut down cleanly with exit code 0.
Chaos children end with ``os._exit(0)`` on purpose: a cohort with a
killed peer must skip the atexit ``jax.distributed.shutdown`` barrier,
which would otherwise QFATAL against the dead process.
"""
import textwrap
import threading
import time

import numpy as np
import pytest

from _child import run_child, run_procs

from repro.dist import fault as F
from repro.dist import faultinject as FI


# ---------------------------------------------------------------------------
# FabricError / fault-injection harness (pure in-process units)
# ---------------------------------------------------------------------------

def test_fabric_error_carries_typed_fields():
    e = F.FabricError("boom", kind="follower_lost", lost=(2, 1),
                      retriable=True)
    assert e.kind == "follower_lost" and e.lost == (2, 1) and e.retriable
    assert "lost processes=[2, 1]" in str(e) and "retriable" in str(e)
    e2 = F.FabricError("gone", kind="leader_lost")
    assert not e2.retriable and e2.lost == ()
    assert "restart" in str(e2)
    assert isinstance(e2, RuntimeError)


def test_faultinject_parse():
    spec = FI.parse("a:kill:1,b:hang:2,c:slow:3:0.25")
    assert spec["a"] == [("kill", 1, 1.0)]
    assert spec["b"] == [("hang", 2, 3600.0)]     # hang defaults to 1 h
    assert spec["c"] == [("slow", 3, 0.25)]
    assert FI.parse("a:kill:1,a:exit:4")["a"] == \
        [("kill", 1, 1.0), ("exit", 4, 1.0)]
    assert FI.parse("") == {}
    for bad in ("a:frob:1", "a:kill", "a:kill:0", "a:kill:x", "a:slow:1:zz"):
        with pytest.raises(ValueError):
            FI.parse(bad)


def test_faultinject_fire_counts_and_disarm():
    FI.configure("s:slow:2:0.2")
    try:
        t0 = time.perf_counter()
        FI.fire("s")                       # nth=1: counted, no fault
        assert time.perf_counter() - t0 < 0.1
        t0 = time.perf_counter()
        FI.fire("s")                       # nth=2: sleeps 0.2 s
        assert time.perf_counter() - t0 >= 0.2
        FI.fire("other")                   # unarmed site: not even counted
        assert FI.counts() == {"s": 2}
    finally:
        FI.configure(None)
    FI.fire("s")                           # disarmed: no-op, no counting
    assert FI.counts() == {}


# ---------------------------------------------------------------------------
# Re-meshing primitives
# ---------------------------------------------------------------------------

def test_shrink_mesh_rejects_bad_axis_and_size():
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="not 'model'"):
        F.shrink_mesh(mesh, "model", 1)
    for bad in (0, 2):
        with pytest.raises(ValueError, match="outside"):
            F.shrink_mesh(mesh, "data", bad)
    assert tuple(F.shrink_mesh(mesh, "data", 1).axis_names) == ("data",)


def test_surviving_submesh_rejects_nd_and_empty():
    import jax
    with pytest.raises(ValueError, match="1-D"):
        F.surviving_submesh(jax.make_mesh((1, 1), ("a", "b")), [0])
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="no devices left"):
        F.surviving_submesh(mesh, [99])
    sub = F.surviving_submesh(mesh, [0])
    assert [d.id for d in sub.devices.flat] == \
        [d.id for d in mesh.devices.flat]


def test_remesh_state_preserves_values_across_shrink():
    """Shrinking a mesh axis and re-placing a sharded state tree keeps
    every leaf bit-identical (4 virtual devices, single process)."""
    run_child("""
        import numpy as np, jax
        from repro.dist import fault as F
        from repro.dist import sharding as S

        m4 = jax.make_mesh((4,), ("data",))
        m22 = jax.make_mesh((2, 2), ("data", "model"))
        tree = {"w": np.arange(32, dtype=np.float32).reshape(8, 4),
                "b": np.arange(4, dtype=np.float32)}
        axes = {"w": ("batch", None), "b": (None,)}
        with S.use_mesh(m4, {"batch": "data"}):
            sharded = F.remesh_state(tree, axes, m4)
        assert len(sharded["w"].sharding.device_set) == 4
        small = F.shrink_mesh(m4, "data", 2)
        with S.use_mesh(small, {"batch": "data"}):
            moved = F.remesh_state(sharded, axes, small)
        assert len(moved["w"].sharding.device_set) == 2
        for k in tree:
            assert np.array_equal(np.asarray(moved[k]), tree[k]), k
        s2 = F.shrink_mesh(m22, "model", 1)
        assert s2.devices.shape == (2, 1)
        assert [d.id for d in s2.devices.flat] == \
            [d.id for d in m22.devices[:, :1].flat]
        print("OK", flush=True)
    """, devices=4)


def test_surviving_submesh_keeps_process_blocks_contiguous():
    """On a 2-process mesh the survivor submesh of each side is that
    side's contiguous device block, in original order."""
    outs = run_procs("""
        import os, sys
        import numpy as np, jax
        from repro.dist import fault as F
        from repro.launch import mesh as M

        mesh = M.make_sweep_mesh()
        for alive in ([0], [1], [0, 1]):
            sub = F.surviving_submesh(mesh, alive)
            want = [d.id for d in mesh.devices.flat
                    if d.process_index in set(alive)]
            assert [d.id for d in sub.devices.flat] == want, (alive, want)
            assert tuple(sub.axis_names) == tuple(mesh.axis_names)
        print("SUBMESH OK", flush=True)
        sys.stdout.flush(); os._exit(0)
    """, num_procs=2, devices=2, timeout=240)
    for out in outs:
        assert "SUBMESH OK" in out


# ---------------------------------------------------------------------------
# Admission control + fabric-error scoping (single-process service)
# ---------------------------------------------------------------------------

def _tiny_stack(k, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((k, 16, 16)).astype(np.float32)


def test_retry_after_backpressure():
    from repro.core import predictors as PRED
    from repro.serve.sweep_service import (RetryAfter, ServiceConfig,
                                           SweepService)
    eps = np.asarray([1e-2, 1e-1], np.float32)
    # long max_wait_ms parks the first request in the queue so the
    # second submission sees a full queue deterministically
    svc = SweepService(ServiceConfig(max_wait_ms=10_000.0,
                                     max_batch_slices=64, max_queue_rows=4))
    try:
        stack = _tiny_stack(3)
        fut = svc.submit_featurize(stack, eps)
        with pytest.raises(RetryAfter) as exc:
            svc.submit_featurize(_tiny_stack(2, seed=1), eps)
        assert exc.value.pending_rows == 3
        assert exc.value.retry_after_s >= 10.0   # >= the queue drain bound
        assert "retry after" in str(exc.value)
        assert svc.stats()["rejected"] == 1
    finally:
        svc.close()                              # drains the parked request
    got = fut.result(60)
    assert np.array_equal(
        got, np.asarray(PRED.features_sweep(stack, eps, sharded=False)))

    # a single over-wide request into an EMPTY queue is never rejected:
    # it must remain servable (it flushes alone)
    svc = SweepService(ServiceConfig(max_wait_ms=1.0, max_queue_rows=2))
    try:
        wide = svc.submit_featurize(_tiny_stack(5), eps).result(60)
        assert wide.shape == (5, 2, 2)
        assert svc.stats()["rejected"] == 0
    finally:
        svc.close()


def test_fabric_error_fails_everything_and_close_is_idempotent():
    """A non-retriable FabricError from the launch path is fabric-scoped:
    the in-flight batch AND queued requests all fail with it, later
    submits are refused, serve() raises it, close() stays idempotent."""
    from repro.serve.sweep_service import ServiceConfig, SweepService
    eps = np.asarray([1e-2], np.float32)
    svc = SweepService(ServiceConfig(max_wait_ms=1.0))
    release = threading.Event()

    def poisoned(*a, **kw):
        release.wait(30)
        raise F.FabricError("injected fabric fault", kind="failed")

    svc._collective_sweep = poisoned
    f1 = svc.submit_featurize(_tiny_stack(2), eps)
    time.sleep(0.2)                    # worker is now blocked in poisoned()
    f2 = svc.submit_featurize(_tiny_stack(1), eps)
    release.set()
    for fut in (f1, f2):
        with pytest.raises(F.FabricError, match="injected fabric fault"):
            fut.result(30)
    with pytest.raises(F.FabricError):
        svc.serve()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit_featurize(_tiny_stack(1), eps)
    assert svc._fabric_error is not None
    svc.close()
    svc.close()                        # idempotent after a fabric failure


# ---------------------------------------------------------------------------
# Chaos scenarios (multi-process cohorts + fault injection)
# ---------------------------------------------------------------------------

_CHAOS_PRELUDE = """
    import dataclasses, os, sys, time
    import numpy as np
    from repro.serve.sweep_service import ServiceConfig, SweepService
    from repro.core import predictors as PRED

    mesh = _M.make_sweep_mesh()
    # launch_timeout_s must cover a FIRST launch's executable compile
    # under full-cohort CPU contention (tens of seconds on a loaded CI
    # box) -- a too-small deadline spuriously evicts healthy followers
    scfg = ServiceConfig(launch_timeout_s=%s, heartbeat_s=0.25,
                         max_wait_ms=20.0)
    svc = SweepService(scfg, mesh=mesh)
    rng = np.random.default_rng(0)
    stack = rng.standard_normal((4, 32, 32)).astype(np.float32)
    eps = np.asarray([1e-3, 1e-2, 1e-1], np.float32)

    def ref(x):
        return np.asarray(PRED.features_sweep(x, eps, sharded=False))
"""


def _chaos_body(tail: str, launch_timeout_s: float = 45) -> str:
    # dedent each fragment here: the prelude and the tails carry
    # different source indentation, and run_procs dedents only once
    return (textwrap.dedent(_CHAOS_PRELUDE % launch_timeout_s)
            + textwrap.dedent(tail))


def test_follower_loss_recovery_3proc():
    """The headline scenario: a 3-process fabric loses one follower
    mid-launch (SIGKILL inside its collective join).  The leader detects
    it within the launch deadline, shrinks to the 2 survivors, relaunches
    on the KV transport, and every outstanding future -- including the
    in-flight one -- completes bit-equal to the single-device engine.
    The surviving follower re-joins and later shuts down cleanly."""
    outs = run_procs(_chaos_body("""
        if PID == 0:
            r1 = svc.submit_featurize(stack, eps).result(60)
            assert np.array_equal(r1, ref(stack))
            t0 = time.monotonic()
            futs = [svc.submit_featurize(stack[i*2:(i+1)*2] + i, eps)
                    for i in range(2)]
            rs = [f.result(180) for f in futs]
            dt = time.monotonic() - t0
            for i, r in enumerate(rs):
                assert np.array_equal(r, ref(stack[i*2:(i+1)*2] + i)), i
            st = svc.stats()
            assert st["epoch"] == 1 and st["transport"] == "kv", st
            assert st["recoveries"] == 1 and st["procs"] == [0, 1], st
            # detection + recovery + relaunch stays well under the old
            # behaviour of waiting out the 560 s child-reap timeout
            assert dt < 3 * scfg.launch_timeout_s, dt
            print("RECOVERED BITEXACT", flush=True)
            svc.close()
            print("CLOSED", flush=True)
        else:
            try:
                svc.serve()
                print("SERVED-CLEAN", flush=True)
            except Exception as e:
                print("SERVED-ERR", type(e).__name__,
                      getattr(e, "kind", None), flush=True)
            svc.close()
        sys.stdout.flush(); os._exit(0)
    """), num_procs=3, devices=2, timeout=300,
        proc_env={2: {"REPRO_FAULT_INJECT": "follower_launch:kill:2"}},
        expect_fail={2})
    assert "RECOVERED BITEXACT" in outs[0] and "CLOSED" in outs[0]
    assert "SERVED-CLEAN" in outs[1]


def test_follower_loss_during_warmup_degrades_to_local():
    """A follower dying during the leader's warmup launch recovers the
    same way as during serving: with no other survivors the fabric
    degrades to the single-process path and requests still complete."""
    outs = run_procs(_chaos_body("""
        if PID == 0:
            svc.warmup([(32, 32)], grid_sizes=(3,), row_buckets=(4,))
            r = svc.submit_featurize(stack, eps).result(60)
            assert np.array_equal(r, ref(stack))
            st = svc.stats()
            assert st["recoveries"] == 1 and st["procs"] == [0], st
            print("WARMUP RECOVERED", flush=True)
            svc.close()
        else:
            try:
                svc.serve()
            except Exception as e:
                print("SERVED-ERR", type(e).__name__,
                      getattr(e, "kind", None), flush=True)
            svc.close()
        sys.stdout.flush(); os._exit(0)
    """), num_procs=2, devices=2, timeout=300,
        proc_env={1: {"REPRO_FAULT_INJECT": "follower_launch:kill:1"}},
        expect_fail={1})
    assert "WARMUP RECOVERED" in outs[0]


def test_double_fault_shrinks_twice_then_serves_local():
    """Two faults in one request: follower 2 dies on the gloo launch,
    then follower 1 dies on the post-recovery KV launch.  The leader
    sheds both across two epochs and still completes the future
    bit-equal, alone."""
    outs = run_procs(_chaos_body("""
        if PID == 0:
            r1 = svc.submit_featurize(stack, eps).result(60)
            assert np.array_equal(r1, ref(stack))
            r2 = svc.submit_featurize(stack + 1, eps).result(180)
            assert np.array_equal(r2, ref(stack + 1))
            st = svc.stats()
            assert st["recoveries"] == 2 and st["procs"] == [0], st
            assert st["epoch"] >= 2 and st["transport"] == "kv", st
            print("DOUBLE-FAULT SURVIVED", flush=True)
            svc.close()
        else:
            try:
                svc.serve()
            except Exception as e:
                print("SERVED-ERR", type(e).__name__,
                      getattr(e, "kind", None), flush=True)
            svc.close()
        sys.stdout.flush(); os._exit(0)
    """), num_procs=3, devices=2, timeout=300,
        proc_env={2: {"REPRO_FAULT_INJECT": "follower_launch:kill:2"},
                  1: {"REPRO_FAULT_INJECT": "kv_launch:kill:1"}},
        expect_fail={1, 2})
    assert "DOUBLE-FAULT SURVIVED" in outs[0]


def test_leader_death_raises_typed_error_on_followers():
    """With the coordination service in its own process (so the KV store
    survives), a leader SIGKILL mid-launch releases the follower from
    serve() with FabricError(kind='leader_lost') promptly -- it must not
    hang in the collective forever -- and the follower exits 0."""
    outs = run_procs(_chaos_body("""
        if PID == 0:
            r1 = svc.submit_featurize(stack, eps).result(60)
            assert np.array_equal(r1, ref(stack))
            svc.submit_featurize(stack, eps).result(60)   # killed here
            print("UNEXPECTED SURVIVAL", flush=True)
        else:
            t0 = time.monotonic()
            try:
                svc.serve()
                print("SERVED-CLEAN (unexpected)", flush=True)
            except Exception as e:
                # prompt: launch1 (compile-bound) + a few heartbeat
                # windows, never a wedged-forever collective
                dt = time.monotonic() - t0
                assert dt < 150, dt
                print("SERVED-ERR", type(e).__name__,
                      getattr(e, "kind", None), flush=True)
            svc.close()
        sys.stdout.flush(); os._exit(0)
    """), num_procs=2, devices=2, timeout=300,
        proc_env={0: {"REPRO_FAULT_INJECT": "leader_launch:kill:2"}},
        expect_fail={0}, external_coordinator=True)
    assert "SERVED-ERR FabricError leader_lost" in outs[1]
    assert "UNEXPECTED SURVIVAL" not in outs[0]


def test_hung_follower_evicted_within_deadline():
    """A wedged-but-alive follower (hangs inside the join, heartbeat
    thread still running) cannot be told apart from inside the
    collective: the leader's launch deadline expires, it evicts the
    follower and completes leader-local; the follower's bounded join
    notices the new epoch, learns it was evicted, and serve() raises
    FabricError(kind='evicted').  Both exit 0."""
    outs = run_procs(_chaos_body("""
        if PID == 0:
            r1 = svc.submit_featurize(stack, eps).result(120)
            assert np.array_equal(r1, ref(stack))       # warm launch
            # executables are compiled now: a short deadline cleanly
            # bounds the wedged launch without risking compile evictions
            svc.scfg = dataclasses.replace(svc.scfg, launch_timeout_s=8.0)
            t0 = time.monotonic()
            r2 = svc.submit_featurize(stack + 1, eps).result(120)
            dt = time.monotonic() - t0
            assert np.array_equal(r2, ref(stack + 1))
            st = svc.stats()
            assert st["recoveries"] == 1 and st["procs"] == [0], st
            assert dt < 45, dt
            print("HUNG FOLLOWER EVICTED", flush=True)
            svc.close()
        else:
            try:
                svc.serve()
                print("SERVED-CLEAN (unexpected)", flush=True)
            except Exception as e:
                print("SERVED-ERR", type(e).__name__,
                      getattr(e, "kind", None), flush=True)
            svc.close()
        sys.stdout.flush(); os._exit(0)
    """), num_procs=2, devices=2, timeout=300,
        proc_env={1: {"REPRO_FAULT_INJECT": "follower_launch:hang:2:3600"}})
    assert "HUNG FOLLOWER EVICTED" in outs[0]
    assert "SERVED-ERR FabricError evicted" in outs[1]
