"""Property-based / metamorphic suite for the sweep stack.

Every numerical claim the serving and streaming layers rest on is an
invariance: rows are independent (so permuting or padding them moves
bits around but never changes them), eps columns are independent (so
coalesced eps unions can reorder freely), truncation features are
relative quantities (scale-free), and coarser quantization can only
destroy information (entropy monotonicity).  This file states each one
as a property over ``tests/_hyp.py`` strategies -- real hypothesis when
installed, the deterministic seeded fallback grid otherwise.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import predictors as P
from repro.dist import sweep as DS

_EPSS = np.asarray([3e-3, 1e-2, 1e-1], np.float32)


def _stack(seed: int, k: int, m: int = 16, n: int = 24) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(size=(k, m, n)).astype(np.float32)


def _bits(a) -> np.ndarray:
    return np.asarray(a, np.float32).view(np.uint32)


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=10),
       st.sampled_from([2, 3, 5]))
def test_slice_permutation_equivariance(seed, k):
    """Rows of ``features_sweep`` are row-independent: permuting the
    slice axis permutes the rows BITWISE, nothing else moves.  (The
    serving layer's coalescing contract: a slice's row cannot depend on
    its batch neighbours.)"""
    x = _stack(seed, k)
    perm = np.random.default_rng(seed + 1).permutation(k)
    base = np.asarray(P.features_sweep(x, _EPSS))
    permuted = np.asarray(P.features_sweep(x[perm], _EPSS))
    assert np.array_equal(_bits(permuted), _bits(base[perm]))


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=10),
       st.sampled_from([2, 4]))
def test_eps_permutation_equivariance(seed, k):
    """Columns of the (k, e, 2) quality tensor are eps-independent:
    permuting the eb grid permutes the columns BITWISE.  (What lets the
    service launch sorted eps unions and scatter rows back per key.)"""
    x = _stack(seed, k)
    perm = np.random.default_rng(seed + 2).permutation(len(_EPSS))
    base = np.asarray(P.quality_sweep(x, _EPSS))
    permuted = np.asarray(P.quality_sweep(x, _EPSS[perm]))
    assert np.array_equal(_bits(permuted), _bits(base[:, perm]))


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=8),
       st.sampled_from([1, 3, 5]),
       st.sampled_from(["features", "quality"]))
def test_pad_row_invariance(seed, pad, mode):
    """``sweep_padded`` pad rows never change the real rows: launching
    at any ``k_pad > k`` returns the unpadded result bit-for-bit in the
    first k rows (the fixed-bucket streaming/serving launch shape)."""
    k = 3
    x = _stack(seed, k)
    fn = P.features_sweep if mode == "features" else P.quality_sweep
    base = np.asarray(fn(x, _EPSS))
    padded = np.asarray(DS.gather_rows(DS.sweep_padded(
        x, _EPSS, P.PredictorConfig(), k_pad=k + pad, mode=mode)))
    assert padded.shape[0] == k + pad
    assert np.array_equal(_bits(padded[:k]), _bits(base))


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=6),
       st.floats(min_value=1e-3, max_value=1e3))
def test_variance_fraction_scale_invariance(seed, scale):
    """The truncation criterion ``variance_fraction_for`` configures is
    RELATIVE: it depends only on the stack rank (never the data), and
    the log trunc-ratio feature it produces is invariant under positive
    scaling of the data (both the kept singular mass and sigma scale
    together)."""
    scale = float(np.float32(scale)) or 1e-3     # fallback grid has 0.0
    cfg = P.PredictorConfig()
    x = _stack(seed, 3)
    for arr in (x, scale * x):
        assert P.variance_fraction_for(cfg, arr.ndim) == \
            cfg.variance_fraction_2d
    assert P.variance_fraction_for(cfg, 4) == cfg.variance_fraction_3d
    # the fraction-of-singular-mass criterion is a ratio, so the
    # truncation it selects cannot move with the data's units
    for s in range(x.shape[0]):
        t0 = float(P.svd_trunc(x[s]))
        t1 = float(P.svd_trunc(np.float32(scale) * x[s]))
        assert t1 == pytest.approx(t0, abs=1.0 / x.shape[2] + 1e-6)


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=10),
       st.sampled_from([1e-3, 4e-3, 1.5e-2]))
def test_qent_monotone_vs_sort_oracle(seed, eps0):
    """Quantized entropy against the exact sort-route oracle, plus the
    data-processing inequality: doubling eps merges code cells
    (floor(x / 2eps) == floor(floor(x / eps) / 2)), so entropy is
    nonincreasing along eps doublings.  Data is kept inside the first
    ``bins`` codes so the histogram's mod-bins fold is injective and
    the binned entropy IS the exact entropy."""
    from repro.kernels.qent.ref import quantized_entropy_sweep

    x = np.clip(_stack(seed, 2), -1.0, 1.0)
    epss = np.asarray([eps0, 2 * eps0, 4 * eps0], np.float32)
    ent = np.asarray(quantized_entropy_sweep(x, epss))     # (k, e)
    for s in range(x.shape[0]):
        flat = x[s].reshape(-1)
        for ei, eps in enumerate(epss):
            codes = np.floor(flat / np.float32(eps)).astype(np.int64)
            _, counts = np.unique(codes, return_counts=True)
            p = counts.astype(np.float64) / counts.sum()
            oracle = float(-(p * np.log2(p)).sum())
            assert ent[s, ei] == pytest.approx(oracle, abs=1e-4)
        assert ent[s, 0] + 1e-5 >= ent[s, 1] >= ent[s, 2] - 1e-5
