"""Autotuner table semantics, donation safety, and XLA_FLAGS merging.

The tuned-table contract (ISSUE 8): explicit kwarg > TuneConfig field >
table cell > kernel default; a missing/corrupt/stale table or an unknown
backend falls back to today's defaults bit-exactly; buffer donation on
the sweep hot path changes buffer lifetime, never results.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tune as KT
from repro.kernels.gram import gram as GK
from repro.kernels.gram import ops as gram_ops
from repro.kernels.qent import qent as QK
from repro.kernels.qent import ops as qent_ops
from repro.launch import xla_flags as XF


@pytest.fixture()
def tuned_dir(tmp_path, monkeypatch):
    """Point the table loader at a scratch dir for the test, then
    restore the checked-in tables."""
    monkeypatch.setenv("REPRO_TUNED_DIR", str(tmp_path))
    KT.invalidate_table_cache()
    yield tmp_path
    KT.invalidate_table_cache()


def _write(path, obj):
    with open(path, "w") as f:
        if isinstance(obj, str):
            f.write(obj)
        else:
            json.dump(obj, f)
    KT.invalidate_table_cache()


# ------------------------------------------------------------- table I/O
def test_table_roundtrip(tuned_dir):
    table = {"schema_version": KT.SCHEMA_VERSION, "backend": "testbe",
             "cells": {KT.gram_key(256, 256): {"bn": 256, "bk": 128}}}
    KT.save_table(table, str(tuned_dir / "testbe.json"))
    got = KT.load_table("testbe")
    assert got == table
    assert KT.gram_blocks(256, 256, KT.TuneConfig(backend="testbe")) \
        == (256, 128)


def test_missing_corrupt_and_stale_tables_fall_back(tuned_dir):
    assert KT.load_table("nosuch") is None

    _write(tuned_dir / "corrupt.json", "{not json")
    with pytest.warns(UserWarning, match="unreadable"):
        assert KT.load_table("corrupt") is None

    _write(tuned_dir / "stale.json",
           {"schema_version": KT.SCHEMA_VERSION + 1, "cells": {}})
    with pytest.warns(UserWarning, match="schema_version"):
        assert KT.load_table("stale") is None

    # every fallback resolves to the kernel defaults
    for be in ("nosuch", "corrupt", "stale"):
        t = KT.TuneConfig(backend=be)
        assert KT.gram_blocks(256, 256, t) == (GK.DEFAULT_BN, GK.DEFAULT_BK)
        assert KT.qent_tile(16384, 4096, t) == QK.DEFAULT_TILE


def test_check_table_gate(tuned_dir):
    with pytest.raises(SystemExit, match="missing or stale"):
        KT.check_table("nosuch")
    KT.save_table({"schema_version": KT.SCHEMA_VERSION, "backend": "be",
                   "cells": {}}, str(tuned_dir / "be.json"))
    assert "OK" in KT.check_table("be")


def test_checked_in_cpu_table_loads():
    """The committed baseline must load at the current schema and carry
    at least one gram and one qent cell."""
    KT.invalidate_table_cache()
    table = KT.load_table("cpu")
    assert table is not None, "kernels/tuned/cpu.json missing or stale"
    keys = table["cells"].keys()
    assert any(k.startswith("gram:") for k in keys)
    assert any(k.startswith("qent:") for k in keys)


# ----------------------------------------------------------- precedence
def test_precedence_kwarg_over_config_over_table(tuned_dir):
    KT.save_table(
        {"schema_version": KT.SCHEMA_VERSION, "backend": "testbe",
         "cells": {KT.gram_key(128, 128): {"bn": 512, "bk": 128},
                   KT.qent_key(8192, 512): {"tile": 4096}}},
        str(tuned_dir / "testbe.json"))

    table_only = KT.TuneConfig(backend="testbe")
    assert KT.gram_blocks(128, 128, table_only) == (512, 128)
    assert KT.qent_tile(8192, 512, table_only) == 4096

    # a set TuneConfig field beats the table (per-field)
    cfg = KT.TuneConfig(backend="testbe", gram_bn=64, qent_tile=1024)
    assert KT.gram_blocks(128, 128, cfg) == (64, 128)
    assert KT.qent_tile(8192, 512, cfg) == 1024

    # an explicit kwarg beats everything
    assert KT.gram_blocks(128, 128, cfg, bn=256, bk=64) == (256, 64)
    assert KT.qent_tile(8192, 512, cfg, tile=512) == 512

    # use_table=False skips the table but keeps set fields
    off = KT.TuneConfig(backend="testbe", use_table=False)
    assert KT.gram_blocks(128, 128, off) == (GK.DEFAULT_BN, GK.DEFAULT_BK)
    assert KT.qent_tile(8192, 512, off) == QK.DEFAULT_TILE

    # a miss on the exact cell key falls through to the defaults
    assert KT.gram_blocks(300, 500, table_only) \
        == (GK.DEFAULT_BN, GK.DEFAULT_BK)


def test_untuned_backend_bitequal_to_defaults():
    """An unknown backend (no table) must produce bit-identical outputs
    to explicitly-passed kernel defaults -- the fallback is exact."""
    rng = np.random.default_rng(3)
    x = np.asarray(rng.standard_normal((2, 128, 128)), np.float32)
    nb = KT.TuneConfig(backend="no-such-backend")
    got = np.asarray(gram_ops.gram_batched(x, tune=nb))
    want = np.asarray(
        gram_ops.gram_batched(x, bn=GK.DEFAULT_BN, bk=GK.DEFAULT_BK))
    assert np.array_equal(got, want)

    flat = np.asarray(rng.standard_normal((2, 8192)), np.float32)
    epss = np.asarray([1e-3, 1e-2], np.float32)
    got = np.asarray(qent_ops.quantized_entropy_sweep(flat, epss, 512,
                                                      tune=nb))
    want = np.asarray(qent_ops.quantized_entropy_sweep(
        flat, epss, 512, tile=QK.DEFAULT_TILE))
    assert np.array_equal(got, want)


def test_cpu_table_bitequal_to_defaults():
    """The committed CPU table's cells never change numerics: the tuned
    configuration's output is bitwise the default's (tuner bit filter)."""
    KT.invalidate_table_cache()
    table = KT.load_table("cpu")
    assert table is not None
    for key, cell in table["cells"].items():
        k = 2
        if key.startswith("gram:"):
            _, m, n = cell["shape"]
            x = np.asarray(np.random.default_rng(0)
                           .standard_normal((k, min(m, 256), min(n, 256))),
                           np.float32)
            got = np.asarray(
                gram_ops.gram_batched(x, bn=cell["bn"], bk=cell["bk"]))
            want = np.asarray(gram_ops.gram_batched(
                x, bn=GK.DEFAULT_BN, bk=GK.DEFAULT_BK))
        else:
            _, n, bins, e = cell["shape"]
            x = np.asarray(np.random.default_rng(1)
                           .standard_normal((k, min(n, 8192))), np.float32)
            epss = np.geomspace(1e-3, 1e-1, e).astype(np.float32)
            got = np.asarray(qent_ops.quantized_entropy_sweep(
                x, epss, bins, tile=min(cell["tile"], 8192)))
            want = np.asarray(qent_ops.quantized_entropy_sweep(
                x, epss, bins, tile=QK.DEFAULT_TILE))
        assert np.array_equal(got, want), key


def test_vmem_budget_from_backend_table():
    assert KT.vmem_compare_budget("cpu") == 8 * 1024 * 1024
    assert KT.vmem_compare_budget("tpu-v5e") == 64 * 1024 * 1024
    assert KT.vmem_compare_budget("tpu-v5-lite") == 64 * 1024 * 1024
    # unknown backends get the conservative default entry
    assert KT.vmem_compare_budget("quantum") == 8 * 1024 * 1024


# ------------------------------------------------------------- donation
def test_sweep_padded_donation_bitequal():
    from repro.dist import sweep as DS
    rng = np.random.default_rng(7)
    x = np.asarray(rng.standard_normal((3, 96, 96)), np.float32)
    epss = [1e-3, 1e-2]
    base = np.asarray(DS.sweep_padded(jnp.asarray(x), epss, k_pad=4,
                                      donate=False))
    donated = np.asarray(DS.sweep_padded(jnp.asarray(x), epss, k_pad=4,
                                         donate=True))
    assert np.array_equal(base, donated)
    # numpy inputs are unaffected by donation (only the device upload
    # is donated); the service's staging buffers rely on this
    donated_np = np.asarray(DS.sweep_padded(x, epss, k_pad=4, donate=True))
    assert np.array_equal(base, donated_np)


def test_donated_jit_variant_bitequal():
    """The donated executable is a distinct jit with identical math.
    (XLA may or may not be able to reuse the donated buffer -- the
    sweep's (k, e, 2) output never aliases the (k, m, n) input -- but
    donation must never change results, only buffer lifetime.)"""
    from repro.core import predictors as PRED
    x = jnp.asarray(np.random.default_rng(8)
                    .standard_normal((2, 96, 96)).astype(np.float32))
    epss = jnp.asarray([1e-3, 1e-2], jnp.float32)
    kw = dict(vf=PRED.variance_fraction_for(PRED.PredictorConfig(), 3),
              bins=PRED.PredictorConfig().qent_bins, use_kernels=True,
              tune=None)
    assert PRED._features_sweep_donated is not PRED._features_sweep_traced
    want = np.asarray(PRED._features_sweep_traced(x, epss, **kw))
    got = np.asarray(PRED._features_sweep_donated(x, epss, **kw))
    assert np.array_equal(got, want)


# ------------------------------------------------------------ xla_flags
def test_parse_format_roundtrip():
    s = "--xla_a=1 --xla_bare --xla_b=x=y"
    assert XF.format_flags(XF.parse_flags(s)) == s
    assert XF.parse_flags(s)["--xla_bare"] is None
    assert XF.parse_flags(s)["--xla_b"] == "x=y"
    assert XF.parse_flags("") == {}


def test_merge_later_wins_and_dedups():
    merged = XF.merge_flag_strings(
        "--xla_a=1 --xla_b=2", "--xla_a=9 --xla_c=3")
    flags = XF.parse_flags(merged)
    assert flags == {"--xla_b": "2", "--xla_a": "9", "--xla_c": "3"}
    assert merged.count("--xla_a") == 1

    # the dryrun shape: default device count loses to the user's export
    assert XF.merge_flag_strings(
        "--xla_force_host_platform_device_count=512",
        "",
        "--xla_force_host_platform_device_count=8",
    ) == "--xla_force_host_platform_device_count=8"


def test_apply_preset_user_wins():
    env = {"XLA_FLAGS": "--xla_cpu_multi_thread_eigen=false --xla_u=1"}
    out = XF.apply_preset("cpu", env=env)
    flags = XF.parse_flags(out)
    assert flags["--xla_cpu_multi_thread_eigen"] == "false"  # user wins
    assert flags["--xla_u"] == "1"
    assert env["XLA_FLAGS"] == out

    env = {}
    out = XF.apply_preset("tpu", extra={"--xla_extra": None}, env=env)
    assert "--xla_step_marker_location=1" in out
    assert "--xla_extra" in out


def test_apply_preset_guards():
    with pytest.raises(ValueError, match="unknown XLA preset"):
        XF.apply_preset("warp-drive", env={})
    assert XF.jax_imported()          # the test process imported jax above
    with pytest.raises(RuntimeError, match="after jax was imported"):
        XF.apply_preset("cpu")        # os.environ + jax imported -> refuse
    assert XF.apply_preset("none", env={}) == ""
