"""f64 numpy oracle + route-equality tests for the fused quality sweep.

The quality tensor's contract has two halves:

* ACCURACY -- the f32 one-pass SSE/PSNR/NRMSE pipeline must track an
  f64 numpy oracle that shares only the quantizer's f32 code decisions
  (so boundary ties can't flip a code between the two), and every edge
  the formulas can hit (constant slices, all-zero slices, eps far above
  the value range) must come out finite and correctly capped;
* BIT-EQUALITY -- the jnp reference route, the Pallas-interpret kernel
  route, the sharded launch, the streamed driver, and the served method
  must all emit the identical bits (the serving/streaming layers'
  coalescing contract, same as the feature sweep's).
"""
import os

import jax
import numpy as np
import pytest

from repro.core import predictors as P
from repro.core import stream as ST
from repro.core import usecases as UC
from repro.data import source as SRC
from repro.dist import sweep as DS
from repro.kernels.quality import NRMSE_CAP, PSNR_CAP, quality_sweep
from repro.quant import INT32_CODE_MAX, INT32_CODE_MIN

_EPSS = np.asarray([1e-3, 1e-2, 1e-1], np.float32)


def _stack(seed=0, k=4, m=24, n=32):
    return np.random.default_rng(seed).normal(
        size=(k, m, n)).astype(np.float32)


def _bits(a):
    return np.asarray(a, np.float32).view(np.uint32)


def oracle_quality(x: np.ndarray, epss) -> np.ndarray:
    """f64 numpy oracle: f32 code decisions (matching the quantizer
    exactly), f64 error accumulation and finalization."""
    x = np.asarray(x, np.float32)
    k = x.shape[0]
    flat = x.reshape(k, -1).astype(np.float64)
    flat32 = x.reshape(k, -1)
    rng = np.abs(flat.max(axis=1) - flat.min(axis=1))
    out = np.empty((k, len(epss), 2), np.float64)
    for ei, eps in enumerate(np.asarray(epss, np.float32)):
        codes = np.clip(np.floor(flat32 / eps), INT32_CODE_MIN,
                        INT32_CODE_MAX).astype(np.int64)
        err = flat - codes * np.float64(eps)
        mse = np.mean(err * err, axis=1)
        exact = mse == 0.0
        with np.errstate(divide="ignore", invalid="ignore"):
            psnr = np.where(
                exact, PSNR_CAP,
                np.clip(20.0 * np.log10(rng) - 10.0 * np.log10(mse),
                        -PSNR_CAP, PSNR_CAP))
            nrmse = np.where(exact, 0.0,
                             np.clip(np.sqrt(mse) / rng, 0.0, NRMSE_CAP))
        out[:, ei, 0] = np.nan_to_num(psnr, nan=-PSNR_CAP,
                                      posinf=PSNR_CAP, neginf=-PSNR_CAP)
        out[:, ei, 1] = np.nan_to_num(nrmse, nan=NRMSE_CAP,
                                      posinf=NRMSE_CAP)
    return out


# ---------------------------------------------------------------------------
# Accuracy vs the f64 oracle
# ---------------------------------------------------------------------------


def test_quality_matches_f64_oracle():
    """Random data, both kernel routes: PSNR within 1e-3 dB and NRMSE
    within 1e-5 relative of the f64 oracle."""
    x = _stack(0)
    want = oracle_quality(x, _EPSS)
    for use_kernel in (False, True):
        got = np.asarray(quality_sweep(x, _EPSS, use_kernel=use_kernel))
        np.testing.assert_allclose(got[:, :, 0], want[:, :, 0], atol=1e-3)
        np.testing.assert_allclose(got[:, :, 1], want[:, :, 1],
                                   rtol=1e-5, atol=1e-12)


def test_quality_volume_rank4():
    """(k, d, m, n) volumes flatten identically to slices: the oracle
    sees the same flat stream."""
    x = np.random.default_rng(1).normal(size=(3, 4, 16, 16)) \
        .astype(np.float32)
    want = oracle_quality(x, _EPSS)
    got = np.asarray(P.quality_sweep(x, _EPSS))
    np.testing.assert_allclose(got[:, :, 0], want[:, :, 0], atol=1e-3)
    np.testing.assert_allclose(got[:, :, 1], want[:, :, 1],
                               rtol=1e-5, atol=1e-12)


def test_constant_slice_exact_psnr_cap():
    """A constant slice exactly representable at eps (c = m * eps) has
    SSE == 0: PSNR reports the +cap, not NaN/inf, and NRMSE is 0."""
    eps = np.float32(0.25)
    x = np.full((2, 8, 16), 16 * 0.25, np.float32)    # codes land exactly
    out = np.asarray(quality_sweep(x, np.asarray([eps])))
    assert np.all(np.isfinite(out))
    assert np.all(out[:, :, 0] == PSNR_CAP)
    assert np.all(out[:, :, 1] == 0.0)


def test_constant_slice_inexact_hits_negative_cap():
    """A constant slice with NONZERO quantization error has zero range:
    log10(0) would be -inf, the clip floors PSNR at the -cap and NRMSE
    saturates at its cap -- everything stays finite."""
    x = np.full((2, 8, 16), 0.3, np.float32)          # 0.3/0.25 -> err != 0
    out = np.asarray(quality_sweep(x, np.asarray([0.25], np.float32)))
    assert np.all(np.isfinite(out))
    assert np.all(out[:, :, 0] == -PSNR_CAP)
    assert np.all(out[:, :, 1] == NRMSE_CAP)


def test_all_zero_slice():
    """All-zero slices quantize exactly at every eps: +cap PSNR, 0
    NRMSE, on both routes."""
    x = np.zeros((3, 16, 16), np.float32)
    for use_kernel in (False, True):
        out = np.asarray(quality_sweep(x, _EPSS, use_kernel=use_kernel))
        assert np.all(out[:, :, 0] == PSNR_CAP)
        assert np.all(out[:, :, 1] == 0.0)


def test_eps_larger_than_value_range():
    """eps far above the value range collapses every positive value to
    code 0 (error = x) and negatives to code -1: finite outputs matching
    the oracle, never NaN."""
    x = _stack(2, k=3) * 0.01                          # range ~ +-0.04
    epss = np.asarray([1.0, 100.0], np.float32)
    want = oracle_quality(x, epss)
    out = np.asarray(quality_sweep(x, epss))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out[:, :, 0], want[:, :, 0], atol=1e-3)
    np.testing.assert_allclose(out[:, :, 1], want[:, :, 1],
                               rtol=1e-5, atol=1e-12)


def test_mixed_edge_stack():
    """One launch mixing random, all-zero, constant-exact and tiny-range
    rows stays finite and bit-equal between the two kernel routes (rows
    are independent: edge rows cannot leak into their neighbours)."""
    rows = [np.random.default_rng(3).normal(size=(8, 16)),
            np.zeros((8, 16)), np.full((8, 16), 0.5),
            1e-30 * np.random.default_rng(4).normal(size=(8, 16))]
    x = np.stack(rows).astype(np.float32)
    a = np.asarray(quality_sweep(x, _EPSS, use_kernel=False))
    b = np.asarray(quality_sweep(x, _EPSS, use_kernel=True))
    assert np.all(np.isfinite(a))
    assert np.array_equal(_bits(a), _bits(b))


# ---------------------------------------------------------------------------
# Route bit-equality
# ---------------------------------------------------------------------------


def test_jnp_vs_pallas_interpret_bitequal():
    x = _stack(5)
    a = np.asarray(quality_sweep(x, _EPSS, use_kernel=False))
    b = np.asarray(quality_sweep(x, _EPSS, use_kernel=True))
    assert np.array_equal(_bits(a), _bits(b))


def test_engine_and_both_mode_bitequal():
    """``features_sweep(quality=True)`` splits one fused "both" launch;
    each half must be bit-equal to its standalone sweep."""
    x = _stack(6)
    feats, qual = P.features_sweep(x, _EPSS, quality=True)
    assert np.array_equal(_bits(feats),
                          _bits(P.features_sweep(x, _EPSS)))
    assert np.array_equal(_bits(qual), _bits(P.quality_sweep(x, _EPSS)))
    eng = P.get_engine(P.PredictorConfig())
    assert np.array_equal(_bits(eng.quality(x, _EPSS)), _bits(qual))


def test_sharded_bitequal():
    """Sharded launch (all local devices) == single-device bits."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run under the multi-device job)")
    from repro.launch import mesh as M
    x = _stack(7, k=8)
    base = np.asarray(P.quality_sweep(x, _EPSS))
    mesh = M.make_sweep_mesh(len(jax.devices()))
    out = np.asarray(P.quality_sweep(x, _EPSS, mesh=mesh))
    assert np.array_equal(_bits(out), _bits(base))


def test_streamed_bitequal(tmp_path):
    """Chunked streaming (tiny budget -> many chunks) == in-memory."""
    gen = SRC.GeneratorSource(
        [SRC.FieldVariable("miranda-vx", 7, (32,), seed=2)])
    path = SRC.write_dataset(str(tmp_path / "ds"), gen, fmt="npz",
                             dtype="float64")
    src = SRC.open_dataset(path)
    x = src.read("miranda-vx")
    feats, qual = ST.stream_features(
        src, "miranda-vx", _EPSS, quality=True,
        stream=ST.StreamConfig(budget_bytes=2 * 32 * 32 * 4))
    assert np.array_equal(_bits(feats),
                          _bits(P.features_sweep(x, _EPSS)))
    assert np.array_equal(_bits(qual), _bits(P.quality_sweep(x, _EPSS)))


def test_served_bitequal():
    """The registered ``quality`` method == the direct sweep, bits."""
    from repro.serve.sweep_service import ServiceConfig, SweepService
    x = _stack(8)
    base = np.asarray(P.quality_sweep(x, _EPSS))
    with SweepService(ServiceConfig(max_wait_ms=20.0)) as svc:
        out = svc.quality(x, _EPSS)
        # distinct key space: the same slices' FEATURE rows must not
        # collide with the quality rows in the cross-request cache
        feats = svc.featurize(x, _EPSS)
    assert np.array_equal(_bits(out), _bits(base))
    assert np.array_equal(_bits(feats),
                          _bits(P.features_sweep(x, _EPSS)))


def test_quality_sweep_validation():
    with pytest.raises(ValueError):
        quality_sweep(_stack(), np.asarray([0.0], np.float32))
    with pytest.raises(ValueError):
        quality_sweep(_stack(), _EPSS, tile=100)       # not 8 * 2^j
    with pytest.raises(ValueError):
        P.quality_sweep(np.zeros((4, 4), np.float32), _EPSS)  # rank 2


# ---------------------------------------------------------------------------
# UC3: quality tables + joint frontier search
# ---------------------------------------------------------------------------


def _models(seed=9, names=("zfp", "sz2")):
    ebs = [1e-4, 1e-3, 1e-2, 1e-1]
    train = _stack(seed, k=6)
    return {n: UC.EbGridModel.train(train, n, ebs) for n in names}


def test_quality_table_trained_and_predicts():
    models = _models()
    x = _stack(10, k=1)[0]
    for gm in models.values():
        assert gm.quality is not None
        assert gm.quality.coef.shape == (4, 3)
        # finer eb -> (weakly) better predicted quality on average data
        p_fine = gm.predict_psnr(x, 1e-4)
        p_coarse = gm.predict_psnr(x, 1e-1)
        assert np.isfinite(p_fine) and np.isfinite(p_coarse)
        assert -PSNR_CAP <= p_coarse <= p_fine + 40.0 <= PSNR_CAP + 40.0


def test_find_setting_feasible_is_grid_complete():
    """Whenever some grid point meets both (monotonized) floors,
    find_setting returns a feasible setting -- checked against a brute
    force over the grid."""
    models = _models()
    x = _stack(11, k=1)[0]
    gm = next(iter(models.values()))
    for psnr_floor in (40.0, 60.0, 90.0):
        # brute-force joint feasibility over the grid
        feas_cr = []
        for name, m in models.items():
            pg = np.minimum.accumulate(
                [m.predict_psnr(x, float(e)) for e in m.ebs])
            cg = np.maximum.accumulate(
                [m.predict(x, float(e)) for e in m.ebs])
            feas_cr += [c for p, c in zip(pg, cg) if p >= psnr_floor]
        if not feas_cr:
            continue
        cr_floor = 0.9 * max(feas_cr)
        res = UC.find_setting(models, x, cr_floor=cr_floor,
                              psnr_floor=psnr_floor)
        assert res.feasible, (psnr_floor, cr_floor, res)
        assert res.predicted_cr >= cr_floor
        assert res.predicted_psnr >= psnr_floor - 1e-6
        assert res.compressor in models


def test_find_setting_infeasible_is_typed():
    models = _models()
    x = _stack(12, k=1)[0]
    res = UC.find_setting(models, x, cr_floor=1e9, psnr_floor=40.0)
    assert not res.feasible and "CR >= 1e+09" in res.reason
    assert set(res.candidates) == set(models)
    res = UC.find_setting(models, x, cr_floor=1.0, psnr_floor=1e4)
    assert not res.feasible and "unreachable" in res.reason


def test_find_setting_requires_quality_tables():
    models = _models()
    import dataclasses
    broken = dict(models)
    first = next(iter(broken))
    broken[first] = dataclasses.replace(broken[first], quality=None)
    with pytest.raises(ValueError, match="quality table"):
        UC.find_setting(broken, _stack(13, k=1)[0],
                        cr_floor=2.0, psnr_floor=50.0)
