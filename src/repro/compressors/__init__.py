"""Error-bounded lossy compressor suite (JAX decorrelation + real byte counts).

Importing this package registers all compressors:
  sz2, sz3-lorenzo, sz3-regression, sz3-interp, zfp, mgard,
  bitgrooming, digitrounding, tthresh.
"""
from repro.compressors import base
from repro.compressors import sz        # noqa: F401  (registers)
from repro.compressors import zfp      # noqa: F401
from repro.compressors import mgard    # noqa: F401
from repro.compressors import rounding # noqa: F401
from repro.compressors import tthresh  # noqa: F401

get = base.get
names = base.names
all_compressors = base.all_compressors

# The 2-D study set used across benchmarks (paper's main compressor list).
STUDY_2D = ["sz2", "sz3-lorenzo", "sz3-regression", "sz3-interp",
            "zfp", "mgard", "bitgrooming", "digitrounding"]
# The 3-D study set (paper section 4.5).
STUDY_3D = ["sz2", "zfp", "mgard", "bitgrooming", "tthresh"]
