"""TTHRESH-like HOSVD (Tucker) compressor for 3-D tensors.

Ballester-Ripoll et al. 2020: whole-tensor HOSVD, then thresholding /
quantization of the core.  TTHRESH bounds *RMSE*, not the pointwise max
error -- the paper singles it out as the hardest CR to predict (Table 4).

TPU adaptation: factor matrices come from eigendecompositions of the mode
Gram matrices (MXU matmul + eigh) rather than LAPACK SVDs of the unfoldings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.compressors import base, lossless


def _unfold(x: jnp.ndarray, mode: int) -> jnp.ndarray:
    return jnp.moveaxis(x, mode, 0).reshape(x.shape[mode], -1)


def hosvd(x: jnp.ndarray):
    """Full Tucker decomposition: returns (core, [U1, U2, U3])."""
    us = []
    for mode in range(x.ndim):
        u = _unfold(x, mode)
        g = u @ u.T
        _, vecs = jnp.linalg.eigh(g)        # ascending
        us.append(vecs[:, ::-1])            # descending eigenvalue order
    core = x
    for mode, u in enumerate(us):
        core = jnp.tensordot(core, u, axes=[[mode], [0]])
        core = jnp.moveaxis(core, -1, mode)
    return core, us


def tucker_reconstruct(core: jnp.ndarray, us) -> jnp.ndarray:
    x = core
    for mode, u in enumerate(us):
        x = jnp.tensordot(x, u.T, axes=[[mode], [0]])
        x = jnp.moveaxis(x, -1, mode)
    return x


class TTHRESH(base.Compressor):
    """Core thresholding to meet an RMSE budget of eps, log-quantized core."""
    name = "tthresh"
    supports_3d = True
    QBITS = 12

    def encode(self, data, eps):
        data = data.astype(jnp.float32)
        core, us = hosvd(data)
        # orthogonal factors => dropping core energy E adds RMSE sqrt(E/N)
        budget = (eps ** 2) * data.size
        c2 = jnp.sort(core.reshape(-1) ** 2)
        cum = jnp.cumsum(c2)
        # largest threshold index whose cumulative energy stays in budget
        idx = jnp.sum(cum <= budget)
        tau2 = jnp.where(idx > 0, c2[jnp.maximum(idx - 1, 0)], 0.0)
        keep = core ** 2 > tau2
        kept = jnp.where(keep, core, 0.0)
        # log-magnitude quantization of surviving coefficients
        amax = jnp.maximum(jnp.max(jnp.abs(kept)), 1e-30)
        logq = jnp.where(
            keep,
            jnp.round(
                (jnp.log2(jnp.maximum(jnp.abs(kept), 1e-30) / amax) + 40.0)
                / 40.0 * (2 ** self.QBITS - 1)
            ),
            0.0,
        ).astype(jnp.int32)
        signs = jnp.where(core < 0, 1, 0).astype(jnp.int8)
        return (logq, signs, keep), {
            "us": us, "amax": amax, "shape": data.shape,
        }

    def decode(self, codes, aux, eps):
        logq, signs, keep = codes
        amax = aux["amax"]
        mag = jnp.exp2(logq.astype(jnp.float32) / (2 ** self.QBITS - 1) * 40.0 - 40.0) * amax
        core = jnp.where(keep, mag * jnp.where(signs == 1, -1.0, 1.0), 0.0)
        return tucker_reconstruct(core, aux["us"])

    def size_bytes(self, codes, aux, eps):
        logq, signs, keep = codes
        keep_np = np.asarray(keep)
        nnz = int(keep_np.sum())
        # significance bitmap (RLE+zstd), quantized magnitudes, signs
        bitmap = np.packbits(keep_np.reshape(-1))
        total = lossless.zstd_bytes(bitmap.tobytes())
        vals = np.asarray(logq).reshape(-1)[keep_np.reshape(-1)]
        if vals.size:
            total += lossless.coded_size_bytes(vals.astype(np.int32))
            total += int(np.ceil(nnz / 8))  # signs
        # factor matrices, stored fp16 (rank truncated to used rows would be
        # better; full storage is TTHRESH-faithful for small tensors)
        for u in aux["us"]:
            total += u.size * 2
        return total + 64

    def roundtrip_error(self, data, eps):  # RMSE, not max error
        codes, aux = self.encode(data, eps)
        recon = self.decode(codes, aux, eps)
        return float(jnp.sqrt(jnp.mean((recon - data) ** 2)))


base.register(TTHRESH())
