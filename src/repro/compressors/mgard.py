"""MGARD-like multilevel (multigrid) compressor.

Hierarchical decomposition (Ainsworth et al.): the data is recursively
restricted to a coarse grid; fine-grid points are predicted by multilinear
interpolation of the *reconstructed* coarse grid and the multilevel
coefficients (prediction residuals) are uniformly quantized and entropy
coded (zstd).  Predicting from reconstructed values keeps the absolute
error bound exact at every point, mirroring MGARD's s=0 uniform-quantizer
mode.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.compressors import base, lossless
from repro.compressors.sz import quantize_bounded


def _interp_even_to_full(coarse: jnp.ndarray, full_shape, axis: int) -> jnp.ndarray:
    """Linear interpolation from even-index samples to the full grid along
    ``axis`` (odd points = average of neighbours, edge clamped)."""
    c = jnp.moveaxis(coarse, axis, 0)
    n_full = full_shape[axis]
    nxt = jnp.concatenate([c[1:], c[-1:]], axis=0)
    odd = 0.5 * (c + nxt)
    out_shape = (n_full,) + c.shape[1:]
    out = jnp.zeros(out_shape, c.dtype)
    out = out.at[0::2].set(c[: (n_full + 1) // 2])
    out = out.at[1::2].set(odd[: n_full // 2])
    return jnp.moveaxis(out, 0, axis)


def _predict_fine(coarse: jnp.ndarray, fine_shape) -> jnp.ndarray:
    """Multilinear prolongation from the [::2,::2(,::2)] grid to fine_shape."""
    cur = coarse
    for axis in range(len(fine_shape)):
        cur = _interp_even_to_full(cur, fine_shape, axis)
    return cur


def _restrict(data: jnp.ndarray) -> jnp.ndarray:
    sl = tuple(slice(None, None, 2) for _ in data.shape)
    return data[sl]


class MGARD(base.Compressor):
    name = "mgard"
    levels = 4

    def encode(self, data, eps):
        data = data.astype(jnp.float32)
        shapes, codes = [], []
        cur = data
        for _ in range(self.levels):
            if min(cur.shape) < 4:
                break
            coarse = _restrict(cur)
            shapes.append(cur.shape)
            codes.append(None)  # placeholder, filled in reverse pass
            cur = coarse
        # Quantize from the coarsest level outward so predictions use
        # reconstructed values (exact error-bound preservation).
        root_codes = quantize_bounded(cur, eps)
        recon = root_codes.astype(jnp.float32) * (2.0 * eps)
        level_codes = []
        # We must re-derive each level's fine data: walk shapes in reverse.
        fines = []
        cur2 = data
        for shape in shapes:
            fines.append(cur2)
            cur2 = _restrict(cur2)
        for fine, shape in zip(reversed(fines), reversed(shapes)):
            pred = _predict_fine(recon, shape)
            resid = fine - pred
            c = quantize_bounded(resid, eps)
            level_codes.append(c)
            recon = pred + c.astype(jnp.float32) * (2.0 * eps)
        return (root_codes, level_codes), {"shape": data.shape, "shapes": shapes}

    def decode(self, codes, aux, eps):
        root_codes, level_codes = codes
        recon = root_codes.astype(jnp.float32) * (2.0 * eps)
        for c, shape in zip(level_codes, reversed(aux["shapes"])):
            pred = _predict_fine(recon, shape)
            recon = pred + c.astype(jnp.float32) * (2.0 * eps)
        return recon

    def size_bytes(self, codes, aux, eps):
        root_codes, level_codes = codes
        total = lossless.coded_size_bytes(np.asarray(root_codes))
        for c in level_codes:
            total += lossless.coded_size_bytes(np.asarray(c))
        return total


base.register(MGARD())
