"""Entropy-coding size measurement (host-side) + in-graph estimators.

The real byte counts come from zstandard on serialized quantization codes —
the same lossless backends SZ/MGARD/Bit-Grooming use.  ``entropy_size_bits``
is the jittable first-order-entropy size model used inside traced code
(e.g. the gradient-compression gate) where host callbacks are not possible.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

try:
    import zstandard

    HAVE_ZSTD = True
    _CCTX = zstandard.ZstdCompressor(level=3)

    def _compress(payload: bytes) -> bytes:
        return _CCTX.compress(payload)
except ImportError:  # minimal environments: stdlib DEFLATE stands in
    import zlib

    HAVE_ZSTD = False

    def _compress(payload: bytes) -> bytes:
        return zlib.compress(payload, 6)


def zstd_bytes(payload: bytes) -> int:
    """Entropy-coded byte count (zstd when installed, else zlib)."""
    return len(_compress(payload))


def pack_codes(codes: np.ndarray) -> tuple[bytes, int]:
    """Serialize integer codes in the narrowest width; large outliers are
    stored out-of-band like SZ's 'unpredictable values' list.

    Returns (payload, outlier_bytes).
    """
    codes = np.asarray(codes)
    lo, hi = codes.min(), codes.max()
    outlier_bytes = 0
    if lo >= np.iinfo(np.int16).min and hi <= np.iinfo(np.int16).max:
        if lo >= np.iinfo(np.int8).min and hi <= np.iinfo(np.int8).max:
            payload = codes.astype(np.int8).tobytes()
        else:
            payload = codes.astype(np.int16).tobytes()
    else:
        # clip to int16 range, store outliers exactly (4B each)
        clipped = np.clip(codes, np.iinfo(np.int16).min + 1, np.iinfo(np.int16).max)
        n_out = int(np.sum(clipped != codes))
        outlier_bytes = 8 * n_out  # 4B index + 4B value
        payload = clipped.astype(np.int16).tobytes()
    return payload, outlier_bytes


def coded_size_bytes(codes: np.ndarray, aux_bytes: int = 0) -> int:
    """Real compressed size: zstd over packed codes + aux/outlier overhead."""
    payload, outlier_bytes = pack_codes(np.asarray(codes))
    return zstd_bytes(payload) + outlier_bytes + aux_bytes + 32  # header


def raw_zstd_size_bytes(arr: np.ndarray, aux_bytes: int = 0) -> int:
    """zstd over raw array bytes (Bit Grooming / Digit Rounding path)."""
    return zstd_bytes(np.asarray(arr).tobytes()) + aux_bytes + 32


# ---------------------------------------------------------------------------
# Jittable size model (first-order entropy), for in-graph decisions
# ---------------------------------------------------------------------------

def entropy_size_bits(codes: jnp.ndarray, num_bins: int = 4096) -> jnp.ndarray:
    """Idealized entropy-coded size in bits for integer codes (jittable)."""
    flat = codes.reshape(-1)
    idx = (flat - jnp.min(flat)) % num_bins
    counts = jnp.zeros((num_bins,), jnp.int32).at[idx].add(1)
    n = flat.shape[0]
    p = counts / n
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))
    return h * n
