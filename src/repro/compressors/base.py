"""Compressor API + registry.

Every compressor exposes:
  * ``encode(data, eps)   -> (codes, aux)``   jittable decorrelate+quantize
  * ``decode(codes, aux, eps) -> recon``      jittable reconstruction
  * ``size_bytes(codes, aux, eps) -> int``    host-side real byte count
                                              (zstd-backed entropy stage)
  * ``cr(data, eps) -> float``                original_bytes / compressed

The decorrelation/quantization stages run in JAX (TPU-lowera­ble, some with
Pallas kernels); the final entropy-coding stage is host-side (zstandard),
exactly mirroring real compressor pipelines (SZ: Huffman+zstd, MGARD: zlib/
zstd, Bit Grooming: generic lossless coder).  CR labels used to train the
paper's regressions are therefore *real measured ratios*.
"""
from __future__ import annotations

import abc
from typing import Any, Dict, Tuple

import numpy as np
import jax.numpy as jnp


class Compressor(abc.ABC):
    name: str = "base"
    supports_3d: bool = True

    @abc.abstractmethod
    def encode(self, data: jnp.ndarray, eps: float) -> Tuple[Any, Dict[str, Any]]:
        ...

    @abc.abstractmethod
    def decode(self, codes: Any, aux: Dict[str, Any], eps: float) -> jnp.ndarray:
        ...

    @abc.abstractmethod
    def size_bytes(self, codes: Any, aux: Dict[str, Any], eps: float) -> int:
        ...

    # ------------------------------------------------------------------
    def cr(self, data: jnp.ndarray, eps: float) -> float:
        """Measured compression ratio (original fp32 bytes / compressed)."""
        codes, aux = self.encode(data, eps)
        size = self.size_bytes(codes, aux, eps)
        return float(data.size * 4) / max(size, 1)

    def roundtrip_error(self, data: jnp.ndarray, eps: float) -> float:
        codes, aux = self.encode(data, eps)
        recon = self.decode(codes, aux, eps)
        return float(jnp.max(jnp.abs(recon - data)))


def error_bound_slack(data: jnp.ndarray) -> float:
    """fp32 representability floor for quantizer-grid reconstructions.

    Reconstruction values fl(q * 2eps) are spaced 2eps +- 1 ulp(|d|) apart, so
    the best achievable max error is eps + ulp/2: for |d| >> eps no integer
    code can do better.  Real SZ escapes this by storing such points verbatim
    ('unpredictable values'); our branch-free parallel quantizer accepts the
    floor instead (documented in DESIGN.md).  Tests assert
    err <= eps + error_bound_slack(data).
    """
    return float(jnp.max(jnp.abs(data))) * 2.0 ** -23


_REGISTRY: Dict[str, Compressor] = {}


def register(comp: Compressor) -> Compressor:
    _REGISTRY[comp.name] = comp
    return comp


def get(name: str) -> Compressor:
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def all_compressors() -> Dict[str, Compressor]:
    return dict(_REGISTRY)
