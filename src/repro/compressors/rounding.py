"""Rounding-based compressors: Bit Grooming and Digit Rounding.

Both operate on IEEE-754 mantissas and rely on a downstream lossless coder
(zstd here) -- they have no spatial decorrelation step, which is exactly why
the paper finds the *quantized entropy* dominates their CR prediction.

Absolute-error-bound operation follows the paper's OptZConfig mapping: the
number of mantissa bits kept is derived from the requested eps.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.compressors import base, lossless


def _bits(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def _floats(b: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(b.astype(jnp.uint32), jnp.float32)


class BitGrooming(base.Compressor):
    """Zender 2016: alternately shave (to 0) and set (to 1) insignificant
    mantissa bits; the number of kept bits is global, derived from eps and
    the field's max exponent (OptZConfig absolute-bound mapping)."""
    name = "bitgrooming"

    def _mask_bits(self, data: jnp.ndarray, eps: float) -> jnp.ndarray:
        amax = jnp.max(jnp.abs(data))
        emax = jnp.floor(jnp.log2(jnp.maximum(amax, 1e-38)))
        # masking k low mantissa bits of a value with exponent e gives
        # error < 2^(e-23+k); bound by worst-case exponent emax.
        k = jnp.clip(
            23 + jnp.floor(jnp.log2(eps)) - emax, 0, 23
        ).astype(jnp.uint32)
        return k

    def encode(self, data, eps):
        data = data.astype(jnp.float32)
        k = self._mask_bits(data, eps)
        b = _bits(data)
        mask = (~jnp.uint32(0)) << k
        flat_idx = jnp.arange(data.size).reshape(data.shape)
        shave = (b & mask)
        setb = (b | (~mask))
        groomed = jnp.where(flat_idx % 2 == 0, shave, setb)
        # keep exact zeros exact (grooming convention)
        groomed = jnp.where(b == 0, b, groomed)
        return _floats(groomed), {"shape": data.shape, "keepbits": k}

    def decode(self, codes, aux, eps):
        return codes

    def size_bytes(self, codes, aux, eps):
        return lossless.raw_zstd_size_bytes(np.asarray(codes))


class DigitRounding(base.Compressor):
    """Delaunay et al. 2018: round (not truncate) to the eps-determined
    binary digit -- equivalent to rounding onto a power-of-two grid."""
    name = "digitrounding"

    def encode(self, data, eps):
        data = data.astype(jnp.float32)
        step = jnp.exp2(jnp.floor(jnp.log2(eps)))  # largest pow2 <= eps
        rounded = jnp.round(data / step) * step
        return rounded.astype(jnp.float32), {"shape": data.shape}

    def decode(self, codes, aux, eps):
        return codes

    def size_bytes(self, codes, aux, eps):
        return lossless.raw_zstd_size_bytes(np.asarray(codes))


base.register(BitGrooming())
base.register(DigitRounding())
