"""SZ-family error-bounded lossy compressors (prediction-based decorrelation).

Three compressor-prediction schemes (paper section 4.2):
  * Lorenzo (SZ1/SZ3-lorenzo)      -- immediate-neighbour stencil predictor
  * Regression (SZ2/SZ3-regression)-- per 6x6(x6) block hyperplane fit
  * Interpolation (SZ3-interp)     -- multilevel cubic interpolation
plus SZ2's *dynamic* per-block selection between Lorenzo and regression.

TPU adaptation: classic SZ predicts from *reconstructed* neighbours, a
sequential data dependence.  We use the cuSZ dual-quantization formulation
for Lorenzo -- pre-quantize every value, then difference the integer codes --
which preserves the absolute error bound exactly and is fully parallel
(maps to the Pallas stencil kernel in ``repro.kernels.lorenzo``).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.compressors import base, lossless

BLOCK = 6  # SZ2 block size


# ---------------------------------------------------------------------------
# Dual-quantization Lorenzo (N-D)
# ---------------------------------------------------------------------------

def quantize_bounded(vals: jnp.ndarray, eps: float | jnp.ndarray) -> jnp.ndarray:
    """Integer codes q with |vals - 2*eps*q| <= eps *exactly*.

    ``round(vals / (2 eps))`` alone can flip a boundary by one ulp of the
    scaled value; real SZ handles this with an unpredictable-value check.
    We instead nudge the code by +-1 where the bound is violated -- exact,
    branch-free and parallel (same trick the Pallas kernel uses).
    """
    q = jnp.round(vals / (2.0 * eps)).astype(jnp.int32)
    for _ in range(2):  # two rounds: the nudge itself re-rounds the product
        # The barrier pins the reconstruction to the exact f32 product the
        # decoder will produce (prevents XLA from FMA-fusing the subtract,
        # which would evaluate the check at higher precision than decode).
        recon = jax.lax.optimization_barrier(
            q.astype(jnp.float32) * (2.0 * eps))
        err = vals - recon
        q = q + (err > eps).astype(jnp.int32) - (err < -eps).astype(jnp.int32)
    return q


@partial(jax.jit, static_argnames=())
def _prequant(data: jnp.ndarray, eps: float | jnp.ndarray) -> jnp.ndarray:
    return quantize_bounded(data, eps)


def lorenzo_encode(data: jnp.ndarray, eps: float) -> jnp.ndarray:
    """codes = prod_axis (1 - S_axis) q  (N-D integer Lorenzo difference)."""
    q = _prequant(data, eps)
    for axis in range(data.ndim):
        shifted = jnp.roll(q, 1, axis=axis)
        # zero out the wrapped-around first slice
        idx = [slice(None)] * data.ndim
        idx[axis] = slice(0, 1)
        shifted = shifted.at[tuple(idx)].set(0)
        q = q - shifted
    return q


def lorenzo_decode(codes: jnp.ndarray, eps: float) -> jnp.ndarray:
    q = codes
    for axis in range(codes.ndim):
        q = jnp.cumsum(q, axis=axis)
    return q.astype(jnp.float32) * (2.0 * eps)


# ---------------------------------------------------------------------------
# Blockwise helpers
# ---------------------------------------------------------------------------

def _pad_to_multiple(data: jnp.ndarray, b: int) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    pads = []
    for s in data.shape:
        r = (-s) % b
        pads.append((0, r))
    return jnp.pad(data, pads, mode="edge"), data.shape


def _to_blocks(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """2-D (M,N) -> (nb, b, b); 3-D (M,N,K) -> (nb, b, b, b)."""
    if x.ndim == 2:
        m, n = x.shape
        x = x.reshape(m // b, b, n // b, b).transpose(0, 2, 1, 3)
        return x.reshape(-1, b, b)
    m, n, k = x.shape
    x = x.reshape(m // b, b, n // b, b, k // b, b).transpose(0, 2, 4, 1, 3, 5)
    return x.reshape(-1, b, b, b)


def _from_blocks(blocks: jnp.ndarray, padded_shape: Tuple[int, ...], b: int) -> jnp.ndarray:
    if len(padded_shape) == 2:
        m, n = padded_shape
        x = blocks.reshape(m // b, n // b, b, b).transpose(0, 2, 1, 3)
        return x.reshape(m, n)
    m, n, k = padded_shape
    x = blocks.reshape(m // b, n // b, k // b, b, b, b).transpose(0, 3, 1, 4, 2, 5)
    return x.reshape(m, n, k)


def _block_coords(b: int, ndim: int) -> jnp.ndarray:
    """Design matrix [1, i, j(, k)] for hyperplane regression: (b^ndim, ndim+1)."""
    axes = [jnp.arange(b, dtype=jnp.float32)] * ndim
    grids = jnp.meshgrid(*axes, indexing="ij")
    cols = [jnp.ones((b,) * ndim, jnp.float32)] + grids
    return jnp.stack([c.reshape(-1) for c in cols], axis=1)


def _fit_planes(blocks: jnp.ndarray) -> jnp.ndarray:
    """Least-squares hyperplane per block: (nb, b..b) -> (nb, ndim+1)."""
    ndim = blocks.ndim - 1
    b = blocks.shape[1]
    x = _block_coords(b, ndim)                       # (p, ndim+1)
    y = blocks.reshape(blocks.shape[0], -1)          # (nb, p)
    pinv = jnp.linalg.pinv(x)                        # (ndim+1, p)
    return y @ pinv.T                                # (nb, ndim+1)


def _plane_values(coefs: jnp.ndarray, b: int, ndim: int) -> jnp.ndarray:
    x = _block_coords(b, ndim)                       # (p, ndim+1)
    return (coefs @ x.T).reshape(coefs.shape[0], *([b] * ndim))


# ---------------------------------------------------------------------------
# Per-block Lorenzo (parallel across blocks; used by SZ2's dynamic mode)
# ---------------------------------------------------------------------------

def _block_lorenzo_codes(qblocks: jnp.ndarray) -> jnp.ndarray:
    """Integer Lorenzo difference within each block (halo-free blocks)."""
    q = qblocks
    ndim = q.ndim - 1
    for axis in range(1, ndim + 1):
        shifted = jnp.roll(q, 1, axis=axis)
        idx = [slice(None)] * q.ndim
        idx[axis] = slice(0, 1)
        shifted = shifted.at[tuple(idx)].set(0)
        q = q - shifted
    return q


def _block_lorenzo_decode(codes: jnp.ndarray) -> jnp.ndarray:
    q = codes
    ndim = q.ndim - 1
    for axis in range(1, ndim + 1):
        q = jnp.cumsum(q, axis=axis)
    return q


# ---------------------------------------------------------------------------
# Compressors
# ---------------------------------------------------------------------------

class SZLorenzo(base.Compressor):
    """SZ3 with the exclusive Lorenzo scheme (dual-quantization form)."""
    name = "sz3-lorenzo"

    def encode(self, data, eps):
        return lorenzo_encode(data, eps), {"shape": data.shape}

    def decode(self, codes, aux, eps):
        return lorenzo_decode(codes, eps)

    def size_bytes(self, codes, aux, eps):
        return lossless.coded_size_bytes(np.asarray(codes))


class SZRegression(base.Compressor):
    """SZ3 with the exclusive regression scheme (per-block hyperplane)."""
    name = "sz3-regression"

    def encode(self, data, eps):
        padded, shape = _pad_to_multiple(data, BLOCK)
        blocks = _to_blocks(padded, BLOCK)
        coefs = _fit_planes(blocks)
        # SZ2 quantizes regression coefficients; we store them quantized with
        # a fine bin (eps/BLOCK keeps plane-eval error within eps/2).
        cq = jnp.round(coefs / (eps / BLOCK)).astype(jnp.int32)
        planes = _plane_values(cq.astype(jnp.float32) * (eps / BLOCK), BLOCK, data.ndim)
        resid = blocks - planes
        codes = quantize_bounded(resid, eps)
        return codes, {"shape": shape, "padded": padded.shape, "coef_codes": cq}

    def decode(self, codes, aux, eps):
        cq = aux["coef_codes"]
        ndim = len(aux["shape"])
        planes = _plane_values(cq.astype(jnp.float32) * (eps / BLOCK), BLOCK, ndim)
        blocks = planes + codes.astype(jnp.float32) * (2.0 * eps)
        full = _from_blocks(blocks, aux["padded"], BLOCK)
        sl = tuple(slice(0, s) for s in aux["shape"])
        return full[sl]

    def size_bytes(self, codes, aux, eps):
        resid = lossless.coded_size_bytes(np.asarray(codes))
        coefb = lossless.coded_size_bytes(np.asarray(aux["coef_codes"]))
        return resid + coefb


class SZInterp(base.Compressor):
    """SZ3 with the multilevel cubic-interpolation scheme (2-D)."""
    name = "sz3-interp"
    supports_3d = False
    levels = 3

    # -- 1-D cubic interpolation of odd positions from even positions -------
    @staticmethod
    def _interp_odd(even: jnp.ndarray, n_odd: int, axis: int) -> jnp.ndarray:
        """Predict values at odd indices from the even-index samples along
        ``axis`` with a 4-point cubic (falls back to linear at the edges)."""
        e = jnp.moveaxis(even, axis, 0)
        ne = e.shape[0]
        # neighbours e[i], e[i+1] surround odd point i; cubic uses i-1..i+2
        em1 = jnp.concatenate([e[:1], e[:-1]], axis=0)
        ep1 = jnp.concatenate([e[1:], e[-1:]], axis=0)
        ep2 = jnp.concatenate([e[2:], e[-1:], e[-1:]], axis=0)
        cubic = (-em1 + 9.0 * e + 9.0 * ep1 - ep2) / 16.0
        pred = cubic[:n_odd]
        return jnp.moveaxis(pred, 0, axis)

    def _encode_rec(self, data, eps, levels_left: int):
        """Recursive multilevel encode; predictions are made from
        *reconstructed* values so the bound holds exactly at every level.

        Returns (codes_tree, recon).
        """
        m, n = data.shape
        if levels_left == 0 or min(m, n) < 8:
            root = quantize_bounded(data, eps)
            return ("root", root), root.astype(jnp.float32) * (2.0 * eps)
        half = data[:, 0::2]                 # even columns (original)
        coarse = half[0::2, :]               # even rows of even cols
        sub_codes, recon_coarse = self._encode_rec(coarse, eps, levels_left - 1)
        # rows: predict odd rows of `half` from reconstructed coarse
        pred_r = self._interp_odd(recon_coarse, half[1::2, :].shape[0], axis=0)
        codes_r = quantize_bounded(half[1::2, :] - pred_r, eps)
        recon_half = jnp.zeros_like(half)
        recon_half = recon_half.at[0::2, :].set(recon_coarse)
        recon_half = recon_half.at[1::2, :].set(
            pred_r + codes_r.astype(jnp.float32) * (2.0 * eps))
        # cols: predict odd columns of `data` from reconstructed half
        pred_c = self._interp_odd(recon_half, data[:, 1::2].shape[1], axis=1)
        codes_c = quantize_bounded(data[:, 1::2] - pred_c, eps)
        recon = jnp.zeros_like(data)
        recon = recon.at[:, 0::2].set(recon_half)
        recon = recon.at[:, 1::2].set(
            pred_c + codes_c.astype(jnp.float32) * (2.0 * eps))
        return ("level", sub_codes, codes_c, codes_r, (m, n)), recon

    def encode(self, data, eps):
        codes, _ = self._encode_rec(data.astype(jnp.float32), eps, self.levels)
        return codes, {"shape": data.shape}

    def _decode_rec(self, codes, eps):
        if codes[0] == "root":
            return codes[1].astype(jnp.float32) * (2.0 * eps)
        _, sub_codes, codes_c, codes_r, (m, n) = codes
        recon_coarse = self._decode_rec(sub_codes, eps)
        half = jnp.zeros((m, (n + 1) // 2), jnp.float32)
        half = half.at[0::2, :].set(recon_coarse)
        pred_r = self._interp_odd(recon_coarse, codes_r.shape[0], axis=0)
        half = half.at[1::2, :].set(
            pred_r + codes_r.astype(jnp.float32) * (2.0 * eps))
        out = jnp.zeros((m, n), jnp.float32)
        out = out.at[:, 0::2].set(half)
        pred_c = self._interp_odd(half, codes_c.shape[1], axis=1)
        out = out.at[:, 1::2].set(
            pred_c + codes_c.astype(jnp.float32) * (2.0 * eps))
        return out

    def decode(self, codes, aux, eps):
        return self._decode_rec(codes, eps)

    def size_bytes(self, codes, aux, eps):
        if codes[0] == "root":
            return lossless.coded_size_bytes(np.asarray(codes[1]))
        _, sub_codes, codes_c, codes_r, _ = codes
        return (self.size_bytes(sub_codes, aux, eps)
                + lossless.coded_size_bytes(np.asarray(codes_c))
                + lossless.coded_size_bytes(np.asarray(codes_r)))


class SZ2(base.Compressor):
    """SZ2: dynamic per-block selection between Lorenzo and regression.

    Mirrors SZ2's sampling-based scheme choice: per block, both predictors
    are evaluated and the one with the smaller absolute residual mass (a
    monotone proxy for the coded entropy) wins.  One flag bit per block.
    """
    name = "sz2"

    def encode(self, data, eps):
        padded, shape = _pad_to_multiple(data, BLOCK)
        blocks = _to_blocks(padded, BLOCK)
        ndim = data.ndim
        # Lorenzo path (per block, dual quantization)
        q = quantize_bounded(blocks, eps)
        lor_codes = _block_lorenzo_codes(q)
        # Regression path
        coefs = _fit_planes(blocks)
        cq = jnp.round(coefs / (eps / BLOCK)).astype(jnp.int32)
        planes = _plane_values(cq.astype(jnp.float32) * (eps / BLOCK), BLOCK, ndim)
        reg_codes = quantize_bounded(blocks - planes, eps)
        # Choice: smaller |codes| mass (entropy proxy); regression also pays
        # for its coefficients (~ (ndim+1)*2 bytes -> ~ 8 code units).
        axes = tuple(range(1, ndim + 1))
        lor_cost = jnp.sum(jnp.minimum(jnp.abs(lor_codes), 255), axis=axes)
        reg_cost = jnp.sum(jnp.minimum(jnp.abs(reg_codes), 255), axis=axes) + 4 * (ndim + 1)
        use_reg = reg_cost < lor_cost
        sel = jnp.where(use_reg[(...,) + (None,) * ndim], reg_codes, lor_codes)
        return sel, {
            "shape": shape, "padded": padded.shape, "use_reg": use_reg,
            "coef_codes": cq,
        }

    def decode(self, codes, aux, eps):
        ndim = len(aux["shape"])
        use_reg = aux["use_reg"]
        cq = aux["coef_codes"]
        planes = _plane_values(cq.astype(jnp.float32) * (eps / BLOCK), BLOCK, ndim)
        reg_blocks = planes + codes.astype(jnp.float32) * (2.0 * eps)
        lor_blocks = _block_lorenzo_decode(codes).astype(jnp.float32) * (2.0 * eps)
        blocks = jnp.where(use_reg[(...,) + (None,) * ndim], reg_blocks, lor_blocks)
        full = _from_blocks(blocks, aux["padded"], BLOCK)
        sl = tuple(slice(0, s) for s in aux["shape"])
        return full[sl]

    def size_bytes(self, codes, aux, eps):
        total = lossless.coded_size_bytes(np.asarray(codes))
        use_reg = np.asarray(aux["use_reg"])
        total += int(np.ceil(use_reg.size / 8))  # 1 flag bit / block
        cq = np.asarray(aux["coef_codes"])[use_reg]  # only coded when chosen
        if cq.size:
            total += lossless.coded_size_bytes(cq)
        return total

    def regression_fraction(self, data, eps) -> float:
        """Fraction of blocks choosing regression (paper section 4.2 stat)."""
        _, aux = self.encode(data, eps)
        return float(jnp.mean(aux["use_reg"].astype(jnp.float32)))


base.register(SZLorenzo())
base.register(SZRegression())
base.register(SZInterp())
base.register(SZ2())
