"""ZFP-like transform compressor (fixed 4^n blocks, near-orthogonal lifting).

Pipeline per 4x4(x4) block (Lindstrom 2014):
  1. block-floating-point: align all values to the block's max exponent,
  2. integer forward lifting transform along each dimension,
  3. embedded bit-plane coding down to an eps-determined cutoff plane.

The integer lifting pair below is the exact fwd/inv lift from the zfp
codebase (arithmetic shifts on int32).  Size is computed from the bit-plane
cutoff analytically -- zfp's output is already entropy-packed, so no zstd
stage.  The forward transform has a Pallas kernel in
``repro.kernels.zfp_block``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.compressors import base, lossless

INTPREC = 26          # fixed-point precision for fp32 inputs


def _guard_bits(ndim: int) -> int:
    """Transform-gain guard: the inverse lifting amplifies per-coefficient
    truncation error by < 2^(1+ndim) in the worst case (measured + margin)."""
    return 1 + ndim


# ---------------------------------------------------------------------------
# Exact zfp integer lifting (4-vectors)
# ---------------------------------------------------------------------------

def fwd_lift4(v: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Forward lift along ``axis`` (length 4), int32 arithmetic shifts."""
    x, y, z, w = jnp.moveaxis(v, axis, 0)
    x = x + w; x = x >> 1; w = w - x
    z = z + y; z = z >> 1; y = y - z
    x = x + z; x = x >> 1; z = z - x
    w = w + y; w = w >> 1; y = y - w
    w = w + (y >> 1); y = y - (w >> 1)
    return jnp.moveaxis(jnp.stack([x, y, z, w]), 0, axis)


def inv_lift4(v: jnp.ndarray, axis: int) -> jnp.ndarray:
    x, y, z, w = jnp.moveaxis(v, axis, 0)
    y = y + (w >> 1); w = w - (y >> 1)
    y = y + w; w = w << 1; w = w - y
    z = z + x; x = x << 1; x = x - z
    y = y + z; z = z << 1; z = z - y
    w = w + x; x = x << 1; x = x - w
    return jnp.moveaxis(jnp.stack([x, y, z, w]), 0, axis)


# ---------------------------------------------------------------------------
# Blocking
# ---------------------------------------------------------------------------

def _pad4(data: jnp.ndarray):
    pads = [(0, (-s) % 4) for s in data.shape]
    return jnp.pad(data, pads, mode="edge"), data.shape


def _to_blocks4(x: jnp.ndarray) -> jnp.ndarray:
    if x.ndim == 2:
        m, n = x.shape
        return x.reshape(m // 4, 4, n // 4, 4).transpose(0, 2, 1, 3).reshape(-1, 4, 4)
    m, n, k = x.shape
    return (x.reshape(m // 4, 4, n // 4, 4, k // 4, 4)
             .transpose(0, 2, 4, 1, 3, 5).reshape(-1, 4, 4, 4))


def _from_blocks4(blocks: jnp.ndarray, padded_shape) -> jnp.ndarray:
    if len(padded_shape) == 2:
        m, n = padded_shape
        return (blocks.reshape(m // 4, n // 4, 4, 4)
                .transpose(0, 2, 1, 3).reshape(m, n))
    m, n, k = padded_shape
    return (blocks.reshape(m // 4, n // 4, k // 4, 4, 4, 4)
            .transpose(0, 3, 1, 4, 2, 5).reshape(m, n, k))


# ---------------------------------------------------------------------------
# Encode / decode
# ---------------------------------------------------------------------------

def zfp_transform(data: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, Tuple]:
    """Blocked block-floating-point + forward lifting.

    Returns (coeff int32 blocks, per-block exponent, padded_shape).
    """
    padded, shape = _pad4(data)
    blocks = _to_blocks4(padded.astype(jnp.float32))
    ndim = blocks.ndim - 1
    axes = tuple(range(1, ndim + 1))
    amax = jnp.max(jnp.abs(blocks), axis=axes)
    # block exponent e: 2^e >= amax  (frexp-style)
    e = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-38))).astype(jnp.int32)
    e = jnp.where(amax > 0, e, 0)
    scale = jnp.exp2((INTPREC - 2 - e).astype(jnp.float32))
    q = jnp.round(blocks * scale[(...,) + (None,) * ndim]).astype(jnp.int32)
    for axis in range(1, ndim + 1):
        q = fwd_lift4(q, axis)
    return q, e, padded.shape


def zfp_untransform(q: jnp.ndarray, e: jnp.ndarray, padded_shape, shape) -> jnp.ndarray:
    ndim = q.ndim - 1
    for axis in range(ndim, 0, -1):
        q = inv_lift4(q, axis)
    scale = jnp.exp2((e - (INTPREC - 2)).astype(jnp.float32))
    blocks = q.astype(jnp.float32) * scale[(...,) + (None,) * ndim]
    full = _from_blocks4(blocks, padded_shape)
    return full[tuple(slice(0, s) for s in shape)]


def _cutoff_plane(e: jnp.ndarray, eps: float, ndim: int) -> jnp.ndarray:
    """Integer bit-plane below which coefficients are dropped.

    LSB of the fixed-point representation is worth 2^(e - (INTPREC-2));
    dropping planes < k introduces error <= 2^k * lsb * transform gain.
    """
    lsb_log2 = e - (INTPREC - 2)
    k = jnp.floor(jnp.log2(eps)).astype(jnp.int32) - lsb_log2 - _guard_bits(ndim)
    return k  # may be negative -> keep everything


def zfp_truncate(q: jnp.ndarray, e: jnp.ndarray, eps: float) -> jnp.ndarray:
    ndim = q.ndim - 1
    k = jnp.maximum(_cutoff_plane(e, eps, ndim), 0)[(...,) + (None,) * ndim]
    step = (jnp.int32(1) << k)
    # round-to-nearest at plane k keeps the bound tight
    half = step >> 1
    return jnp.where(
        step > 1,
        jnp.sign(q) * (((jnp.abs(q) + half) >> k) << k),
        q,
    )


def zfp_size_bits(q: jnp.ndarray, e: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Embedded-coding size model: per coefficient, bits above the cutoff
    plane + sign, plus per-block header (exponent + group tests)."""
    ndim = q.ndim - 1
    k = jnp.maximum(_cutoff_plane(e, eps, ndim), 0)[(...,) + (None,) * ndim]
    mag = jnp.abs(q)
    bitlen = jnp.where(mag > 0, jnp.ceil(jnp.log2(mag.astype(jnp.float32) + 1.0)), 0.0)
    kept = jnp.maximum(bitlen - k.astype(jnp.float32), 0.0)
    signs = (kept > 0).astype(jnp.float32)
    per_block = jnp.sum(kept + signs, axis=tuple(range(1, ndim + 1)))
    header = 8.0 + 2.0 * (4 ** ndim) / 4.0  # exponent + group-test bits
    return jnp.sum(per_block + header)


class ZFP(base.Compressor):
    name = "zfp"

    def encode(self, data, eps):
        q, e, padded_shape = zfp_transform(data)
        qt = zfp_truncate(q, e, eps)
        return qt, {"e": e, "padded": padded_shape, "shape": data.shape}

    def decode(self, codes, aux, eps):
        return zfp_untransform(codes, aux["e"], aux["padded"], aux["shape"])

    def size_bytes(self, codes, aux, eps):
        bits = float(zfp_size_bits(codes, aux["e"], eps))
        return int(np.ceil(bits / 8.0))


base.register(ZFP())
