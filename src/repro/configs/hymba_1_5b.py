"""hymba-1.5b: 32L d_model=1600 25H (GQA kv=5) d_ff=5504, parallel
attn+mamba heads, ssm_state=16, SWA + 3 global layers, 128 meta tokens
[arXiv:2411.13676; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    window_size=1024, num_global_layers=3, meta_tokens=128,
    sliding_window_decode=True,
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, ssm_state=8,
        ssm_head_dim=16, window_size=32, num_global_layers=1,
        meta_tokens=8, ssm_chunk=16)
