"""Architecture configs (one per assigned arch) + shape cells."""
from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES,
                                ARCH_IDS, get_arch, get_smoke)
