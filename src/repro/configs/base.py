"""Model / shape / run configuration schema.

Every assigned architecture is a ``ModelConfig`` (exact published dims) in
``repro/configs/<id>.py``; each also provides a reduced ``smoke()`` variant
for CPU tests.  ``ShapeConfig`` encodes the assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | mla_moe | encdec | ssm | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention details
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0      # stablelm uses 0.25
    qkv_bias: bool = False           # qwen-style
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (if != d_ff)
    dense_first_layer: bool = False  # deepseek-v2: layer 0 is dense
    capacity_factor: float = 1.25
    # MLA (deepseek-v2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500       # stubbed conv-frontend output length
    # hybrid (hymba)
    window_size: int = 0             # sliding-window attention width (0=full)
    num_global_layers: int = 0       # full-attention layers in a SWA model
    meta_tokens: int = 0             # hymba learnable prefix
    # vlm (qwen2-vl)
    mrope_sections: Tuple[int, ...] = ()
    # numerics
    dtype: str = "bfloat16"
    # serving
    sliding_window_decode: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over the mesh."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM state or SWA)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        from repro.models import model as M
        return M.count_params(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1            # gradient-accumulation steps (train)


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "stablelm-3b", "codeqwen1.5-7b", "granite-8b", "granite-3-2b",
    "phi3.5-moe-42b-a6.6b", "deepseek-v2-236b", "whisper-large-v3",
    "mamba2-370m", "qwen2-vl-72b", "hymba-1.5b",
]


def get_arch(arch_id: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.smoke()
