"""granite-3-2b: 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
GQA [hf:ibm-granite/granite-3.0-2b-base; hf].  head_dim=64 (32H x 64 =
2048 = d_model)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=49155,
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=300)
