"""qwen2-vl-72b: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064,
M-RoPE sections (16,24,24), dynamic-resolution vision frontend STUBBED
[arXiv:2409.12191; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, qkv_bias=True, rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, mrope_sections=(2, 3, 3))
