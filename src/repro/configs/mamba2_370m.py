"""mamba2-370m: 48L d_model=1024 attn-free, ssm_state=128, SSD
[arXiv:2405.21060; unverified].  d_inner=2048, 32 heads x P=64, 1 group."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=32, num_kv_heads=32,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
