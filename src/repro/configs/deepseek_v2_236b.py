"""deepseek-v2-236b: 60L d_model=5120 128H MLA (kv_lora=512, rope 64,
nope/v head dims 128) d_ff=1536 per routed expert; 2 shared + 160 routed
top-6; dense first layer (d_ff=12288); vocab=102400 [arXiv:2405.04434; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="mla_moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab_size=102400, head_dim=128,
    num_experts=160, experts_per_token=6, num_shared_experts=2,
    moe_d_ff=1536, dense_first_layer=True,
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=64, vocab_size=256,
        num_experts=8, experts_per_token=2, num_shared_experts=1,
        moe_d_ff=64, q_lora_rank=32, kv_lora_rank=32,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
