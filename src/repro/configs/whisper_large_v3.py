"""whisper-large-v3: enc-dec, 32+32L d_model=1280 20H d_ff=5120
vocab=51866 [arXiv:2212.04356; unverified].  Conv frontend STUBBED:
input_specs provides precomputed 1500-frame embeddings."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, encoder_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=51866,
    encoder_frames=1500,
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, encoder_frames=64)
