"""Shared quantization plumbing: code-range saturation + eps validation.

One home for the constants and checks that BOTH quantization routes (the
jnp sort/histogram paths in ``repro.core.predictors`` and the Pallas
kernel route in ``repro.kernels.qent``) must agree on exactly -- the
sharded-equivalence gates depend on the routes staying bit-identical.
Leaf module: imports nothing from core/kernels/dist.
"""
from __future__ import annotations

import numpy as np
import jax

# floor(x/eps) is clamped to this f32-representable sub-range of int32
# before any cast: the largest float32 not exceeding 2^31 - 1 is
# 2147483520.0, so casting the clamped value can never wrap (a wrapped
# code would corrupt run-length / histogram entropies).
INT32_CODE_MIN = -2147483648.0
INT32_CODE_MAX = 2147483520.0


def validate_eps_positive(epss) -> None:
    """Reject non-positive / non-finite error bounds at trace boundaries.

    Only applies to concrete values: inside jit the caller's public entry
    point has already validated (tracers carry no values to check).  The
    check runs in numpy -- it sits on per-probe hot paths (UC1 bisection),
    where a jnp check would add a device dispatch + host sync per call.
    """
    # tree_leaves catches tracers however they arrive: bare, or wrapped
    # in a list/tuple like the engine's features(slices, eps) -> [eps]
    if any(isinstance(leaf, jax.core.Tracer)
           for leaf in jax.tree_util.tree_leaves(epss)):
        return
    arr = np.asarray(epss)
    if arr.size and not bool(np.all(np.isfinite(arr) & (arr > 0))):
        raise ValueError(
            f"error bounds must be positive and finite, got {arr}; "
            "an eps <= 0 makes floor(x/eps) ill-defined")
