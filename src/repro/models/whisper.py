"""Whisper-large-v3 backbone: encoder-decoder transformer.

The conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed mel-frame embeddings (B, encoder_frames, d_model).  Encoder:
bidirectional self-attention; decoder: causal self-attention +
cross-attention to the encoder memory.  Pre-LayerNorm, GELU MLPs, biased
projections (Whisper convention).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models.params import ParamDef

Params = Dict[str, Any]


def _ln(n, cfg, names):
    t = {}
    for k in names:
        t[f"{k}_g"] = ParamDef((n, cfg.d_model), ("layers", None), init="ones")
        t[f"{k}_b"] = ParamDef((n, cfg.d_model), ("layers", None), init="zeros")
    return t


def _attn(n, cfg, prefix=""):
    d, hq, hd = cfg.d_model, cfg.num_heads, cfg.hd
    return {
        f"{prefix}wq": ParamDef((n, d, hq * hd), ("layers", "fsdp", "model")),
        f"{prefix}wk": ParamDef((n, d, hq * hd), ("layers", "fsdp", "model")),
        f"{prefix}wv": ParamDef((n, d, hq * hd), ("layers", "fsdp", "model")),
        f"{prefix}wo": ParamDef((n, hq * hd, d), ("layers", "model", "fsdp")),
        f"{prefix}bq": ParamDef((n, hq * hd), ("layers", "model"), init="zeros"),
        f"{prefix}bv": ParamDef((n, hq * hd), ("layers", "model"), init="zeros"),
        f"{prefix}bo": ParamDef((n, d), ("layers", None), init="zeros"),
    }


def _mlp(n, cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": ParamDef((n, d, f), ("layers", "fsdp", "model")),
        "b1": ParamDef((n, f), ("layers", "model"), init="zeros"),
        "w2": ParamDef((n, f, d), ("layers", "model", "fsdp")),
        "b2": ParamDef((n, d), ("layers", None), init="zeros"),
    }


def param_table(cfg: ModelConfig) -> Params:
    v = cfg.padded_vocab
    ne, nd = cfg.encoder_layers, cfg.num_layers
    return {
        "embed": ParamDef((v, cfg.d_model), (None, "model")),
        "pos_dec": ParamDef((8192, cfg.d_model), (None, "fsdp")),
        "pos_enc": ParamDef((cfg.encoder_frames, cfg.d_model), (None, "fsdp")),
        "enc": {**_attn(ne, cfg), **_mlp(ne, cfg), **_ln(ne, cfg, ["ln1", "ln2"])},
        "dec": {**_attn(nd, cfg), **_attn(nd, cfg, "x_"), **_mlp(nd, cfg),
                **_ln(nd, cfg, ["ln1", "lnx", "ln2"])},
        "enc_norm_g": ParamDef((cfg.d_model,), (None,), init="ones"),
        "enc_norm_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "final_g": ParamDef((cfg.d_model,), (None,), init="ones"),
        "final_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "lm_head": ParamDef((cfg.d_model, v), ("fsdp", "model")),
    }


class WhisperCache(NamedTuple):
    k: jnp.ndarray            # (nd, B, T, H, hd) decoder self-attn
    v: jnp.ndarray
    pos: jnp.ndarray          # (nd, B, T)
    xk: jnp.ndarray           # (nd, B, F, H, hd) cross-attn (fixed)
    xv: jnp.ndarray


def _mha(x, p, cfg, prefix="", kv: Optional[Tuple] = None, causal=True,
         cache=None, pos_offset=0):
    """Whisper MHA (no GQA, biased q/v).  kv: override source (cross-attn)."""
    b, s, _ = x.shape
    hq, hd = cfg.num_heads, cfg.hd
    src = kv[0] if kv is not None else x
    q = (jnp.einsum("bsd,dk->bsk", x, p[f"{prefix}wq"]) + p[f"{prefix}bq"])
    if kv is not None and len(kv) == 3:      # precomputed k, v (decode cross)
        k, v = kv[1], kv[2]
    else:
        k = jnp.einsum("bsd,dk->bsk", src, p[f"{prefix}wk"])
        v = (jnp.einsum("bsd,dk->bsk", src, p[f"{prefix}wv"]) + p[f"{prefix}bv"])
        k = k.reshape(b, -1, hq, hd)
        v = v.reshape(b, -1, hq, hd)
    q = q.reshape(b, s, hq, hd)
    new_cache = None
    if cache is not None:                    # cached self-attn (prefill/decode)
        ck, cv, cpos = cache
        slot = jnp.asarray(pos_offset) % ck.shape[1]
        pos_blk = (pos_offset + jnp.arange(s, dtype=jnp.int32))[None, :]
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cpos, jnp.broadcast_to(pos_blk, (b, s)), (0, slot))
        if s == 1:
            out = L.attention(q, ck, cv, causal=True, q_offset=pos_offset,
                              kv_positions=cpos)
        else:  # prefill: attend within the block directly
            out = L.attention(q, k, v, causal=True, q_offset=0)
        new_cache = (ck, cv, cpos)
    else:
        out = L.attention(q, k, v, causal=causal, q_offset=0)
    out = out.reshape(b, s, hq * hd)
    return jnp.einsum("bsk,kd->bsd", out, p[f"{prefix}wo"]) + p[f"{prefix}bo"], new_cache


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, F, D) stubbed conv-frontend output -> encoder memory."""
    adt = jnp.dtype(cfg.dtype)
    x = frames.astype(adt) + params["pos_enc"][None].astype(adt)
    x = shard(x, "batch", None, None)
    enc = params["enc"]

    def body(h, lp):
        a, _ = _mha(L.layer_norm(h, lp["ln1_g"], lp["ln1_b"]), lp, cfg,
                    causal=False)
        h = h + a
        m = L.gelu_mlp(L.layer_norm(h, lp["ln2_g"], lp["ln2_b"]),
                       lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        return h + m, ()

    from repro.models.causal_lm import _unroll_scans
    if _unroll_scans():
        bf = jax.checkpoint(body)
        for li in range(cfg.encoder_layers):
            x, _ = bf(x, jax.tree.map(lambda a, _li=li: a[_li], enc))
    else:
        x, _ = jax.lax.scan(jax.checkpoint(body), x, enc)
    return L.layer_norm(x, params["enc_norm_g"], params["enc_norm_b"])


def decode(params: Params, tokens: jnp.ndarray, memory: jnp.ndarray,
           cfg: ModelConfig, cache: Optional[WhisperCache] = None,
           pos_offset=0):
    """Decoder forward; returns hidden states (and updated cache)."""
    b, s = tokens.shape
    pos_ids = pos_offset + jnp.arange(s)
    adt = jnp.dtype(cfg.dtype)
    x = (params["embed"].astype(adt)[tokens]
         + params["pos_dec"].astype(adt)[pos_ids][None])
    x = shard(x, "batch", None, None)
    dec = params["dec"]

    def body(h, xs):
        lp, lc = xs
        self_cache = (lc[0], lc[1], lc[2]) if lc is not None else None
        a, new_self = _mha(L.layer_norm(h, lp["ln1_g"], lp["ln1_b"]), lp, cfg,
                           cache=self_cache, pos_offset=pos_offset)
        h = h + a
        if lc is not None:
            kv = (memory, lc[3], lc[4])
        else:
            kv = (memory,)
        c, _ = _mha(L.layer_norm(h, lp["lnx_g"], lp["lnx_b"]), lp, cfg,
                    prefix="x_", kv=kv, causal=False)
        h = h + c
        m = L.gelu_mlp(L.layer_norm(h, lp["ln2_g"], lp["ln2_b"]),
                       lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        new_c = ((new_self[0], new_self[1], new_self[2], lc[3], lc[4])
                 if lc is not None else None)
        return h + m, new_c

    from repro.models.causal_lm import _unroll_scans
    if cache is not None:
        xs = (dec, (cache.k, cache.v, cache.pos, cache.xk, cache.xv))
        if _unroll_scans():
            ncs_list = []
            for li in range(cfg.num_layers):
                x, nc = body(x, jax.tree.map(lambda a, _li=li: a[_li], xs))
                ncs_list.append(nc)
            ncs = jax.tree.map(lambda *a: jnp.stack(a), *ncs_list)
        else:
            x, ncs = jax.lax.scan(body, x, xs)
        new_cache = WhisperCache(*ncs)
    else:
        if _unroll_scans():
            bf = jax.checkpoint(body)
            for li in range(cfg.num_layers):
                x, _ = bf(x, jax.tree.map(lambda a, _li=li: a[_li],
                                          (dec, None)))
        else:
            x, _ = jax.lax.scan(jax.checkpoint(body), x, (dec, None))
        new_cache = None
    x = L.layer_norm(x, params["final_g"], params["final_b"])
    return x, new_cache


def init_cache(params: Params, memory: jnp.ndarray, cfg: ModelConfig,
               max_len: int) -> WhisperCache:
    """Precompute cross-attention K/V from the encoder memory."""
    nd = cfg.num_layers
    b, f, _ = memory.shape
    hq, hd = cfg.num_heads, cfg.hd

    def one(lp):
        k = jnp.einsum("bfd,dk->bfk", memory, lp["x_wk"]).reshape(b, f, hq, hd)
        v = (jnp.einsum("bfd,dk->bfk", memory, lp["x_wv"]) + lp["x_bv"]
             ).reshape(b, f, hq, hd)
        return k, v

    xk, xv = jax.vmap(one)(params["dec"])
    adt = jnp.dtype(cfg.dtype)
    return WhisperCache(
        k=jnp.zeros((nd, b, max_len, hq, hd), adt),
        v=jnp.zeros((nd, b, max_len, hq, hd), adt),
        pos=jnp.full((nd, b, max_len), 10 ** 9, jnp.int32),
        xk=xk.astype(adt), xv=xv.astype(adt),
    )


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: ModelConfig) -> jnp.ndarray:
    from repro.models.causal_lm import xent_loss
    memory = encode(params, batch["frames"], cfg)
    hidden, _ = decode(params, batch["tokens"], memory, cfg)
    return xent_loss(params, hidden, batch["labels"], cfg.padded_vocab)
