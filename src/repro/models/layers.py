"""Shared model layers: norms, RoPE variants, chunked attention, MLPs.

All functions are pure; parameters arrive as dict subtrees created from the
declarative tables in each family module.  Activation sharding is expressed
with logical axes via ``repro.dist.sharding.shard``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.dist.sharding import shard


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with f32 internals and *input-dtype cotangents*.

    The custom VJP keeps backward math in f32 while guaranteeing dx comes
    back in x.dtype, so bf16 activation-grad all-reduces cannot be widened
    by cotangent dtype leaks.  (Perf iteration [train-2] found the f32 ARs
    observed on the CPU backend are an XLA-CPU promotion -- TPU keeps bf16
    -- so this change is type hygiene, not the measured win; see
    EXPERIMENTS.md section Perf.)
    """
    return _rms_norm_fwd(x, gamma, eps)[0]


def _rms_norm_fwd(x, gamma, eps):
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    y = (xf * r * gamma.astype(jnp.float32)).astype(x.dtype)
    return y, (x, gamma, r)


def _rms_norm_bwd(eps, res, g):
    x, gamma, r = res
    d = x.shape[-1]
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32) * gamma.astype(jnp.float32)
    s = jnp.sum(gf * xf, axis=-1, keepdims=True)
    dx = r * gf - xf * (r ** 3) * (s / d)
    dgamma = jnp.sum(g.astype(jnp.float32) * xf * r,
                     axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def layer_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(rot_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               partial: float = 1.0) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    rot = int(hd * partial)
    rot -= rot % 2
    freqs = rope_freqs(rot, theta)                        # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), xp], axis=-1)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Qwen2-VL M-RoPE.  positions3: (3, ..., S) for (t, h, w); frequency
    pairs are split into ``sections`` (per half), each using its own
    position stream."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    # section id per frequency pair
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=hd // 2)
    pos = positions3[sec_id]                              # (hd/2, ..., S)
    pos = jnp.moveaxis(pos, 0, -1)                        # (..., S, hd/2)
    ang = pos.astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (chunked over query blocks: memory-efficient for 32k prefill)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B,S,Hq,hd), k: (B,T,Hkv,hd) -> (B,Hq,S,T) with GQA grouping."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k)
    return scores.reshape(b, hkv * g, s, k.shape[1])


def _gqa_out(w, v):
    """w: (B,Hq,S,T), v: (B,T,Hkv,hd) -> (B,S,Hq,hd)."""
    b, hq, s, t = w.shape
    hkv = v.shape[2]
    g = hq // hkv
    w = w.reshape(b, hkv, g, s, t)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, hq, v.shape[-1])


def attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int | jnp.ndarray = 0,
    window: int = 0,
    chunk: int = 1024,
    kv_positions: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Chunked-query GQA attention.

    q: (B, S, Hq, hd); k, v: (B, T, Hkv, hd).  Rows are processed in query
    chunks so the (chunk, T) score block -- not (S, T) -- is materialized:
    the standard memory-efficient schedule for 32k-token prefill.
    q_offset: absolute position of q[0] (decode: pos; prefill: 0).
    window > 0 adds a sliding-window constraint.
    kv_positions: (B, T) absolute positions of cache slots (ring buffers).
    """
    b, s, hq, hd = q.shape
    t = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    qf = (q * scale).astype(q.dtype)
    if kv_positions is None:
        kv_pos = jnp.arange(t)[None, :]                   # (1, T)
    else:
        kv_pos = kv_positions                             # (B, T)

    def block(qc, qpos):
        # qc: (B, C, Hq, hd); qpos: (C,) absolute positions
        scores = _gqa_scores(qc, k).astype(jnp.float32)   # (B,Hq,C,T)
        mask = jnp.ones((qc.shape[0] if kv_pos.shape[0] > 1 else 1,
                         1, qc.shape[1], t), bool)
        if causal:
            mask &= kv_pos[:, None, None, :] <= qpos[None, None, :, None]
        if window:
            mask &= kv_pos[:, None, None, :] > (qpos[None, None, :, None] - window)
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return _gqa_out(w, v)

    if s <= chunk:
        qpos = q_offset + jnp.arange(s)
        return block(qf, qpos)

    vd = v.shape[-1]                  # value head dim (MLA: != query hd)
    pad = (-s) % chunk
    if pad:                           # ragged tails (meta tokens, enc frames)
        qf = jnp.concatenate(
            [qf, jnp.zeros((b, pad, hq, hd), qf.dtype)], axis=1)
    nc = (s + pad) // chunk
    qcs = qf.reshape(b, nc, chunk, hq, hd)

    def body(i, acc):
        qpos = q_offset + i * chunk + jnp.arange(chunk)
        out = block(qcs[:, i], qpos)
        return jax.lax.dynamic_update_slice(
            acc, out[:, None], (0, i, 0, 0, 0))

    from repro.dist.sharding import pvary_manual
    acc = pvary_manual(jnp.zeros((b, nc, chunk, hq, vd), q.dtype))
    acc = jax.lax.fori_loop(0, nc, body, acc)
    return acc.reshape(b, s + pad, hq, vd)[:, :s]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, wg, wu, wd):
    """SwiGLU MLP: x (B,S,D); wg/wu (D,F); wd (F,D).

    The down-projection output is checkpoint-named: its producing einsum
    carries the TP all-reduce, so saving it under REPRO_REMAT=tp_outs
    avoids re-running that collective in backward."""
    g = jnp.einsum("bsd,df->bsf", x, wg)
    u = jnp.einsum("bsd,df->bsf", x, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", None, "model")
    out = jnp.einsum("bsf,fd->bsd", h, wd)
    return _checkpoint_name(out, "tp_ar_out")


def gelu_mlp(x, w1, b1, w2, b2):
    h = jnp.einsum("bsd,df->bsf", x, w1) + b1
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, w2) + b2
