"""Declarative parameter tables.

A model declares its parameters once as a nested dict of ``ParamDef``;
``init_params`` materializes them, ``logical_axes`` yields the sharding
tree, and ``abstract_params`` gives ShapeDtypeStructs for AOT lowering
without ever allocating the (potentially multi-hundred-GB) tree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]        # logical axis names
    init: str = "normal"                   # normal | zeros | ones | embed
    scale: float = 1.0
    dtype: jnp.dtype = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(table, key: jax.Array):
    leaves, treedef = jax.tree.flatten(table, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, d.shape, jnp.float32) * std)
                       .astype(d.dtype))
    return jax.tree.unflatten(treedef, out)


def logical_axes(table):
    return jax.tree.map(lambda d: d.axes, table, is_leaf=_is_def)


def abstract_params(table):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), table, is_leaf=_is_def)


def param_specs(table, mesh=None):
    """Tree of NamedShardings for the whole parameter table."""
    from repro.dist import sharding as S
    return jax.tree.map(
        lambda d: S.named_sharding(d.shape, d.axes, mesh), table, is_leaf=_is_def)


def count(table) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree.leaves(table, is_leaf=_is_def))
