"""Unified model facade: build any assigned architecture from its config.

Provides param tables / abstract trees (for AOT lowering at 236B scale
without allocation), loss / prefill / decode entry points, and
ShapeDtypeStruct input specs for every (shape x kind) dry-run cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import causal_lm as CLM
from repro.models import whisper as WSP
from repro.models import params as PRM


def param_table(cfg: ModelConfig):
    if cfg.family == "encdec":
        return WSP.param_table(cfg)
    return CLM.param_table(cfg)


def init_params(cfg: ModelConfig, key: jax.Array):
    return PRM.init_params(param_table(cfg), key)


def abstract_params(cfg: ModelConfig):
    return PRM.abstract_params(param_table(cfg))


def param_specs(cfg: ModelConfig, mesh=None):
    return PRM.param_specs(param_table(cfg), mesh)


def count_params(cfg: ModelConfig) -> int:
    return PRM.count(param_table(cfg))


def active_params(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE: only routed-in experts count)."""
    total = count_params(cfg)
    if cfg.num_experts:
        ff = cfg.moe_d_ff or cfg.d_ff
        per_expert = 3 * cfg.d_model * ff
        moe_layers = cfg.num_layers - (1 if cfg.dense_first_layer else 0)
        inactive = (cfg.num_experts - cfg.experts_per_token) * per_expert * moe_layers
        return total - inactive
    return total


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    if cfg.family == "encdec":
        return WSP.loss_fn(params, batch, cfg)
    return CLM.loss_fn(params, batch, cfg)


def prefill(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            max_len: int):
    if cfg.family == "encdec":
        memory = WSP.encode(params, batch["frames"], cfg)
        cache = WSP.init_cache(params, memory, cfg, max_len)
        hidden, cache = WSP.decode(params, batch["tokens"], memory, cfg, cache)
        logits = CLM.logits_fn(params, hidden[:, -1:])
        return logits[:, 0], cache
    return CLM.prefill(params, batch["tokens"], cfg, max_len)


def decode_step(params, cache, token: jnp.ndarray, pos, cfg: ModelConfig,
                mrope_positions=None):
    """token: (B, 1); pos: scalar int32 (current absolute position)."""
    if cfg.family == "encdec":
        # memory unused at decode: cross-K/V live in the cache
        b = token.shape[0]
        x, cache = WSP.decode(params, token, None, cfg, cache, pos_offset=pos)
        return CLM.logits_fn(params, x)[:, 0], cache
    return CLM.decode_step(params, cache, token, pos, cfg,
                           mrope_positions=mrope_positions)


def init_cache(cfg: ModelConfig, params_or_abstract, batch: int, max_len: int):
    """Cache pytree; whisper needs memory-shaped cross-K/V placeholders."""
    if cfg.family == "encdec":
        hq, hd = cfg.num_heads, cfg.hd
        nd, f = cfg.num_layers, cfg.encoder_frames
        adt = jnp.dtype(cfg.dtype)
        return WSP.WhisperCache(
            k=jnp.zeros((nd, batch, max_len, hq, hd), adt),
            v=jnp.zeros((nd, batch, max_len, hq, hd), adt),
            pos=jnp.full((nd, batch, max_len), 10 ** 9, jnp.int32),
            xk=jnp.zeros((nd, batch, f, hq, hd), adt),
            xv=jnp.zeros((nd, batch, f, hq, hd), adt),
        )
    return CLM.init_cache(cfg, batch, max_len)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: init_cache(cfg, None, batch, max_len))


def cache_logical_axes(cfg: ModelConfig, cache):
    if cfg.family == "encdec":
        def axes_for(x):
            if x.ndim == 5:
                return ("layers", "batch", None, "model", None)
            return ("layers", "batch", None)
        return jax.tree.map(axes_for, cache)
    return CLM.cache_logical_axes(cfg, cache)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs) per dry-run cell
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for a (arch x shape) cell.

    train:   {tokens, labels (B,S)} (+frames for encdec, +mrope for vlm)
    prefill: {tokens (B,S)} (+extras)
    decode:  {token (B,1), pos (), cache}
    """
    b, s = shape.global_batch, shape.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
    if shape.kind == "train":
        out = {"tokens": tok(b, s), "labels": tok(b, s)}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            out["mrope_positions"] = tok(3, b, s)
        return out
    if shape.kind == "prefill":
        out = {"tokens": tok(b, s)}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            out["mrope_positions"] = tok(3, b, s)
        return out
    # decode
    out = {
        "token": tok(b, 1),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": abstract_cache(cfg, b, s),
    }
    if cfg.family == "vlm":
        out["mrope_positions"] = tok(3, b, 1)
    return out
