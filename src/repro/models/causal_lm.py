"""Unified decoder-only causal LM covering the assigned families:

  dense    -- stablelm-3b, codeqwen1.5-7b, granite-8b, granite-3-2b
  moe      -- phi3.5-moe (16e top-2)
  mla_moe  -- deepseek-v2 (MLA attention, 2 shared + 160 routed top-6)
  vlm      -- qwen2-vl backbone (M-RoPE; patch frontend stubbed)
  ssm      -- mamba2 (attention-free)
  hybrid   -- hymba (parallel attn+SSM heads, SWA + 3 global layers,
              meta tokens)

Layers run under ``jax.lax.scan`` with stacked parameters (compile-time and
HLO-size control at 80 layers); non-uniform layers (deepseek's dense first
layer, hymba's global-attention layers) are unrolled segments around the
scan.  Remat is applied per layer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.configs.base import ModelConfig
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.params import ParamDef

Params = Dict[str, Any]


def _unroll_scans() -> bool:
    """Cost-accounting mode: unroll layer scans so XLA cost analysis counts
    every layer (lax.scan bodies are otherwise counted once)."""
    import os
    return os.environ.get("REPRO_UNROLL_SCAN", "0") == "1"


def _remat_policy():
    """Remat policy knob (REPRO_REMAT=full|dots).

    ``full`` (default): save only layer boundaries -- minimal memory,
    recompute everything (including the TP all-reduces) in backward.
    ``dots``: additionally save matmul/collective outputs inside the layer
    -- backward skips recomputing the heavy einsums *and* their trailing
    all-reduces, trading ~1-2 GB of activations for collective traffic.
    """
    import os
    mode = os.environ.get("REPRO_REMAT", "full")
    if mode == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if mode == "tp_outs":
        # save exactly the two per-layer activations whose producing
        # einsums carry the tensor-parallel all-reduce
        return jax.checkpoint_policies.save_only_these_names("tp_ar_out")
    return None


def _checkpoint(fn):
    pol = _remat_policy()
    return jax.checkpoint(fn, policy=pol) if pol is not None \
        else jax.checkpoint(fn)


# ===========================================================================
# Parameter tables
# ===========================================================================

def _attn_table(n: int, cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    t = {
        "wq": ParamDef((n, d, hq * hd), ("layers", "fsdp", "model")),
        "wk": ParamDef((n, d, hkv * hd), ("layers", "fsdp", "model")),
        "wv": ParamDef((n, d, hkv * hd), ("layers", "fsdp", "model")),
        "wo": ParamDef((n, hq * hd, d), ("layers", "model", "fsdp")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamDef((n, hq * hd), ("layers", "model"), init="zeros")
        t["bk"] = ParamDef((n, hkv * hd), ("layers", "model"), init="zeros")
        t["bv"] = ParamDef((n, hkv * hd), ("layers", "model"), init="zeros")
    return t


def _mla_table(n: int, cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": ParamDef((n, d, qr), ("layers", "fsdp", None)),
        "q_norm": ParamDef((n, qr), ("layers", None), init="ones"),
        "wq_b": ParamDef((n, qr, h * (dn + dr)), ("layers", None, "model")),
        "wkv_a": ParamDef((n, d, kvr + dr), ("layers", "fsdp", None)),
        "kv_norm": ParamDef((n, kvr), ("layers", None), init="ones"),
        "wk_b": ParamDef((n, kvr, h * dn), ("layers", None, "model")),
        "wv_b": ParamDef((n, kvr, h * dv), ("layers", None, "model")),
        "wo": ParamDef((n, h * dv, d), ("layers", "model", "fsdp")),
    }


def _mlp_table(n: int, cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wg": ParamDef((n, d, f), ("layers", "fsdp", "model")),
        "wu": ParamDef((n, d, f), ("layers", "fsdp", "model")),
        "wd": ParamDef((n, f, d), ("layers", "model", "fsdp")),
    }


def _norms_table(n: int, cfg: ModelConfig, names) -> Dict[str, ParamDef]:
    return {k: ParamDef((n, cfg.d_model), ("layers", None), init="ones")
            for k in names}


def _layer_table(n: int, cfg: ModelConfig, moe_layer: bool) -> Dict[str, Any]:
    """Table for a stack of ``n`` homogeneous layers of this family."""
    t: Dict[str, Any] = {}
    fam = cfg.family
    if fam == "ssm":
        t["ssm"] = SSM.ssm_param_table(n, cfg)
        t.update(_norms_table(n, cfg, ["norm1"]))
        return t
    if fam == "mla_moe":
        t["attn"] = _mla_table(n, cfg)
    else:
        t["attn"] = _attn_table(n, cfg)
    if fam == "hybrid":
        t["ssm"] = SSM.ssm_param_table(n, cfg)
        d_inner, _ = SSM.ssm_dims(cfg)
        t["mix_attn"] = ParamDef((n, cfg.d_model), ("layers", None), init="ones")
        t["mix_ssm"] = ParamDef((n, cfg.d_model), ("layers", None), init="ones")
    if moe_layer:
        t["moe"] = MOE.moe_param_table(
            n, cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts,
            cfg.num_shared_experts,
            shared_d_ff=(cfg.moe_d_ff or cfg.d_ff) * max(cfg.num_shared_experts, 1))
    else:
        t["mlp"] = _mlp_table(n, cfg)
    t.update(_norms_table(n, cfg, ["norm1", "norm2"]))
    return t


def segments(cfg: ModelConfig):
    """Layer segmentation: list of (kind, count) with kind in
    scan | dense0 | global."""
    if cfg.family == "mla_moe" and cfg.dense_first_layer:
        return [("dense0", 1), ("scan", cfg.num_layers - 1)]
    if cfg.family == "hybrid" and cfg.num_global_layers:
        ng = cfg.num_global_layers
        ns = cfg.num_layers - ng
        # global layers at start / middle / end, scan segments between
        per = ns // ng
        segs = []
        rem = ns
        for i in range(ng):
            segs.append(("global", 1))
            take = per if i < ng - 1 else rem - per * (ng - 1)
            if take:
                segs.append(("scan", take))
        return segs
    return [("scan", cfg.num_layers)]


def param_table(cfg: ModelConfig) -> Dict[str, Any]:
    v = cfg.padded_vocab
    t: Dict[str, Any] = {
        # embed sharded on d_model (not vocab): vocab-sharded gathers make
        # the SPMD partitioner fall back to full rematerialization (and
        # CHECK-fail under partial-manual shard_map).  Under podsync the
        # partitioner still mis-slices the gather, so the table is fully
        # replicated there (REPRO_EMBED_REPLICATED=1; ~400 MB for the
        # podsync demo arch).
        "embed": ParamDef(
            (v, cfg.d_model),
            ((None, None) if __import__("os").environ.get(
                "REPRO_EMBED_REPLICATED") == "1" else (None, "model")),
            init="embed", scale=1.0),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones"),
        "lm_head": ParamDef((cfg.d_model, v), ("fsdp", "model")),
    }
    if cfg.meta_tokens:
        t["meta"] = ParamDef((cfg.meta_tokens, cfg.d_model), (None, "fsdp"))
    moe_fam = cfg.family in ("moe", "mla_moe")
    for i, (kind, n) in enumerate(segments(cfg)):
        if kind == "dense0":
            # deepseek-v2 first layer: MLA attn + dense MLP (d_ff=12288)
            dcfg = dataclasses.replace(cfg, d_ff=12288)
            sub = _layer_table(n, dataclasses.replace(dcfg, family="mla_moe"),
                               moe_layer=False)
            t[f"seg{i}"] = sub
        elif kind == "global":
            t[f"seg{i}"] = _layer_table(n, cfg, moe_layer=moe_fam)
        else:
            t[f"seg{i}"] = _layer_table(n, cfg, moe_layer=moe_fam)
    return t


# ===========================================================================
# KV caches
# ===========================================================================

class AttnCache(NamedTuple):
    k: jnp.ndarray          # (n, B, T, Hkv, hd)   [stacked over layers]
    v: jnp.ndarray
    pos: jnp.ndarray        # (n, B, T) absolute positions of slots (or -1)


class MLACache(NamedTuple):
    ckv: jnp.ndarray        # (n, B, T, kv_lora)
    krope: jnp.ndarray      # (n, B, T, rope_dim)
    pos: jnp.ndarray


class HybridCache(NamedTuple):
    attn: AttnCache
    conv: jnp.ndarray       # (n, B, K-1, conv_dim)
    state: jnp.ndarray      # (n, B, H, P, N)


def _attn_cache(n: int, b: int, t: int, cfg: ModelConfig, dtype) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((n, b, t, cfg.num_kv_heads, cfg.hd), dtype),
        v=jnp.zeros((n, b, t, cfg.num_kv_heads, cfg.hd), dtype),
        pos=jnp.full((n, b, t), 10 ** 9, jnp.int32),
    )


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Cache pytree keyed by segment, honouring per-family cache shapes."""
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    caches = {}
    extra = cfg.meta_tokens
    for i, (kind, n) in enumerate(segments(cfg)):
        if cfg.family == "ssm":
            c = SSM.init_ssm_cache(batch, cfg, dtype)
            caches[f"seg{i}"] = HybridCache(
                attn=None,  # type: ignore
                conv=c.conv[None].repeat(n, 0) if n > 1 else c.conv[None],
                state=c.state[None].repeat(n, 0) if n > 1 else c.state[None],
            )
            continue
        if cfg.family == "hybrid":
            w = cfg.window_size if (kind == "scan" and cfg.window_size
                                    and cfg.sliding_window_decode) else max_len
            t = min(w, max_len) + extra
            c = SSM.init_ssm_cache(batch, cfg, dtype)
            caches[f"seg{i}"] = HybridCache(
                attn=_attn_cache(n, batch, t, cfg, dtype),
                conv=jnp.broadcast_to(c.conv[None], (n,) + c.conv.shape),
                state=jnp.broadcast_to(c.state[None], (n,) + c.state.shape),
            )
            continue
        if cfg.family == "mla_moe":
            caches[f"seg{i}"] = MLACache(
                ckv=jnp.zeros((n, batch, max_len, cfg.kv_lora_rank), dtype),
                krope=jnp.zeros((n, batch, max_len, cfg.qk_rope_head_dim), dtype),
                pos=jnp.full((n, batch, max_len), 10 ** 9, jnp.int32),
            )
        else:
            caches[f"seg{i}"] = _attn_cache(n, batch, max_len + extra, cfg, dtype)
    return caches


def cache_logical_axes(cfg: ModelConfig, cache) -> Any:
    """Logical axes for cache arrays: batch-sharded everywhere, plus a
    `model`-axis shard on KV heads when they divide the mesh extent, else
    on the *sequence* dimension (flash-decoding style).  The fallback is
    what keeps e.g. granite-8b's kv=8 cache from being replicated 16x over
    the model axis (618 GB -> 2.4 GB/device at decode_32k)."""
    from repro.dist import sharding as S
    mesh = S.current_mesh()
    model_ext = 1
    if mesh is not None:
        model_ext = S._mesh_extent(mesh, S.current_rules().get("model", ()))
    kv_shards = model_ext > 1 and cfg.num_kv_heads % model_ext == 0

    def axes_for(x):
        if x.ndim == 5 and cfg.family != "ssm":       # (n,B,T,Hkv,hd)
            if kv_shards:
                return ("layers", "batch", None, "model", None)
            return ("layers", "batch", "seq_model", None, None)
        if x.ndim == 5:                                # ssm state (n,B,H,P,N)
            return ("layers", "batch", "model", None, None)
        if x.ndim == 4 and cfg.family == "mla_moe":    # (n,B,T,ckv)
            return ("layers", "batch", "seq_model", None)
        if x.ndim == 4:                                # conv (n,B,K-1,C)
            return ("layers", "batch", None, "model")
        if x.ndim == 3:                                # pos (n,B,T)
            return ("layers", "batch", "seq_model")
        return tuple([None] * x.ndim)
    return jax.tree.map(axes_for, cache)


# ===========================================================================
# Blocks
# ===========================================================================

def _project_qkv(x, p, cfg: ModelConfig):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, cfg.hd)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.hd)
    return q, k, v


def _rope_qk(q, k, positions, cfg: ModelConfig, mrope_positions=None):
    if cfg.family == "vlm" and cfg.mrope_sections:
        pos3 = (mrope_positions if mrope_positions is not None
                else jnp.broadcast_to(positions, (3,) + positions.shape))
        return (L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections),
                L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections))
    return (L.apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary),
            L.apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary))


def attn_block(x, p, cfg: ModelConfig, *, window: int = 0,
               cache: Optional[AttnCache] = None,
               pos_offset=0, mrope_positions=None):
    """Full/windowed GQA attention; with cache -> decode/prefill update.

    Returns (out, new_cache_entry or None).  Cache entries here are
    per-layer (B,T,...) -- stacking over layers happens in the scan driver.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    positions = pos_offset + jnp.arange(s)[None, :]       # (1,S) broadcast
    q, k = _rope_qk(q, k, positions, cfg, mrope_positions)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)

    if cache is None:
        out = L.attention(q, k, v, causal=True, q_offset=0, window=window)
        new = None
    elif s == 1:  # decode: ring-buffer (windowed) or linear cache write
        ck, cv, cpos = cache
        t = ck.shape[1]
        slot = jnp.asarray(pos_offset) % t
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cpos, jnp.broadcast_to(positions.astype(jnp.int32), (b, 1)),
            (0, slot))
        out = L.attention(q, ck, cv, causal=True, q_offset=positions[0, 0],
                          window=window, kv_positions=cpos)
        new = AttnCache(ck, cv, cpos)
    else:  # prefill: attend over the full local K/V, cache stores the tail
        ck, cv, cpos = cache
        t = ck.shape[1]
        k_tail, v_tail = k[:, -t:], v[:, -t:]
        pos_tail = jnp.broadcast_to(positions[:, -t:].astype(jnp.int32),
                                    (b, min(s, t)))
        if t < s:  # ring buffer: place position p at slot p % t
            shift = s % t
            k_tail = jnp.roll(k_tail, shift, axis=1)
            v_tail = jnp.roll(v_tail, shift, axis=1)
            pos_tail = jnp.roll(pos_tail, shift, axis=1)
        ck = jax.lax.dynamic_update_slice(ck, k_tail, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v_tail, (0, 0, 0, 0))
        cpos = jax.lax.dynamic_update_slice(cpos, pos_tail, (0, 0))
        out = L.attention(q, k, v, causal=True, q_offset=0, window=window)
        new = AttnCache(ck, cv, cpos)
    out = out.reshape(b, s, cfg.num_heads * cfg.hd)
    out = jnp.einsum("bsk,kd->bsd", out, p["wo"])
    out = _checkpoint_name(out, "tp_ar_out")
    return out, new


def mla_block(x, p, cfg: ModelConfig, *, cache: Optional[MLACache] = None,
              pos_offset=0):
    """DeepSeek-V2 multi-head latent attention.

    Training / prefill (S > 1) use the *expanded* form -- per-head K/V are
    decompressed from the latent and fed to the chunked-query attention
    (heads shard over ``model``).  Single-token decode uses the *absorbed*
    form: scores are taken against the compressed latent directly, so the
    cache stores only (c_kv, k_rope) -- MLA's memory saving."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = pos_offset + jnp.arange(s)[None, :]

    cq = L.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rk->bsk", cq, p["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    q_nope = shard(q_nope, "batch", None, "model", None)
    q_rope = shard(q_rope, "batch", None, "model", None)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv, k_rope_in = kv_a[..., : cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    ckv = L.rms_norm(ckv, p["kv_norm"])
    k_rope = L.apply_rope(k_rope_in[:, :, None, :], positions,
                          cfg.rope_theta)[:, :, 0]         # shared across heads

    new = None
    if cache is not None:
        cckv, ckr, cpos = cache
        t = cckv.shape[1]
        if s == 1:
            slot = jnp.asarray(pos_offset) % t
            cckv = jax.lax.dynamic_update_slice(cckv, ckv, (0, slot, 0))
            ckr = jax.lax.dynamic_update_slice(ckr, k_rope, (0, slot, 0))
            cpos = jax.lax.dynamic_update_slice(
                cpos, jnp.broadcast_to(positions.astype(jnp.int32), (b, 1)),
                (0, slot))
        else:
            cckv = jax.lax.dynamic_update_slice(cckv, ckv[:, -t:], (0, 0, 0))
            ckr = jax.lax.dynamic_update_slice(ckr, k_rope[:, -t:], (0, 0, 0))
            cpos = jax.lax.dynamic_update_slice(
                cpos, jnp.broadcast_to(
                    positions[:, -t:].astype(jnp.int32), (b, min(s, t))),
                (0, 0))
        new = MLACache(cckv, ckr, cpos)

    scale = (dn + dr) ** -0.5
    if s > 1:
        # expanded form: decompress per-head K/V, chunked attention
        wk_b = p["wk_b"].reshape(cfg.kv_lora_rank, h, dn)
        wv_b = p["wv_b"].reshape(cfg.kv_lora_rank, h, dv)
        k_nope = jnp.einsum("btr,rhn->bthn", ckv, wk_b)
        v = jnp.einsum("btr,rhv->bthv", ckv, wv_b)
        k_nope = shard(k_nope, "batch", None, "model", None)
        v = shard(v, "batch", None, "model", None)
        kr = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        kk = jnp.concatenate([k_nope, kr], axis=-1)
        out = L.attention(qq, kk, v, causal=True, q_offset=0, scale=scale)
    else:
        # absorbed decode: score against the latent cache directly
        cckv, ckr, cpos = new
        wk_b = p["wk_b"].reshape(cfg.kv_lora_rank, h, dn)
        q_eff = jnp.einsum("bshn,rhn->bshr", q_nope, wk_b)   # (B,1,H,kvr)
        scores = (jnp.einsum("bshr,btr->bhst", q_eff, cckv)
                  + jnp.einsum("bshr,btr->bhst", q_rope, ckr))
        scores = scores.astype(jnp.float32) * scale
        qpos = positions[0]                                  # (1,)
        mask = cpos[:, None, None, :] <= qpos[None, None, :, None]
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        lat = jnp.einsum("bhst,btr->bshr", w, cckv)          # (B,1,H,kvr)
        wv_b = p["wv_b"].reshape(cfg.kv_lora_rank, h, dv)
        out = jnp.einsum("bshr,rhv->bshv", lat, wv_b)
    out = out.reshape(b, s, h * dv)
    return jnp.einsum("bsk,kd->bsd", out, p["wo"]), new


def mlp_or_moe(x, p, cfg: ModelConfig, moe_layer: bool):
    if moe_layer:
        return MOE.moe_ffn(
            x, p["moe"], num_experts=cfg.num_experts,
            top_k=cfg.experts_per_token, capacity_factor=cfg.capacity_factor)
    return L.swiglu(x, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])


def layer_fwd(x, lp, cfg: ModelConfig, *, moe_layer: bool, window: int = 0,
              cache=None, pos_offset=0, mrope_positions=None):
    """One transformer layer of any family.  cache: per-layer entry."""
    fam = cfg.family
    if fam == "ssm":
        h = L.rms_norm(x, lp["norm1"])
        sc = (SSM.SSMCache(cache.conv, cache.state)
              if cache is not None else None)
        y, new_sc = SSM.mamba_mixer(h, lp["ssm"], cfg, sc)
        newc = (HybridCache(None, new_sc.conv, new_sc.state)
                if new_sc is not None else None)
        return x + y, newc

    h = L.rms_norm(x, lp["norm1"])
    if fam == "mla_moe":
        a, new_attn = mla_block(h, lp["attn"], cfg, cache=cache,
                                pos_offset=pos_offset)
    else:
        ac = cache.attn if fam == "hybrid" and cache is not None else cache
        a, new_attn = attn_block(h, lp["attn"], cfg, window=window,
                                 cache=ac, pos_offset=pos_offset,
                                 mrope_positions=mrope_positions)
    if fam == "hybrid":
        sc = (SSM.SSMCache(cache.conv, cache.state)
              if cache is not None else None)
        sy, new_sc = SSM.mamba_mixer(h, lp["ssm"], cfg, sc)
        a = 0.5 * (a * lp["mix_attn"][None, None, :]
                   + sy * lp["mix_ssm"][None, None, :])
        newc = (HybridCache(new_attn, new_sc.conv, new_sc.state)
                if cache is not None else None)
    else:
        newc = new_attn
    x = x + a
    h2 = L.rms_norm(x, lp["norm2"])
    x = x + mlp_or_moe(h2, lp, cfg, moe_layer)
    return x, newc


# ===========================================================================
# Model driver: embed -> segments (scan/unrolled) -> norm -> head
# ===========================================================================

def _take_layer(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _seg_window(cfg: ModelConfig, kind: str) -> int:
    if cfg.family == "hybrid" and kind == "scan" and cfg.window_size:
        return cfg.window_size
    return 0


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            *, caches=None, pos_offset=0, mrope_positions=None,
            remat: bool = True):
    """tokens: (B, S) int32 -> logits-ready hidden (B, S(+meta), D).

    With ``caches`` (dict per segment) also returns updated caches.
    """
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    x = shard(x, "batch", None, None)
    if cfg.meta_tokens and (caches is None or tokens.shape[1] > 1):
        meta = jnp.broadcast_to(
            params["meta"].astype(x.dtype)[None],
            (x.shape[0], cfg.meta_tokens, x.shape[-1]))
        x = jnp.concatenate([meta, x], axis=1)

    new_caches = {} if caches is not None else None
    moe_fam = cfg.family in ("moe", "mla_moe")
    for i, (kind, n) in enumerate(segments(cfg)):
        seg_p = params[f"seg{i}"]
        window = _seg_window(cfg, kind)
        moe_layer = moe_fam and kind != "dense0"
        seg_cache = caches[f"seg{i}"] if caches is not None else None

        if kind != "scan" or n == 1:
            lp = _take_layer(seg_p, 0)
            lc = _take_layer(seg_cache, 0) if seg_cache is not None else None
            x, nc = layer_fwd(x, lp, cfg, moe_layer=moe_layer, window=window,
                              cache=lc, pos_offset=pos_offset,
                              mrope_positions=mrope_positions)
            if new_caches is not None:
                new_caches[f"seg{i}"] = jax.tree.map(
                    lambda a: a[None], nc) if nc is not None else None
            continue

        def body(carry, xs):
            h = carry
            lp, lc = xs
            h, nc = layer_fwd(h, lp, cfg, moe_layer=moe_layer, window=window,
                              cache=lc, pos_offset=pos_offset,
                              mrope_positions=mrope_positions)
            return h, nc

        body_fn = _checkpoint(body) if remat else body
        if _unroll_scans():
            # cost-accounting mode (dryrun --unroll): identical math without
            # the while loop, so compiled.cost_analysis() sees every layer
            ncs_list = []
            for li in range(n):
                xs_i = jax.tree.map(lambda a, _li=li: a[_li],
                                    (seg_p, seg_cache))
                x, nc_i = body_fn(x, xs_i)
                ncs_list.append(nc_i)
            ncs = (jax.tree.map(lambda *a: jnp.stack(a), *ncs_list)
                   if ncs_list and ncs_list[0] is not None else None)
        else:
            x, ncs = jax.lax.scan(body_fn, x, (seg_p, seg_cache))
        if new_caches is not None:
            new_caches[f"seg{i}"] = ncs

    x = L.rms_norm(x, params["final_norm"])
    if cfg.meta_tokens and (caches is None or tokens.shape[1] > 1):
        x = x[:, cfg.meta_tokens:]
    return (x, new_caches) if caches is not None else x


def logits_fn(params: Params, hidden: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bsd,dv->bsv", hidden, params["lm_head"].astype(hidden.dtype))


def xent_loss(params: Params, hidden: jnp.ndarray, labels: jnp.ndarray,
              vocab: int, chunk: int = 512) -> jnp.ndarray:
    """Chunked softmax cross-entropy over the (padded) vocab.

    The (B, S, V) logits tensor is never materialized: sequence chunks of
    ``chunk`` positions are processed in a scan (512 x 152k logits per step
    for the largest vocab)."""
    b, s, d = hidden.shape
    assert s % chunk == 0 or s < chunk, (s, chunk)
    chunk = min(chunk, s)
    nc = s // chunk
    h = hidden.reshape(b, nc, chunk, d)
    y = labels.reshape(b, nc, chunk)
    w = params["lm_head"]

    def body(acc, i):
        logit = jnp.einsum("bcd,dv->bcv", h[:, i], w.astype(hidden.dtype))
        logit = logit.astype(jnp.float32)
        lse = jax.nn.logsumexp(logit, axis=-1)
        gold = jnp.take_along_axis(logit, y[:, i][..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), ()

    from repro.dist.sharding import pvary_manual
    init = pvary_manual(jnp.float32(0.0))
    if _unroll_scans():
        total = init
        for i in range(nc):
            total, _ = body(total, i)
    else:
        total, _ = jax.lax.scan(body, init, jnp.arange(nc))
    return total / (b * s)


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = True) -> jnp.ndarray:
    hidden = forward(params, batch["tokens"], cfg, remat=remat,
                     mrope_positions=batch.get("mrope_positions"))
    return xent_loss(params, hidden, batch["labels"], cfg.padded_vocab)


def prefill(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            max_len: int, dtype=None):
    """Returns (last-token logits, populated cache)."""
    caches = init_cache(cfg, tokens.shape[0], max_len, dtype)
    hidden, caches = forward(params, tokens, cfg, caches=caches)
    logits = logits_fn(params, hidden[:, -1:])
    return logits[:, 0], caches


def decode_step(params: Params, caches, token: jnp.ndarray,
                pos, cfg: ModelConfig, mrope_positions=None):
    """token: (B, 1) int32; pos: scalar absolute position (incl. meta)."""
    off = pos + (cfg.meta_tokens or 0)
    hidden, caches = forward(params, token, cfg, caches=caches,
                             pos_offset=off, mrope_positions=mrope_positions)
    return logits_fn(params, hidden)[:, 0], caches
