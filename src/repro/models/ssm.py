"""Mamba2 (SSD -- state-space duality) mixer: chunked train scan + O(1) decode.

Training uses the SSD chunked algorithm (Dao & Gu 2024): quadratic
attention-like computation within chunks, linear state passing between
chunks.  Decode is a single recurrent state update -- the property that
makes the ``long_500k`` cell feasible for SSM/hybrid archs.

Layout: x (B, S, H, P) heads; B/C (B, S, G, N) groups; A scalar per head;
dt per head per step.  Heads shard over the ``model`` mesh axis when
divisible.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.params import ParamDef


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads


def ssm_param_table(layers: int, cfg):
    d_inner, heads = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_dim = d_inner + 2 * g * n
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "in_proj": ParamDef(
            (layers, cfg.d_model, 2 * d_inner + 2 * g * n + heads),
            ("layers", "fsdp", "model")),
        "conv_w": ParamDef((layers, cfg.ssm_conv, conv_dim),
                           ("layers", None, "model")),
        "conv_b": ParamDef((layers, conv_dim), ("layers", "model"), init="zeros"),
        "a_log": ParamDef((layers, heads), ("layers", "model"), init="zeros",
                          dtype=jnp.float32),
        "d_skip": ParamDef((layers, heads), ("layers", "model"), init="ones",
                           dtype=jnp.float32),
        "dt_bias": ParamDef((layers, heads), ("layers", "model"), init="zeros",
                            dtype=jnp.float32),
        "norm_g": ParamDef((layers, d_inner), ("layers", "model"), init="ones"),
        "out_proj": ParamDef((layers, d_inner, cfg.d_model),
                             ("layers", "model", "fsdp")),
    }


class SSMCache(NamedTuple):
    conv: jnp.ndarray    # (B, K-1, conv_dim) last inputs for the short conv
    state: jnp.ndarray   # (B, H, P, N) recurrent state


def init_ssm_cache(batch: int, cfg, dtype=jnp.bfloat16) -> SSMCache:
    d_inner, heads = ssm_dims(cfg)
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, heads, cfg.ssm_head_dim, cfg.ssm_state),
                        jnp.float32),
    )


def _split_proj(xz: jnp.ndarray, cfg):
    d_inner, heads = ssm_dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state
    z, xbc_dt = jnp.split(xz, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv, window K: (B,S,C) -> (B,S,C).

    ``history``: (B, K-1, C) values preceding position 0 (decode cache)."""
    k = w.shape[0]
    if history is None:
        history = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    xp = jnp.concatenate([history, xbc], axis=1)
    out = sum(xp[:, i: i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _segsum(da: jnp.ndarray) -> jnp.ndarray:
    """da: (..., Q) -> (..., Q, Q) lower-tri cumulative sums
    L[i, j] = sum_{j < m <= i} da[m] (=-inf above diagonal)."""
    q = da.shape[-1]
    cs = jnp.cumsum(da, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(x: jnp.ndarray, b_in: jnp.ndarray, c_in: jnp.ndarray,
                dt: jnp.ndarray, a: jnp.ndarray, d_skip: jnp.ndarray,
                chunk: int,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    x (B,S,H,P), b_in/c_in (B,S,G,N), dt (B,S,H) [post-softplus],
    a (H,) negative.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    bc = jnp.repeat(b_in.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c_in.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    dtc = dt.reshape(bsz, nc, chunk, h)
    da = dtc * a[None, None, None, :]                    # (B,nc,Q,H)
    da = jnp.moveaxis(da, -1, 2)                         # (B,nc,H,Q)

    # intra-chunk (quadratic within chunk)
    lmat = jnp.exp(_segsum(da))                          # (B,nc,H,Q,Q)
    scores = jnp.einsum("bnqhx,bnkhx->bnhqk", cc, bc)    # (B,nc,H,Q,Q)
    scores = scores * lmat.astype(scores.dtype)
    dx = xc * dtc[..., None]                             # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", scores, dx)

    # chunk end-states: item k decays by exp(sum_{m>k} da_m) -- note the
    # *exclusive* tail sum, matching the recurrence h_t = e^{da_t} h_{t-1} + ...
    cs = jnp.cumsum(da, axis=-1)
    decay_to_end = jnp.exp(cs[..., -1:] - cs)
    # state_n = sum_k decay(end<-k) * B_k x_k : (B,nc,H,P,N)
    states = jnp.einsum("bnhk,bnkhx,bnkhp->bnhpx",
                        decay_to_end.astype(dx.dtype), bc, dx)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(jnp.sum(da, axis=-1))          # (B,nc,H)

    def scan_fn(h_prev, inp):
        st, dec = inp
        h_new = h_prev * dec[..., None, None] + st.astype(jnp.float32)
        return h_new, h_prev

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    states_t = jnp.moveaxis(states, 1, 0)                # (nc,B,H,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)            # (nc,B,H)
    final, h_prevs = jax.lax.scan(scan_fn, init_state, (states_t, decay_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (B,nc,H,P,N)

    # inter-chunk contribution: y += C_q * decay(q<-start) * h_prev
    decay_in = jnp.exp(jnp.cumsum(da, axis=-1))          # (B,nc,H,Q)
    y_inter = jnp.einsum("bnqhx,bnhq,bnhpx->bnqhp",
                         cc, decay_in.astype(cc.dtype),
                         h_prevs.astype(cc.dtype))
    y = y_intra + y_inter + dx * d_skip[None, None, None, :, None].astype(dx.dtype)
    return y.reshape(bsz, s, h, p), final


def ssm_step(x: jnp.ndarray, b_in: jnp.ndarray, c_in: jnp.ndarray,
             dt: jnp.ndarray, a: jnp.ndarray, d_skip: jnp.ndarray,
             state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrence: x (B,H,P), b/c (B,G,N), dt (B,H)."""
    h = x.shape[1]
    rep = h // b_in.shape[1]
    bb = jnp.repeat(b_in, rep, axis=1)                   # (B,H,N)
    ccd = jnp.repeat(c_in, rep, axis=1)
    decay = jnp.exp(dt * a[None, :])                     # (B,H)
    dx = x * dt[..., None]
    state = (state * decay[..., None, None]
             + jnp.einsum("bhn,bhp->bhpn", bb, dx).astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state.astype(ccd.dtype), ccd)
    y = y + dx * d_skip[None, :, None].astype(dx.dtype)
    return y, state


def mamba_mixer(x: jnp.ndarray, p: dict, cfg,
                cache: Optional[SSMCache] = None,
                ) -> Tuple[jnp.ndarray, Optional[SSMCache]]:
    """Full mamba2 mixer: in_proj -> conv -> SSD/step -> gated norm -> out.

    x: (B, S, D).  With ``cache`` and S == 1, performs a decode step and
    returns the updated cache."""
    bsz, s, d = x.shape
    d_inner, heads = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xbc, dt_raw = _split_proj(xz, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])

    decode = cache is not None and s == 1
    if decode:
        hist = cache.conv
        new_conv = jnp.concatenate([hist, xbc], axis=1)[:, 1:]
        xbc_c = _causal_conv(xbc, p["conv_w"], p["conv_b"], hist)
    else:
        new_conv = None
        xbc_c = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, b_in, c_in = jnp.split(xbc_c, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(bsz, s, heads, cfg.ssm_head_dim)
    b_in = b_in.reshape(bsz, s, g, n)
    c_in = c_in.reshape(bsz, s, g, n)
    xs = shard(xs, "batch", None, "model", None)

    if decode:
        y, new_state = ssm_step(xs[:, 0], b_in[:, 0], c_in[:, 0], dt[:, 0],
                                a, p["d_skip"], cache.state)
        y = y[:, None]
        new_cache = SSMCache(conv=new_conv, state=new_state)
    else:
        init = cache.state if cache is not None else None
        # largest chunk <= cfg.ssm_chunk dividing S (meta tokens can make
        # S a non-multiple; e.g. hymba prefill 32768+128)
        chunk = cfg.ssm_chunk
        while s % chunk:
            chunk //= 2
            if chunk <= 1:
                chunk = 1
                break
        y, final = ssd_forward(xs, b_in, c_in, dt, a, p["d_skip"],
                               chunk, init)
        # prefill: stash the conv-window tail for subsequent decode steps
        new_cache = (SSMCache(conv=xbc[:, -(cfg.ssm_conv - 1):].astype(
                         cache.conv.dtype), state=final)
                     if cache is not None else None)

    y = y.reshape(bsz, s, d_inner)
    # gated RMSNorm (mamba2's norm-before-out-proj, gated by z)
    from repro.models.layers import rms_norm
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["norm_g"]).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out.astype(x.dtype), new_cache
