"""GShard-style token-dropping MoE with dispatch/combine einsums.

Experts are sharded over the ``model`` mesh axis; the dispatch einsum
(tokens batch-sharded -> experts model-sharded) lowers to the canonical
all-to-all under the SPMD partitioner.  Capacity-factor token dropping
bounds the dispatch tensor to (groups, group_size, E, capacity).

DeepSeek-V2 details supported: shared experts (always-on dense experts
added to the routed output) and top-k > 2 routing with softmax-then-top-k.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.params import ParamDef

GROUP_SIZE = 4096  # tokens per dispatch group


def moe_param_table(layers: int, d_model: int, d_ff: int, num_experts: int,
                    num_shared: int, shared_d_ff: int = 0):
    t = {
        "router": ParamDef((layers, d_model, num_experts),
                           ("layers", "fsdp", None), dtype=jnp.float32),
        "wg": ParamDef((layers, num_experts, d_model, d_ff),
                       ("layers", "model", "fsdp", None)),
        "wu": ParamDef((layers, num_experts, d_model, d_ff),
                       ("layers", "model", "fsdp", None)),
        "wd": ParamDef((layers, num_experts, d_ff, d_model),
                       ("layers", "model", None, "fsdp")),
    }
    if num_shared:
        sff = shared_d_ff or d_ff * num_shared
        t["shared_wg"] = ParamDef((layers, d_model, sff),
                                  ("layers", "fsdp", "model"))
        t["shared_wu"] = ParamDef((layers, d_model, sff),
                                  ("layers", "fsdp", "model"))
        t["shared_wd"] = ParamDef((layers, sff, d_model),
                                  ("layers", "model", "fsdp"))
    return t


def _top_k_gating(logits: jnp.ndarray, k: int):
    """logits (G, T, E) -> (weights (G,T,k), indices (G,T,k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx


def moe_ffn(x: jnp.ndarray, p: dict, *, num_experts: int, top_k: int,
            capacity_factor: float = 1.25,
            group_size: int = GROUP_SIZE) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).  p holds this layer's router/wg/wu/wd
    (+ optional shared_*)."""
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t_total = tokens.shape[0]
    g_size = min(group_size, t_total)
    assert t_total % g_size == 0, (t_total, g_size)
    g = t_total // g_size
    xt = tokens.reshape(g, g_size, d)
    xt = shard(xt, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(xt.dtype))
    weights, idx = _top_k_gating(logits, top_k)            # (G,T,k)

    capacity = int(max(top_k, g_size * top_k / num_experts * capacity_factor))
    capacity = min(capacity, g_size)

    # position of each (token, choice) within its expert's queue
    onehot = jax.nn.one_hot(idx, num_experts, dtype=jnp.int32)  # (G,T,k,E)
    # priority: expert choices in token order, k-major within token
    flatoh = onehot.reshape(g, g_size * top_k, num_experts)
    pos_in_expert = jnp.cumsum(flatoh, axis=1) - flatoh
    pos_in_expert = pos_in_expert.reshape(g, g_size, top_k, num_experts)
    within_cap = pos_in_expert < capacity

    # dispatch: (G, T, E, C) one-hot (dropped tokens vanish)
    pos_oh = jax.nn.one_hot(
        jnp.sum(pos_in_expert * onehot, axis=-1), capacity,
        dtype=xt.dtype)                                    # (G,T,k,C)
    disp_k = (onehot.astype(xt.dtype) *
              within_cap.astype(xt.dtype))[..., None] * pos_oh[..., None, :]
    dispatch = jnp.sum(disp_k, axis=2)                     # (G,T,E,C)
    combine = jnp.sum(
        disp_k * weights.astype(xt.dtype)[..., None, None], axis=2)

    # tokens -> expert buffers (all-to-all under SPMD).  Keeping the group
    # dim sharded over `data` is essential: leaving it replicated makes the
    # partitioner all-gather the full (E_loc, G, C, D) expert tensor over
    # the data axis -- measured 10 GB x n_layers on deepseek-v2 prefill_32k
    # (perf iteration [moe-5]).
    ex_in = jnp.einsum("gtec,gtd->egcd", dispatch, xt)
    ex_in = shard(ex_in, "model", "batch", None, None)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", ex_in, p["wg"])
                    .astype(jnp.float32)).astype(xt.dtype)
    h = h * jnp.einsum("egcd,edf->egcf", ex_in, p["wu"])
    ex_out = jnp.einsum("egcf,efd->egcd", h, p["wd"])
    ex_out = shard(ex_out, "model", "batch", None, None)
    y = jnp.einsum("gtec,egcd->gtd", combine, ex_out)

    if "shared_wg" in p:
        from repro.models.layers import swiglu
        y = y + swiglu(xt, p["shared_wg"], p["shared_wu"], p["shared_wd"])
    return y.reshape(b, s, d)


def aux_load_balance_loss(logits: jnp.ndarray, idx: jnp.ndarray,
                          num_experts: int) -> jnp.ndarray:
    """Switch-style auxiliary loss (fraction * probability per expert)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(jax.nn.one_hot(idx[..., 0], num_experts), axis=tuple(range(idx.ndim - 1)))
    pmean = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return num_experts * jnp.sum(frac * pmean)
