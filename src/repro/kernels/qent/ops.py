"""jit'd public wrapper for the qent kernel (padding + entropy reduction)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.qent import qent as _k
from repro.kernels.qent import ref as _ref


def quantized_entropy(x: jnp.ndarray, eps, num_bins: int = _k.DEFAULT_BINS) -> jnp.ndarray:
    """Entropy (bits/symbol) of quantized data via the Pallas histogram.

    Padding uses the first element so the pad value lands in an existing
    bin; its count is subtracted from that bin afterwards.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % _k.DEFAULT_TILE
    if pad:
        flat_p = jnp.concatenate([flat, jnp.broadcast_to(flat[:1], (pad,))])
    else:
        flat_p = flat
    hist = _k.qent_histogram(flat_p, jnp.asarray(eps, jnp.float32), bins=num_bins)
    if pad:
        first_code = jnp.floor(flat[0] / eps).astype(jnp.int32)
        idx = jnp.where(first_code % num_bins < 0,
                        first_code % num_bins + num_bins,
                        first_code % num_bins)
        hist = hist.at[idx].add(-pad)
    return _ref.entropy_bits(hist)
