"""jit'd public wrappers for the qent kernel (padding + entropy reduction)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import tune as _tune
from repro.kernels.qent import qent as _k
from repro.kernels.qent import ref as _ref
from repro.quant import validate_eps_positive as _check_eps


def quantized_entropy_sweep(
    x: jnp.ndarray,
    epss: jnp.ndarray,
    num_bins: int = _k.DEFAULT_BINS,
    *,
    tile: Optional[int] = None,
    tune: Optional[_tune.TuneConfig] = None,
) -> jnp.ndarray:
    """Entropies for a stack of slices at a vector of error bounds.

    ``x``: (k, ...) stack (trailing dims flattened per slice);
    ``epss``: (e,).  Returns (k, e) bits/symbol from one fused kernel
    launch that reads each input tile once.  Per-slice padding reuses the
    slice's own first element (so the pad lands in an existing bin) and
    its count is subtracted from that bin per eps afterwards.

    The kernel tile resolves via the tuned table (explicit ``tile`` >
    ``tune.qent_tile`` > table cell > ``DEFAULT_TILE``); the histogram
    accumulation is integer, so every tile choice is bit-exact.
    """
    _check_eps(epss)
    k = x.shape[0]
    flat = x.reshape(k, -1).astype(jnp.float32)
    epss = jnp.asarray(epss, jnp.float32).reshape(-1)
    e = epss.shape[0]
    n = flat.shape[1]
    tile = _tune.qent_tile(n, num_bins, tune, tile=tile)
    pad = (-n) % tile
    if pad:
        flat_p = jnp.concatenate(
            [flat, jnp.broadcast_to(flat[:, :1], (k, pad))], axis=1)
    else:
        flat_p = flat
    hist = _k.qent_histogram_sweep(flat_p, epss, tile=tile,
                                   bins=num_bins)  # (k, e, B)
    if pad:
        first_code = jnp.clip(               # same saturation as the kernel
            jnp.floor(flat[:, :1] / epss[None, :]),
            _k.INT32_CODE_MIN, _k.INT32_CODE_MAX).astype(jnp.int32)
        idx = first_code % num_bins        # jnp floored-mod: already in [0, B)
        hist = hist.at[jnp.arange(k)[:, None], jnp.arange(e)[None, :], idx
                       ].add(-pad)
    return _ref.entropy_bits_rows(hist)


def quantized_entropy(x: jnp.ndarray, eps, num_bins: int = _k.DEFAULT_BINS,
                      *, tile: Optional[int] = None,
                      tune: Optional[_tune.TuneConfig] = None) -> jnp.ndarray:
    """Entropy (bits/symbol) of one slice at one eps: the (k=1, e=1) case
    of the fused sweep (single implementation of the padding logic)."""
    return quantized_entropy_sweep(
        x.reshape(1, -1), jnp.asarray([eps], jnp.float32), num_bins,
        tile=tile, tune=tune)[0, 0]
