"""Pure-jnp oracle for the qent kernel."""
import jax
import jax.numpy as jnp


def qent_histogram(x: jnp.ndarray, eps, bins: int = 4096) -> jnp.ndarray:
    codes = jnp.floor(x.reshape(-1) / eps).astype(jnp.int32)
    idx = jax.lax.rem(codes, bins)
    idx = jnp.where(idx < 0, idx + bins, idx)
    return jnp.zeros((bins,), jnp.int32).at[idx].add(1)


def entropy_bits(hist: jnp.ndarray) -> jnp.ndarray:
    n = jnp.maximum(jnp.sum(hist), 1)
    p = hist / n
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))


def quantized_entropy(x: jnp.ndarray, eps, bins: int = 4096) -> jnp.ndarray:
    return entropy_bits(qent_histogram(x, eps, bins))


def entropy_bits_rows(hist: jnp.ndarray) -> jnp.ndarray:
    """Entropy along the last (bins) axis of a histogram stack."""
    n = jnp.maximum(jnp.sum(hist, axis=-1, keepdims=True), 1)
    p = hist / n
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0),
                    axis=-1)


def qent_histogram_sweep(x: jnp.ndarray, epss, bins: int = 4096) -> jnp.ndarray:
    """Oracle for the sweep kernel: (k, ...) x (e,) -> (k, e, bins)."""
    k = x.shape[0]
    flat = x.reshape(k, -1)
    return jnp.stack([
        jnp.stack([qent_histogram(flat[s], eps, bins) for eps in epss])
        for s in range(k)])


def quantized_entropy_sweep(x: jnp.ndarray, epss, bins: int = 4096) -> jnp.ndarray:
    return entropy_bits_rows(qent_histogram_sweep(x, epss, bins))
