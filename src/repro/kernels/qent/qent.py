"""Fused quantize+histogram Pallas kernel for the quantized entropy.

The paper's q-ent predictor needs the histogram of ``floor(d / eps)``.
GPUs use atomics/hash maps; TPUs have no scatter in VMEM, so we bucket the
codes into ``B`` *hashed* bins via compare-and-reduce against the bin
iota.  Hash collisions only *lower* the measured entropy; with B = 4096
and the paper's error bounds the code ranges fit in one window so the
hash is injective (tests assert exactness in that regime).

Accumulation scheme: instead of materializing the dense
``(8, tile/8, bins)`` one-hot (33 MB of int32 at the default tile/bins —
over VMEM), each of the 8 sublane rows is compared and reduced on its
own, so the peak live compare is ``(tile/8, bins)`` and the 8 partial
histograms are summed into the accumulator at the end.  When lowering
for real TPU hardware the tile auto-shrinks until that compare fits the
VMEM budget (large-``bins`` configs trade grid steps for residency);
interpret mode keeps the full tile.

``qent_histogram_sweep`` is the sweep engine: a (k, n) stack of slices
x an (e,) vector of error bounds in ONE launch.  Each input tile is read
from HBM once and quantized at every error bound while resident in VMEM,
turning e full passes over the data into one.  The single-(slice, eps)
histogram is its (k=1, e=1) case (see ops.py).

Grid: (slices, tiles of the flattened input); histograms accumulate in
the output ref across grid steps (sequential TPU grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant import INT32_CODE_MIN, INT32_CODE_MAX

DEFAULT_TILE = 2048
DEFAULT_BINS = 4096

# fallback per-sublane compare budget (half of a conservative 16 MB
# VMEM); at trace time the budget is resolved per backend generation
# from tune.BACKEND_HW so 128 MB-VMEM parts stop over-shrinking tiles
_VMEM_COMPARE_BUDGET = 8 * 1024 * 1024


def _compare_budget() -> int:
    from repro.kernels import tune
    try:
        return tune.vmem_compare_budget()
    except Exception:          # backend probe failed: conservative default
        return _VMEM_COMPARE_BUDGET


def _fit_tile(tile: int, bins: int, interpret: bool,
              budget: int | None = None) -> int:
    """Shrink the tile until the per-sublane compare fits VMEM (TPU only).

    Any divisor of the original tile still divides the padded input
    length, so halving preserves the grid invariants.
    """
    if interpret:
        return tile
    budget = _compare_budget() if budget is None else budget
    while tile > 8 and tile % 2 == 0 and (tile // 8) * bins * 4 > budget:
        tile //= 2
    if (tile // 8) * bins * 4 > budget:
        raise ValueError(
            f"qent kernel compare tile (tile/8={tile // 8}, bins={bins}) "
            f"exceeds the {budget}-byte VMEM budget even at "
            f"the minimum tile; use bins <= {budget // 4}")
    return tile


def _hash_codes(x, eps, bins: int):
    """floor(x/eps) (int32-saturated) hashed into [0, bins) (positive mod).

    The clamp saturates instead of wrapping -- a wrapped code would
    scatter into an arbitrary histogram bin."""
    codes = jnp.clip(jnp.floor(x / eps),
                     INT32_CODE_MIN, INT32_CODE_MAX).astype(jnp.int32)
    idx = jax.lax.rem(codes, bins)
    return jnp.where(idx < 0, idx + bins, idx)


def _tile_histogram(idx, bins: int):
    """Histogram of an (8, t) index tile via per-sublane partial
    histograms: 8 compares of (t, bins) each, summed at the end."""
    hist = jnp.zeros((bins,), jnp.int32)
    t = idx.shape[1]
    bins_iota = jax.lax.broadcasted_iota(jnp.int32, (t, bins), 1)
    for s in range(idx.shape[0]):
        eq = (idx[s, :, None] == bins_iota).astype(jnp.int32)
        hist += jnp.sum(eq, axis=0)
    return hist


def _qent_sweep_kernel(eps_ref, x_ref, hist_ref, *, bins: int, n_eps: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = x_ref[0]                                     # (8, tile/8): ONE read
    for ei in range(n_eps):                          # e histograms, 0 rereads
        idx = _hash_codes(x, eps_ref[ei], bins)
        hist_ref[0, ei, :] += _tile_histogram(idx, bins)


@functools.partial(jax.jit, static_argnames=("tile", "bins"))
def qent_histogram_sweep(
    x: jnp.ndarray,
    epss: jnp.ndarray,
    tile: int = DEFAULT_TILE,
    bins: int = DEFAULT_BINS,
) -> jnp.ndarray:
    """(k, n) slice stack x (e,) error bounds -> (k, e, bins) histograms.

    One launch; grid = (k slices, n/tile tiles).  Each tile is quantized
    at all e error bounds while resident in VMEM.
    """
    k, n = x.shape
    (n_eps,) = epss.shape
    assert n % tile == 0, (n, tile)
    interpret = jax.default_backend() != "tpu"
    tile = _fit_tile(tile, bins, interpret)
    xb = jnp.swapaxes(x.reshape(k, n // 8, 8), 1, 2)  # (k, 8, n/8)
    kernel = functools.partial(_qent_sweep_kernel, bins=bins, n_eps=n_eps)
    return pl.pallas_call(
        kernel,
        grid=(k, n // tile),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 8, tile // 8), lambda s, t: (s, 0, t)),
        ],
        out_specs=pl.BlockSpec((1, n_eps, bins), lambda s, t: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n_eps, bins), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(epss, jnp.float32), xb)
