"""Fused quantize+histogram Pallas kernel for the quantized entropy.

The paper's q-ent predictor needs the histogram of ``floor(d / eps)``.
GPUs use atomics/hash maps; TPUs have no scatter in VMEM, so we bucket the
codes into ``B`` *hashed* bins via a one-hot compare-and-reduce, which the
VPU executes as dense (T, B) lane-parallel ops -- the standard TPU
histogram idiom.  Hash collisions only *lower* the measured entropy; with
B = 4096 and the paper's error bounds the code ranges fit in one window so
the hash is injective (tests assert exactness in that regime).

Grid: 1-D over tiles of the flattened input; the histogram accumulates in
the output ref across grid steps (sequential TPU grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE = 2048
DEFAULT_BINS = 4096


def _qent_kernel(eps_ref, x_ref, hist_ref, *, bins: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    eps = eps_ref[0]
    x = x_ref[...]                                   # (8, tile/8) f32
    codes = jnp.floor(x / eps).astype(jnp.int32)
    idx = jax.lax.rem(codes, bins)
    idx = jnp.where(idx < 0, idx + bins, idx)        # positive mod
    # one-hot compare against the bin iota, reduce over the tile
    bins_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bins), 2)
    eq = (idx[:, :, None] == bins_iota).astype(jnp.int32)
    hist_ref[...] += jnp.sum(eq, axis=(0, 1))


@functools.partial(jax.jit, static_argnames=("tile", "bins"))
def qent_histogram(
    x: jnp.ndarray,
    eps: jnp.ndarray,
    tile: int = DEFAULT_TILE,
    bins: int = DEFAULT_BINS,
) -> jnp.ndarray:
    """Histogram of hashed quantization codes. x: flat f32, len % tile == 0."""
    (n,) = x.shape
    assert n % tile == 0, (n, tile)
    x2 = x.reshape(n // 8, 8).T                      # (8, n/8): sublane-major
    eps_arr = jnp.asarray([eps], jnp.float32)
    kernel = functools.partial(_qent_kernel, bins=bins)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((8, tile // 8), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((bins,), jnp.int32),
        interpret=jax.default_backend() != "tpu",
    )(eps_arr, x2)
