"""Public entry point for the fused quality sweep.

``quality_sweep`` owns everything both routes share -- flattening,
per-slice extrema on the UNPADDED data, zero-padding to a tile multiple,
the (k, 8, n/8) layout, and the PSNR/NRMSE finalization -- then
dispatches the SSE reduction to the jnp reference or the Pallas kernel.
Because the shared pieces are literally the same code and the two SSE
routes are bit-equal by construction (see ``ref``), the full (k, e, 2)
quality tensor is bitwise identical whichever route runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quality import ref as _ref
from repro.quant import validate_eps_positive as _check_eps


@functools.partial(jax.jit, static_argnames=("use_kernel", "tile"))
def _quality_sweep_jit(x, epss, *, use_kernel: bool, tile: int):
    k = x.shape[0]
    flat = x.astype(jnp.float32).reshape(k, -1)
    n = flat.shape[1]
    vmin = jnp.min(flat, axis=1)
    vmax = jnp.max(flat, axis=1)
    pad = (-n) % tile
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((k, pad), jnp.float32)], axis=1)
    xb = jnp.swapaxes(flat.reshape(k, (n + pad) // 8, 8), 1, 2)
    if use_kernel:
        from repro.kernels.quality import quality as _kern
        sse = _kern.qdq_sse_sweep(xb, epss, tile=tile)
    else:
        sse = _ref.sse_sweep(xb, epss, tile)
    return _ref.quality_from_stats(sse, n, vmin, vmax)


def quality_sweep(x: jnp.ndarray, epss, *, use_kernel: bool = False,
                  tile: int | None = None) -> jnp.ndarray:
    """(k, ...) stack x (e,) error bounds -> (k, e, 2) [PSNR dB, NRMSE].

    PSNR and NRMSE of the quantization proxy: quantize-dequantize each
    slice at every error bound (saturating int32 quantizer from
    ``repro.quant``) and score the reconstruction against the original.
    Exactly-representable slices report ``PSNR_CAP`` (not inf/NaN);
    zero-range slices with nonzero error report ``-PSNR_CAP`` and an
    ``NRMSE_CAP``-clipped NRMSE -- every emitted value is finite.

    One read of the data for the whole eb grid; ``use_kernel=True``
    routes the SSE reduction through the Pallas kernel (interpret mode
    off-TPU), bit-equal to the default jnp route.

    The whole entry is jitted: eager elementwise chains compile one op
    per executable (no multiply-add contraction), so an eager route
    would NOT be bit-equal to the jitted production paths.  Keeping
    every route inside a jit is part of the bit-equality contract.
    """
    _check_eps(epss)
    epss = jnp.asarray(epss, jnp.float32).reshape(-1)
    tile = _ref.DEFAULT_TILE if tile is None else int(tile)
    c = tile // 8
    if tile % 8 or c & (c - 1):
        raise ValueError(
            f"quality_sweep tile must be 8 * 2**j (fixed balanced "
            f"reduction tree), got {tile}")
    return _quality_sweep_jit(jnp.asarray(x), epss, use_kernel=use_kernel,
                              tile=tile)
