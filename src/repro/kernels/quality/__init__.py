"""Fused per-(slice, eb) quality-metric sweep (PSNR / NRMSE of the
quantization proxy): jnp reference route in ``ref``, the Pallas kernel in
``quality``, public dispatch in ``ops``."""

from repro.kernels.quality.ops import quality_sweep  # noqa: F401
from repro.kernels.quality.ref import (  # noqa: F401
    DEFAULT_TILE,
    NRMSE_CAP,
    PSNR_CAP,
)
