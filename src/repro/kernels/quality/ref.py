"""jnp reference route for the fused quality sweep.

Computes, for a stack of slices and a grid of error bounds, the sum of
squared quantize-dequantize errors -- the one data-dependent reduction
behind PSNR and NRMSE of the quantization proxy.  Everything here is
written so the batched reference and the per-slice Pallas kernel produce
BITWISE identical f32 results:

- the only reduction is an explicit balanced elementwise tree
  (``tile_sse``), never ``jnp.sum`` -- XLA is free to reshape a generic
  reduction's tree with the batch shape, elementwise adds it is not;
- tiles accumulate sequentially in the same order as the kernel's grid
  (last grid dimension fastest), as a plain ``+=`` chain;
- padding is with 0.0: the QDQ error of 0.0 is exactly 0.0 for every
  eps, and adding +0.0 to a (>= +0.0) f32 accumulator is a bitwise
  no-op, so padded and unpadded streams agree bit for bit.

No Pallas imports here: this module is the oracle the kernel is checked
against, and it must load on environments without pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import INT32_CODE_MAX, INT32_CODE_MIN

# The tile is part of the numerical spec, not a tuning knob: SSE partial
# sums depend on the accumulation boundaries, so every route (reference,
# kernel, sharded, streamed, served) must use the same tile.  8 sublanes
# x 256 lanes; the lane count must be a power of two for the halving
# tree in ``tile_sse``.
DEFAULT_TILE = 2048

# PSNR is clamped to +-PSNR_CAP dB.  An exactly-representable slice
# (SSE == 0, "infinite" PSNR) reports +PSNR_CAP; a zero-range slice with
# nonzero error reports -PSNR_CAP.  300 dB sits far above the ~200 dB
# ceiling int32 quantization can express, so no real measurement clips.
PSNR_CAP = 300.0

# NRMSE of a zero-range slice with nonzero error would be +inf; the cap
# keeps every emitted tensor finite (tests assert no NaN/inf anywhere).
NRMSE_CAP = 1e30


def qdq_error_sq(x, eps):
    """Elementwise squared quantize-dequantize error at ``eps``.

    Same saturating uniform quantizer as the predictor stack
    (``repro.quant``): codes are ``floor(x / eps)`` clipped to the int32
    range, dequantized as ``code * eps``.
    """
    codes = jnp.clip(jnp.floor(x / eps), INT32_CODE_MIN,
                     INT32_CODE_MAX).astype(jnp.int32)
    err = x - codes.astype(jnp.float32) * eps
    return err * err


def tile_sse(err2):
    """Reduce a (..., 8, c) squared-error tile to (...) with a FIXED
    balanced tree of elementwise adds (c must be a power of two).

    The 8 sublanes fold as explicit pairs, then the lane axis halves
    until scalar.  Elementwise adds are bit-deterministic per element
    regardless of leading batch dims, which is what makes the batched
    reference bit-equal to the per-slice kernel.
    """
    s = err2
    v = (((s[..., 0, :] + s[..., 1, :]) + (s[..., 2, :] + s[..., 3, :]))
         + ((s[..., 4, :] + s[..., 5, :]) + (s[..., 6, :] + s[..., 7, :])))
    while v.shape[-1] > 1:
        v = v[..., 0::2] + v[..., 1::2]
    return v[..., 0]


def tile_sse_all_eps(xt, epss, n_eps):
    """One (..., 8, c) tile -> (..., n_eps) SSE, one eps at a time.

    Shared verbatim by the reference loop and the Pallas kernel body
    (``epss`` may be a traced array or an SMEM ref -- both index the
    same way), so the two routes run structurally identical ops.
    """
    return jnp.stack([tile_sse(qdq_error_sq(xt, epss[ei]))
                      for ei in range(n_eps)], axis=-1)


def sse_sweep(xb, epss, tile):
    """(k, 8, n/8) tiled layout x (e,) -> (k, e) f32 SSE, reference route.

    ``xb`` is the kernel's input layout: the zero-padded flat slice
    reshaped (k, n/8, 8) and swapped to (k, 8, n/8), so element i sits
    at sublane i % 8, column i // 8, and tile t covers the contiguous
    elements [t*tile, (t+1)*tile).  Tiles accumulate sequentially --
    the same order as the kernel's (k, T) grid with T fastest.
    """
    n_eps = int(epss.shape[0])
    c = tile // 8
    steps = xb.shape[2] // c
    acc = jnp.zeros(xb.shape[:1] + (n_eps,), jnp.float32)
    for t in range(steps):
        acc = acc + tile_sse_all_eps(xb[:, :, t * c:(t + 1) * c], epss, n_eps)
    return acc


# 1/ln(2) and log10(2) as f32 constants for the deterministic log10.
_INV_LN2 = 1.4426950408889634
_LOG10_2 = 0.30102999566398120


def det_log10(x):
    """Bit-deterministic elementwise log10 for positive f32 inputs.

    Library ``log`` implementations are NOT batch-shape-invariant on
    CPU -- the SIMD main loop and the scalar remainder round differently,
    so the same element changes bits when its array length changes
    (exactly what sharding does).  This one uses only bitcasts, +, *, /
    (each IEEE correctly rounded per element), so its bits never move
    with the batch shape.

    Split x = m * 2**e with m in [1, 2) via the f32 bit layout
    (subnormals pre-scaled by 2**64), then log2(m) from the atanh
    series in t = (m-1)/(m+1) (|t| <= 1/3: the t**15 tail is < 1e-8,
    below f32 resolution).  x <= 0 maps to -1e4, which the PSNR clip
    floors out exactly like the -inf a true log would give.  XLA CPU
    runs with denormals-are-zero, so subnormal inputs take the same
    -1e4 branch -- a subnormal data range degrades to the clip caps,
    deterministically, on every route.
    """
    x = x.astype(jnp.float32)
    small = x < 2.0 ** -100
    xs = jnp.where(small, x * jnp.float32(2.0 ** 64), x)
    bits = jax.lax.bitcast_convert_type(xs, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    m = jax.lax.bitcast_convert_type(
        (bits & 0x007FFFFF) | (127 << 23), jnp.float32)
    t = (m - 1.0) / (m + 1.0)
    s = t * t
    p = jnp.float32(1.0 / 13.0)
    for q in (1.0 / 11.0, 1.0 / 9.0, 1.0 / 7.0, 1.0 / 5.0, 1.0 / 3.0, 1.0):
        p = p * s + jnp.float32(q)
    log2m = (2.0 * _INV_LN2) * (t * p)
    log2x = e.astype(jnp.float32) + log2m - jnp.where(small, 64.0, 0.0)
    return jnp.where(x > 0.0, jnp.float32(_LOG10_2) * log2x,
                     jnp.float32(-1e4))


def quality_from_stats(sse, n, vmin, vmax):
    """(k, e) SSE + per-slice stats -> (k, e, 2) [PSNR dB, NRMSE].

    ``n`` is the UNPADDED element count and ``vmin``/``vmax`` the
    unpadded per-slice extrema (shared by every route).  ``abs`` on the
    range kills the -0.0 hazard: on a mixed-sign-zero slice min/max may
    tie-break either way, and a -0.0 range would send NRMSE to -inf on
    one route and +inf on another.
    """
    rng = jnp.abs(vmax - vmin)[:, None]                      # (k, 1)
    mse = sse / jnp.float32(n)
    exact = sse == 0.0
    psnr = jnp.where(
        exact, jnp.float32(PSNR_CAP),
        jnp.clip(20.0 * det_log10(rng) - 10.0 * det_log10(mse),
                 -PSNR_CAP, PSNR_CAP))
    nrmse = jnp.where(
        exact, jnp.float32(0.0),
        jnp.clip(jnp.sqrt(mse) / rng, 0.0, jnp.float32(NRMSE_CAP)))
    return jnp.stack([psnr, nrmse], axis=-1)
