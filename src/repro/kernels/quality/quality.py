"""Fused quantize-dequantize SSE Pallas kernel (the quality sweep).

One launch computes the sum of squared QDQ errors for a (k, n) stack of
flattened slices x an (e,) vector of error bounds: each input tile is
read from HBM once and quantize-dequantized at every error bound while
resident in VMEM, exactly like ``kernels/qent``.  The reduction inside a
tile is the fixed balanced elementwise tree from ``ref.tile_sse`` (the
same code object), and tiles accumulate across the sequential TPU grid
in the same order as ``ref.sse_sweep``'s Python loop -- that pairing is
what makes the kernel route BITWISE equal to the jnp reference.

Unlike qent there is no ``_fit_tile``: the per-eps live tile is a single
(8, tile/8) f32 block (8 KB at the default tile), nowhere near VMEM
limits, and the tile size is part of the numerical spec (accumulation
boundaries move with it), so it must never silently shrink per backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quality import ref as _ref

DEFAULT_TILE = _ref.DEFAULT_TILE


def _quality_sweep_kernel(eps_ref, x_ref, sse_ref, *, n_eps: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        sse_ref[...] = jnp.zeros_like(sse_ref)

    x = x_ref[0]                                     # (8, tile/8): ONE read
    sse_ref[0, :] += _ref.tile_sse_all_eps(x, eps_ref, n_eps)


@functools.partial(jax.jit, static_argnames=("tile",))
def qdq_sse_sweep(xb: jnp.ndarray, epss: jnp.ndarray,
                  tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """(k, 8, n/8) tiled stack x (e,) error bounds -> (k, e) f32 SSE.

    ``xb`` is the shared layout produced by ``ops.quality_sweep`` (flat
    slices zero-padded to a tile multiple, reshaped (k, n/8, 8), axes
    1/2 swapped).  Grid = (k slices, n/tile tiles), SSE accumulates in
    the output ref across the sequential grid.
    """
    k = xb.shape[0]
    n = xb.shape[2] * 8
    (n_eps,) = epss.shape
    assert n % tile == 0, (n, tile)
    interpret = jax.default_backend() != "tpu"
    kernel = functools.partial(_quality_sweep_kernel, n_eps=n_eps)
    return pl.pallas_call(
        kernel,
        grid=(k, n // tile),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 8, tile // 8), lambda s, t: (s, 0, t)),
        ],
        out_specs=pl.BlockSpec((1, n_eps), lambda s, t: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n_eps), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(epss, jnp.float32), xb)
