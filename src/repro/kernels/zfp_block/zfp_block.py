"""ZFP forward-transform Pallas kernel (2-D, 4x4 blocks).

Per 4x4 block: block-floating-point alignment to the block's max exponent,
then the exact zfp integer lifting along rows and columns.  A (BM, BN) VMEM
tile holds (BM/4) x (BN/4) blocks; the lifting is expressed as strided
slices of the tile so all blocks advance in lockstep on the VPU (no 4-wide
vectors: lanes stay 128-wide).

Outputs: transformed int32 coefficients (same layout) + per-block exponents.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTPREC = 26
DEFAULT_BM = 128
DEFAULT_BN = 128


def _lift_rows(q: jnp.ndarray) -> jnp.ndarray:
    """Lift along axis 0 within each 4-row group: q is (BM, BN) int32."""
    x, y, z, w = q[0::4], q[1::4], q[2::4], q[3::4]
    x = x + w; x = x >> 1; w = w - x
    z = z + y; z = z >> 1; y = y - z
    x = x + z; x = x >> 1; z = z - x
    w = w + y; w = w >> 1; y = y - w
    w = w + (y >> 1); y = y - (w >> 1)
    bm, bn = q.shape
    out = jnp.zeros_like(q)
    out = out.at[0::4].set(x).at[1::4].set(y).at[2::4].set(z).at[3::4].set(w)
    return out


def _lift_cols(q: jnp.ndarray) -> jnp.ndarray:
    x, y, z, w = q[:, 0::4], q[:, 1::4], q[:, 2::4], q[:, 3::4]
    x = x + w; x = x >> 1; w = w - x
    z = z + y; z = z >> 1; y = y - z
    x = x + z; x = x >> 1; z = z - x
    w = w + y; w = w >> 1; y = y - w
    w = w + (y >> 1); y = y - (w >> 1)
    out = jnp.zeros_like(q)
    out = (out.at[:, 0::4].set(x).at[:, 1::4].set(y)
              .at[:, 2::4].set(z).at[:, 3::4].set(w))
    return out


def _block_exponents(x: jnp.ndarray) -> jnp.ndarray:
    """(BM, BN) -> (BM/4, BN/4) ceil-log2 max-abs exponent per 4x4 block."""
    bm, bn = x.shape
    a = jnp.abs(x).reshape(bm // 4, 4, bn // 4, 4)
    amax = jnp.max(a, axis=(1, 3))
    e = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-38))).astype(jnp.int32)
    return jnp.where(amax > 0, e, 0)


def _zfp_kernel(x_ref, coef_ref, exp_ref):
    x = x_ref[...].astype(jnp.float32)
    e = _block_exponents(x)                                  # (BM/4, BN/4)
    scale = jnp.exp2((INTPREC - 2 - e).astype(jnp.float32))
    scale_full = jnp.repeat(jnp.repeat(scale, 4, axis=0), 4, axis=1)
    q = jnp.round(x * scale_full).astype(jnp.int32)
    q = _lift_rows(q)
    q = _lift_cols(q)
    coef_ref[...] = q
    exp_ref[...] = e


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def zfp_forward2d(x: jnp.ndarray, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """(m, n) -> (coeffs int32 (m, n), exponents int32 (m/4, n/4)).

    m % bm == 0, n % bn == 0 and bm, bn % 4 == 0 (ops.py pads).
    """
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _zfp_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm // 4, bn // 4), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m // 4, n // 4), jnp.int32),
        ],
        interpret=jax.default_backend() != "tpu",
    )(x)
