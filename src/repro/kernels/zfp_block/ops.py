"""jit'd public wrapper for the zfp_block kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.zfp_block import zfp_block as _k


def zfp_forward2d(x: jnp.ndarray):
    """Forward zfp transform of an arbitrary (m, n) slice.

    Edge-pads to tile multiples; returns (coeffs, exponents) cropped back to
    the 4-padded extent (the compressor consumes whole 4x4 blocks).
    """
    m, n = x.shape
    m4, n4 = m + ((-m) % 4), n + ((-n) % 4)
    xp = jnp.pad(x, ((0, m4 - m), (0, n4 - n)), mode="edge")
    pm, pn = (-m4) % _k.DEFAULT_BM, (-n4) % _k.DEFAULT_BN
    xp = jnp.pad(xp, ((0, pm), (0, pn)), mode="edge")
    coef, exp = _k.zfp_forward2d(xp)
    return coef[:m4, :n4], exp[: m4 // 4, : n4 // 4]
