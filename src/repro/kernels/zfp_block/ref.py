"""Pure-jnp oracle for the zfp_block kernel: repro.compressors.zfp semantics
re-expressed in the kernel's (m, n) coefficient layout."""
import jax.numpy as jnp

from repro.compressors import zfp as Z


def zfp_forward2d(x: jnp.ndarray):
    q_blocks, e, padded_shape = Z.zfp_transform(x.astype(jnp.float32))
    m, n = padded_shape
    coef = Z._from_blocks4(q_blocks, padded_shape)
    exp = e.reshape(m // 4, n // 4)
    return coef, exp
