"""jit'd public wrapper for the Gram kernel (handles padding + transpose)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gram import gram as _k


def _pad_to(x: jnp.ndarray, mult_m: int, mult_n: int) -> jnp.ndarray:
    m, n = x.shape
    return jnp.pad(x, ((0, (-m) % mult_m), (0, (-n) % mult_n)))


def gram(x: jnp.ndarray, transpose: bool = True) -> jnp.ndarray:
    """Gram matrix of the smaller side; zero padding is exact for X^T X.

    transpose=True  -> X^T X  (n x n)
    transpose=False -> X X^T  (m x m)  (computed as (X^T)^T (X^T))
    """
    x = x.astype(jnp.float32)
    if not transpose:
        x = x.T
    n = x.shape[1]
    xp = _pad_to(x, _k.DEFAULT_BK, _k.DEFAULT_BN)
    g = _k.gram_xtx(xp)
    return g[:n, :n]


def _pad_to_batched(x: jnp.ndarray, mult_m: int, mult_n: int) -> jnp.ndarray:
    _, m, n = x.shape
    return jnp.pad(x, ((0, 0), (0, (-m) % mult_m), (0, (-n) % mult_n)))


def gram_batched(x: jnp.ndarray, transpose: bool = True) -> jnp.ndarray:
    """Batched Gram over a (k, m, n) stack of slices in one kernel launch.

    transpose=True  -> X^T X per slice: (k, n, n)
    transpose=False -> X X^T per slice: (k, m, m)
    """
    x = x.astype(jnp.float32)
    if not transpose:
        x = jnp.swapaxes(x, 1, 2)
    n = x.shape[2]
    xp = _pad_to_batched(x, _k.DEFAULT_BK, _k.DEFAULT_BN)
    g = _k.gram_xtx_batched(xp)
    return g[:, :n, :n]
