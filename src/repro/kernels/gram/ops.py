"""jit'd public wrapper for the Gram kernel (handles padding + transpose)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import tune as _tune
from repro.kernels.gram import gram as _k


def _pad_to(x: jnp.ndarray, mult_m: int, mult_n: int) -> jnp.ndarray:
    m, n = x.shape
    return jnp.pad(x, ((0, (-m) % mult_m), (0, (-n) % mult_n)))


def gram(x: jnp.ndarray, transpose: bool = True, *,
         bn: Optional[int] = None, bk: Optional[int] = None,
         tune: Optional[_tune.TuneConfig] = None) -> jnp.ndarray:
    """Gram matrix of the smaller side; zero padding is exact for X^T X.

    transpose=True  -> X^T X  (n x n)
    transpose=False -> X X^T  (m x m)  (computed as (X^T)^T (X^T))

    Block sizes resolve via the tuned table (explicit ``bn``/``bk`` >
    ``tune`` fields > table cell > kernel defaults); zero-padding to the
    resolved multiples keeps every choice exact for the top-left block.
    """
    x = x.astype(jnp.float32)
    if not transpose:
        x = x.T
    m, n = x.shape
    bn, bk = _tune.gram_blocks(m, n, tune, bn=bn, bk=bk)
    xp = _pad_to(x, bk, bn)
    g = _k.gram_xtx(xp, bn=bn, bk=bk)
    return g[:n, :n]


def _pad_to_batched(x: jnp.ndarray, mult_m: int, mult_n: int) -> jnp.ndarray:
    _, m, n = x.shape
    return jnp.pad(x, ((0, 0), (0, (-m) % mult_m), (0, (-n) % mult_n)))


def gram_batched(x: jnp.ndarray, transpose: bool = True, *,
                 bn: Optional[int] = None, bk: Optional[int] = None,
                 tune: Optional[_tune.TuneConfig] = None) -> jnp.ndarray:
    """Batched Gram over a (k, m, n) stack of slices in one kernel launch.

    transpose=True  -> X^T X per slice: (k, n, n)
    transpose=False -> X X^T per slice: (k, m, m)

    Block-size resolution matches :func:`gram`.
    """
    x = x.astype(jnp.float32)
    if not transpose:
        x = jnp.swapaxes(x, 1, 2)
    _, m, n = x.shape
    bn, bk = _tune.gram_blocks(m, n, tune, bn=bn, bk=bk)
    xp = _pad_to_batched(x, bk, bn)
    g = _k.gram_xtx_batched(xp, bn=bn, bk=bk)
    return g[:, :n, :n]
