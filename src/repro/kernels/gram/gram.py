"""Tiled Gram-matrix Pallas kernel: G = X^T X (or X X^T).

The SVD-trunc predictor needs only singular *values*; on TPU we get them
from ``eigvalsh`` of the Gram matrix, turning the predictor's hot loop into
one MXU-resident matmul.  Classic three-loop tiling: grid = (n/bn, n/bn,
m/bk) with accumulation over the contraction tiles; 128-aligned blocks to
match the MXU systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BN = 128   # output tile edge (MXU-aligned)
DEFAULT_BK = 128   # contraction tile


def _gram_kernel(x1_ref, x2_ref, o_ref):
    """One (bn, bn) output tile; accumulates over the k grid dimension."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = x1_ref[...]            # (bk, bn) tile of X[:, i-block]
    b = x2_ref[...]            # (bk, bn) tile of X[:, j-block]
    o_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bn", "bk"))
def gram_xtx(x: jnp.ndarray, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> jnp.ndarray:
    """X^T X for (m, n) x, m % bk == 0 and n % bn == 0 (pad in ops.py)."""
    m, n = x.shape
    assert m % bk == 0 and n % bn == 0, (m, n, bk, bn)
    grid = (n // bn, n // bn, m // bk)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=_interpret_default(),
    )(x, x)


def _gram_batched_kernel(x1_ref, x2_ref, o_ref):
    """One (bn, bn) output tile of one slice; grid = (k, n/bn, n/bn, m/bk).

    The slice index is the *leading* grid dimension, so the whole stack of
    Gram matrices runs as a single MXU-resident launch: the accumulator
    tile stays in VMEM across the contraction steps of each slice and the
    k separate kernel launches of the unbatched path collapse into one.
    """
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = x1_ref[0]              # (bk, bn) tile of X[s, :, i-block]
    b = x2_ref[0]              # (bk, bn) tile of X[s, :, j-block]
    o_ref[0] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bn", "bk"))
def gram_xtx_batched(x: jnp.ndarray, bn: int = DEFAULT_BN,
                     bk: int = DEFAULT_BK) -> jnp.ndarray:
    """Batched X^T X: (k, m, n) -> (k, n, n), one launch for all k slices."""
    k, m, n = x.shape
    assert m % bk == 0 and n % bn == 0, (k, m, n, bk, bn)
    grid = (k, n // bn, n // bn, m // bk)
    return pl.pallas_call(
        _gram_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk, bn), lambda s, i, j, t: (s, t, i)),
            pl.BlockSpec((1, bk, bn), lambda s, i, j, t: (s, t, j)),
        ],
        out_specs=pl.BlockSpec((1, bn, bn), lambda s, i, j, t: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n, n), jnp.float32),
        interpret=_interpret_default(),
    )(x, x)


def _interpret_default() -> bool:
    """TPU lowering on TPU backends, interpreter elsewhere (CPU CI)."""
    return jax.default_backend() != "tpu"
