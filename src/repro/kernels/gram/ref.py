"""Pure-jnp oracle for the Gram kernel."""
import jax.numpy as jnp


def gram_xtx(x: jnp.ndarray) -> jnp.ndarray:
    return x.astype(jnp.float32).T @ x.astype(jnp.float32)


def gram_xxt(x: jnp.ndarray) -> jnp.ndarray:
    return x.astype(jnp.float32) @ x.astype(jnp.float32).T


def gram_xtx_batched(x: jnp.ndarray) -> jnp.ndarray:
    """(k, m, n) -> (k, n, n) stack of X^T X."""
    xf = x.astype(jnp.float32)
    return jnp.einsum("kmi,kmj->kij", xf, xf)
