"""jit'd public wrapper for the Lorenzo kernel (padding to tile multiples)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.lorenzo import lorenzo as _k


def lorenzo2d(x: jnp.ndarray, eps) -> jnp.ndarray:
    """Lorenzo codes for arbitrary (m, n); edge-pad then crop.

    Edge padding replicates the boundary so cropped codes equal the unpadded
    kernel's codes (replicated rows produce zero differences).
    """
    m, n = x.shape
    pm, pn = (-m) % _k.DEFAULT_BM, (-n) % _k.DEFAULT_BN
    xp = jnp.pad(x, ((0, pm), (0, pn)), mode="edge")
    codes = _k.lorenzo2d(xp, jnp.asarray(eps, jnp.float32))
    return codes[:m, :n]
