"""Dual-quantization Lorenzo Pallas kernel (cuSZ reformulation, 2-D).

codes[i,j] = Q(x[i,j]) - Q(x[i-1,j]) - Q(x[i,j-1]) + Q(x[i-1,j-1])

where Q is the bounded quantizer.  Classic SZ is sequential (predicts from
reconstructed values); dual quantization pre-quantizes every element, making
the stencil embarrassingly parallel.  The cross-tile halo is handled with
the recompute-over-communicate idiom: the kernel receives four shifted views
of the zero-padded input (four overlapping HBM->VMEM streams of the same
buffer) and re-quantizes each -- redundant VPU flops instead of
neighbour-tile synchronization, the right trade on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256


def _quantize(x, eps):
    q = jnp.round(x / (2.0 * eps)).astype(jnp.int32)
    for _ in range(2):
        recon = jax.lax.optimization_barrier(q.astype(jnp.float32) * (2.0 * eps))
        err = x - recon
        q = q + (err > eps).astype(jnp.int32) - (err < -eps).astype(jnp.int32)
    return q


def _lorenzo_kernel(eps_ref, a_ref, b_ref, c_ref, d_ref, o_ref):
    eps = eps_ref[0]
    qa = _quantize(a_ref[...], eps)   # x[i, j]
    qb = _quantize(b_ref[...], eps)   # x[i-1, j]
    qc = _quantize(c_ref[...], eps)   # x[i, j-1]
    qd = _quantize(d_ref[...], eps)   # x[i-1, j-1]
    o_ref[...] = qa - qb - qc + qd


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def lorenzo2d(
    x: jnp.ndarray,
    eps: jnp.ndarray,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """2-D Lorenzo codes; x shape (m, n) with m % bm == 0, n % bn == 0."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, bm, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((1, 0), (1, 0)))
    a = xp[1:, 1:]
    b = xp[:-1, 1:]
    c = xp[1:, :-1]
    d = xp[:-1, :-1]
    eps_arr = jnp.asarray([eps], jnp.float32)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _lorenzo_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=jax.default_backend() != "tpu",
    )(eps_arr, a, b, c, d)
