"""Pure-jnp oracle for the Lorenzo kernel: repro.compressors.sz semantics."""
import jax.numpy as jnp

from repro.compressors.sz import lorenzo_encode, lorenzo_decode  # noqa: F401


def lorenzo2d(x: jnp.ndarray, eps) -> jnp.ndarray:
    return lorenzo_encode(x.astype(jnp.float32), eps)
