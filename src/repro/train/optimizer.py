"""AdamW with fp32 moments, sharded like the parameters.

No optax dependency: the optimizer is a pure pytree transform so its state
inherits each parameter's sharding (ZeRO-style: moments sharded over
data x model exactly as the weights are).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any                 # fp32 first moments  (param tree)
    nu: Any                 # fp32 second moments (param tree)


def init(params) -> OptState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(z, params),
                    nu=jax.tree.map(z, params))


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply(cfg: AdamWConfig, params, grads, state: OptState
          ) -> Tuple[Any, OptState, jnp.ndarray]:
    """One AdamW step; returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), gnorm
