"""Error-feedback gradient compression gated by the paper's CR prediction.

Integration of the paper into distributed training: before each gradient
sync, per-bucket quantized entropy (the paper's q-ent predictor, computed
with the same Pallas-backed primitive) estimates whether int8 block
quantization will pay for itself on the wire.  Buckets whose predicted
compressed size clears ``gate_ratio`` are quantized with error feedback
(residuals carried to the next step -- convergence-safe); incompressible
buckets ship uncompressed.

The same int8 block format feeds ``repro.dist.collectives`` for the
cross-pod all-gather path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (per-block scale)


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    enabled: bool = True
    gate_ratio: float = 2.0       # predicted CR must beat this to compress
    qent_bins: int = 4096


class EFState(NamedTuple):
    """Error-feedback residuals, one per compressible leaf."""
    residuals: Any


def init_ef(grads) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _blockify(flat: jnp.ndarray) -> jnp.ndarray:
    n = flat.shape[0]
    pad = (-n) % BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-wise symmetric int8: returns (codes (nb, BLOCK) i8, scales)."""
    blocks = _blockify(x.reshape(-1).astype(jnp.float32))
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    # explicit reciprocal-multiply: XLA rewrites /127.0 into * (1/127.0)
    # when this runs under jit but not eagerly, so the division form makes
    # jitted and eager quantization disagree by 1 ulp in the scales
    scale = jnp.maximum(amax, 1e-12) * (1.0 / 127.0)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale[:, 0]


def dequantize_int8(codes: jnp.ndarray, scales: jnp.ndarray,
                    shape, dtype=jnp.float32) -> jnp.ndarray:
    blocks = codes.astype(jnp.float32) * scales[:, None]
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


def predicted_cr_int8(g: jnp.ndarray, bins: int = 4096) -> jnp.ndarray:
    """Predicted CR of the int8+entropy-coded gradient vs raw fp32.

    Uses the paper's quantized-entropy size model (jittable, in-graph):
    size ~ N * H(codes) / 8 + scales.  CR = 4N / size.
    """
    codes, scales = quantize_int8(g)
    flat = codes.reshape(-1).astype(jnp.int32)
    idx = (flat + 128) % bins
    counts = jnp.zeros((bins,), jnp.int32).at[idx].add(1)
    n = flat.shape[0]
    p = counts / n
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))
    size_bytes = n * h / 8.0 + scales.shape[0] * 4.0
    return (4.0 * n) / jnp.maximum(size_bytes, 1.0)


def compress_tree(grads, ef: EFState, cfg: CompressConfig
                  ) -> Tuple[Any, EFState, Any]:
    """Quantize-dequantize each leaf with error feedback + q-ent gating.

    Returns (synced_grads, new_ef, diagnostics{leaf: predicted_cr}).
    The quantize->dequantize round trip is exactly what the compressed
    collective transmits; the gate uses the in-graph q-ent size model.
    """
    if not cfg.enabled:
        return grads, ef, {}

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residuals)
    out_g, out_r, crs = [], [], []
    for g, r in zip(flat_g, flat_r):
        gf = g.astype(jnp.float32) + r
        cr = predicted_cr_int8(gf, cfg.qent_bins)
        codes, scales = quantize_int8(gf)
        deq = dequantize_int8(codes, scales, gf.shape)
        use = cr >= cfg.gate_ratio
        sent = jnp.where(use, deq, gf)
        resid = jnp.where(use, gf - deq, jnp.zeros_like(gf))
        out_g.append(sent.astype(g.dtype))
        out_r.append(resid)
        crs.append(cr)
    new_ef = EFState(jax.tree.unflatten(tdef, out_r))
    diags = jax.tree.unflatten(tdef, crs)
    return jax.tree.unflatten(tdef, out_g), new_ef, diags
