"""pjit train step: microbatched gradient accumulation + AdamW + optional
error-feedback gradient compression (paper-gated) and explicit compressed
cross-pod sync.

Modes:
  * "pjit"     -- whole-array programming; the SPMD partitioner inserts all
                  gradient reductions (baseline for the dry-run roofline).
  * "podsync"  -- hybrid shard_map: manual over "pod", auto over
                  data/model.  Per-pod gradients are synced explicitly with
                  the int8 all-gather collective (4x cross-pod wire bytes
                  reduction; see repro.dist.collectives).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train import optimizer as OPT
from repro.train import grad_compress as GC
from repro.dist import collectives as COL
from repro.dist import sharding as S


class TrainState(NamedTuple):
    params: Any
    opt: OPT.OptState
    ef: Optional[GC.EFState]


def init_state(cfg: ModelConfig, key, compress: bool = False) -> TrainState:
    params = M.init_params(cfg, key)
    opt = OPT.init(params)
    ef = GC.init_ef(params) if compress else None
    return TrainState(params, opt, ef)


def stack_for_podsync(state: TrainState, n_pods: int) -> TrainState:
    """One-time conversion to the podsync layout: every param/opt/ef leaf
    gains a leading (n_pods,) axis sharded P("pod") -- per-device memory is
    identical to plain pod-replication."""
    st = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_pods,) + a.shape), t)
    return TrainState(
        st(state.params),
        OPT.OptState(state.opt.step, st(state.opt.mu), st(state.opt.nu)),
        GC.EFState(st(state.ef.residuals)) if state.ef is not None else None)


def _microbatch(batch: Dict[str, jnp.ndarray], m: int) -> Dict[str, jnp.ndarray]:
    def rs(x):
        b = x.shape[0]
        assert b % m == 0, (b, m)
        return x.reshape(m, b // m, *x.shape[1:])
    out = {}
    for k, v in batch.items():
        if k == "mrope_positions":          # (3, B, S) -> (m, 3, B/m, S)
            out[k] = jnp.moveaxis(rs(jnp.moveaxis(v, 0, 1)), 2, 1)
        else:
            out[k] = rs(v)
    return out


def _grads(cfg: ModelConfig, params, batch, microbatches: int):
    def loss_for(p, mb):
        return M.loss_fn(p, mb, cfg)

    if microbatches <= 1:
        return jax.value_and_grad(loss_for)(params, batch)

    mbs = _microbatch(batch, microbatches)

    def body(carry, mb):
        loss_acc, g_acc = carry
        l, g = jax.value_and_grad(loss_for)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (loss_acc + l, g_acc), ()

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    init = S.pvary_manual((jnp.float32(0.0), zeros))
    (loss, gsum), _ = jax.lax.scan(body, init, mbs)
    inv = 1.0 / microbatches
    return loss * inv, jax.tree.map(lambda g: g * inv, gsum)


def make_train_step(
    cfg: ModelConfig,
    ocfg: OPT.AdamWConfig = OPT.AdamWConfig(),
    microbatches: int = 1,
    compress: Optional[GC.CompressConfig] = None,
    mode: str = "pjit",
    mesh=None,
    param_specs=None,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def step_core(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        loss, grads = _grads(cfg, state.params, batch, microbatches)
        metrics = {"loss": loss}
        ef = state.ef
        if compress is not None and compress.enabled and ef is not None:
            grads, ef, crs = GC.compress_tree(grads, ef, compress)
            metrics["mean_pred_cr"] = jnp.mean(
                jnp.stack(jax.tree.leaves(crs)))
        params, opt, gnorm = OPT.apply(ocfg, state.params, grads, state.opt)
        metrics["grad_norm"] = gnorm
        return TrainState(params, opt, ef), metrics

    if mode == "pjit":
        return step_core

    # --- podsync: manual over "pod", auto over the rest -------------------
    # The whole train state is kept *pod-stacked*: every leaf has a leading
    # (n_pods,) axis with P("pod") sharding.  Per-device memory equals plain
    # replication, every pod computes identical updates from the synced
    # gradients, and the vma type system never needs an invariance proof.
    # Pod-local error-feedback residuals fit naturally (their stacks really
    # do differ across pods).
    assert mesh is not None and "pod" in mesh.axis_names
    n_pods = mesh.shape["pod"]
    use_ef = compress is not None and compress.enabled

    def _constrain_like_params(tree):
        """Pin a param-shaped tree to the params' in-pod (auto-axis)
        sharding; without this the EF-residual add loses the sharding and
        the cross-pod int8 all-gather ships whole tensors."""
        if param_specs is None:
            return tree
        am = S.abstract_mesh_or(mesh)
        return jax.tree.map(
            lambda g, ns: jax.lax.with_sharding_constraint(
                g, jax.sharding.NamedSharding(am, ns.spec)),
            tree, param_specs)

    def per_pod(params_s, mu_s, nu_s, step_ctr, ef_s, batch):
        take = lambda t: jax.tree.map(lambda a: a[0], t)
        params, mu, nu = take(params_s), take(mu_s), take(nu_s)
        loss, grads = _grads(cfg, params, batch, microbatches)
        grads = _constrain_like_params(grads)
        loss = jax.lax.pmean(loss, "pod")
        metrics = {"loss": loss}
        new_ef_s = ef_s
        if compress is not None and compress.enabled:
            if ef_s is not None:
                # error feedback: residual added pre-quantization; the
                # sharded int8 collective is the only cross-pod transfer
                flat_g, tdef = jax.tree.flatten(grads)
                ef_local = _constrain_like_params(
                    jax.tree.unflatten(tdef,
                                       [a[0] for a in jax.tree.leaves(ef_s)]))
                flat_r = jax.tree.leaves(ef_local)
                out_g, out_r = [], []
                for g, r in zip(flat_g, flat_r):
                    gf = g.astype(jnp.float32) + r
                    synced = COL.compressed_pod_allreduce(gf)
                    # residual vs own dequantized contribution
                    xf = gf
                    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
                    scale = jnp.maximum(amax, 1e-12) / 127.0
                    deq = (jnp.clip(jnp.round(xf / scale), -127, 127)
                           .astype(jnp.int8).astype(jnp.float32) * scale)
                    out_g.append(synced.astype(g.dtype))
                    out_r.append((gf - deq)[None])
                grads = jax.tree.unflatten(tdef, out_g)
                new_ef_s = jax.tree.unflatten(tdef, out_r)
            else:
                grads = jax.tree.map(COL.compressed_pod_allreduce, grads)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, "pod"), grads)
        opt = OPT.OptState(step_ctr, mu, nu)
        params, opt, gnorm = OPT.apply(ocfg, params, grads, opt)
        metrics["grad_norm"] = jax.lax.pmean(gnorm, "pod")
        put = lambda t: jax.tree.map(lambda a: a[None], t)
        return (put(params), put(opt.mu), put(opt.nu), opt.step,
                new_ef_s, metrics)

    def step(state: TrainState, batch):
        # state must be pod-stacked up front: see stack_for_podsync()
        params_s = state.params
        mu_s, nu_s = state.opt.mu, state.opt.nu
        ef_s = state.ef.residuals if (use_ef and state.ef is not None) \
            else None
        pod = lambda t: jax.tree.map(lambda _: P("pod"), t)
        ef_spec = pod(ef_s) if ef_s is not None else None
        out = S.shard_map(
            per_pod, mesh=mesh,
            in_specs=(pod(params_s), pod(mu_s), pod(nu_s), P(),
                      ef_spec, P("pod")),
            out_specs=(pod(params_s), pod(mu_s), pod(nu_s), P(),
                       ef_spec, P()),
            axis_names=frozenset({"pod"}),
        )(params_s, mu_s, nu_s, state.opt.step, ef_s, batch)
        params_s, mu_s, nu_s, step_ctr, ef_s, metrics = out
        new_state = TrainState(
            params_s,
            OPT.OptState(step_ctr, mu_s, nu_s),
            GC.EFState(ef_s) if ef_s is not None else None)
        return new_state, metrics

    return step
