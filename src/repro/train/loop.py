"""Training loop with fault tolerance, straggler mitigation and elasticity.

Production behaviours exercised here (and in tests) at CPU scale:
  * checkpoint/restart -- async sharded checkpoints every K steps; on
    (re)start the loop resumes from the newest COMMITTED step and
    deterministically fast-forwards the data stream;
  * simulated failures -- ``failure_prob`` raises mid-run like a preempted
    worker; the driver restarts the loop which recovers from the last
    checkpoint (tests assert loss continuity);
  * straggler mitigation -- per-step wall-time EMA; steps slower than
    ``straggler_factor`` x EMA are counted and surfaced so an orchestrator
    can re-slot the worker; the loop also supports skipping the laggard's
    microbatch via a smaller accumulation count for that step;
  * elastic re-mesh -- ``repro.dist.fault.remesh_state`` re-shards a state
    pytree onto a new mesh (grow/shrink the data axis between runs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.ckpt import checkpoint as CKPT
from repro.train import optimizer as OPT
from repro.train import train_step as TS


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    failure_prob: float = 0.0            # simulated preemption probability
    failure_seed: int = 0
    straggler_factor: float = 3.0
    lossy: CKPT.LossyPolicy = dataclasses.field(default_factory=CKPT.LossyPolicy)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopResult:
    losses: Dict[int, float]
    final_step: int
    straggler_steps: int
    restarts: int


def run(
    cfg: ModelConfig,
    state: TS.TrainState,
    step_fn: Callable,
    data_iter: Callable[[int], Dict[str, jnp.ndarray]],
    loop: LoopConfig,
    losses_out: Optional[Dict[int, float]] = None,
) -> tuple[TS.TrainState, LoopResult]:
    """Run from the latest checkpoint (if any) to ``total_steps``.

    ``losses_out``: optional shared dict that survives SimulatedFailure
    (the recovery driver passes one to keep the full loss history)."""
    ckpt = CKPT.AsyncCheckpointer(loop.ckpt_dir, loop.lossy)
    start = CKPT.latest_step(loop.ckpt_dir)
    restarts = 0
    if start is not None:
        # one atomic tree per step: params + optimizer moments together
        tree = {"params": state.params, "mu": state.opt.mu,
                "nu": state.opt.nu}
        loaded = CKPT.load(loop.ckpt_dir, start, tree)
        state = TS.TrainState(
            params=loaded["params"],
            opt=OPT.OptState(step=jnp.asarray(start, jnp.int32),
                             mu=loaded["mu"], nu=loaded["nu"]),
            ef=state.ef,
        )
        restarts = 1
    begin = (start or 0)

    rng = np.random.default_rng(loop.failure_seed)
    losses: Dict[int, float] = losses_out if losses_out is not None else {}
    ema = None
    stragglers = 0
    try:
        for step in range(begin, loop.total_steps):
            if loop.failure_prob and rng.random() < loop.failure_prob \
                    and step > begin + 2:
                raise SimulatedFailure(f"worker preempted at step {step}")
            t0 = time.perf_counter()
            batch = data_iter(step)      # deterministic per-step stream
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > loop.straggler_factor * ema and step > begin + 3:
                stragglers += 1
            losses[step] = loss
            if (step + 1) % loop.ckpt_every == 0 or step + 1 == loop.total_steps:
                ckpt.submit(step + 1, {"params": state.params,
                                       "mu": state.opt.mu,
                                       "nu": state.opt.nu})
    finally:
        ckpt.wait()
        ckpt.close()
    return state, LoopResult(losses, loop.total_steps, stragglers, restarts)


def run_with_recovery(cfg, make_state, step_fn, data_iter, loop: LoopConfig,
                      max_restarts: int = 5):
    """Driver: restart on simulated failures, resuming from checkpoints."""
    all_losses: Dict[int, float] = {}
    restarts = 0
    for attempt in range(max_restarts + 1):
        state = make_state()
        try:
            state, res = run(cfg, state, step_fn, data_iter, loop,
                             losses_out=all_losses)
            return state, LoopResult(all_losses, res.final_step,
                                     res.straggler_steps, restarts)
        except SimulatedFailure:
            restarts += 1
            continue
    raise RuntimeError("exceeded max_restarts")
