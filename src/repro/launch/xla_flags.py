"""Curated XLA/runtime flag presets + XLA_FLAGS merge semantics.

XLA reads ``XLA_FLAGS`` exactly once, when jax initializes its backends,
so every launcher in this repo used to carry its own ad-hoc docstring
string (``XLA_FLAGS=--xla_force_host_platform_device_count=8 python
...``) and ``launch/dryrun.py`` rebuilt the variable by string
concatenation -- silently clobbering whatever the user had exported.
This module is the one place those strings live now:

* :data:`PRESETS` -- small, curated per-backend flag dicts (the
  ``--xla-preset`` CLI flag on ``sweep_serve``/``serve`` names one);
* :func:`merge` / :func:`merge_flag_strings` -- duplicate-deduped merge
  where LATER sources win, so callers always put the user's exported
  ``XLA_FLAGS`` last and the user wins;
* :func:`apply_preset` -- writes the merged result back to the
  environment, guarded so it can only happen BEFORE jax is imported
  (after backend init the variable is dead weight and silently applying
  nothing is exactly the bug this module exists to prevent).

This module must stay importable without jax: the whole point is to run
before ``import jax``.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Mapping, Optional

# Flag value ``None`` means a bare flag (no ``=value`` part).
FlagDict = Dict[str, Optional[str]]

# Curated per-backend presets.  Deliberately conservative: nothing here
# may change numerics (the repo's gates assert bit-equality across
# launch configurations), only scheduling/runtime behavior.
PRESETS: Dict[str, FlagDict] = {
    # CPU hosts (CI, dev boxes): thread the Eigen matmuls the interpret
    # -mode kernels and the jnp sweep body lower to.
    "cpu": {
        "--xla_cpu_multi_thread_eigen": "true",
    },
    # TPU pods: overlap collectives with compute and mark steps at the
    # outer loop (the run.sh exemplar's step-marker choice: 0 = entry,
    # 1 = outer while).
    "tpu": {
        "--xla_tpu_data_parallel_opt_different_sized_ops": "true",
        "--xla_tpu_enable_data_parallel_all_reduce_opt": "true",
        "--xla_step_marker_location": "1",
    },
    # GPU: hide collective latency behind compute.
    "gpu": {
        "--xla_gpu_enable_latency_hiding_scheduler": "true",
    },
    # Explicit no-op preset so scripts can pass a preset unconditionally.
    "none": {},
}


def parse_flags(s: str) -> FlagDict:
    """``"--a=1 --b"`` -> ``{"--a": "1", "--b": None}`` (order kept)."""
    out: FlagDict = {}
    for tok in (s or "").split():
        if "=" in tok:
            k, v = tok.split("=", 1)
            out[k] = v
        else:
            out[tok] = None
    return out


def format_flags(flags: Mapping[str, Optional[str]]) -> str:
    """Inverse of :func:`parse_flags`."""
    return " ".join(k if v is None else f"{k}={v}"
                    for k, v in flags.items())


def merge(*sources: Mapping[str, Optional[str]]) -> FlagDict:
    """Merge flag dicts; duplicates deduped, LATER sources win.

    A flag overridden by a later source also takes that source's
    position, so the winning source's relative flag ordering survives
    verbatim (XLA itself resolves duplicates last-wins; after this
    merge there are no duplicates left to resolve).
    """
    out: FlagDict = {}
    for src in sources:
        for k, v in src.items():
            out.pop(k, None)        # re-insert at the winner's position
            out[k] = v
    return out


def merge_flag_strings(*strs: str) -> str:
    """String-level :func:`merge`: later strings win, duplicates deduped."""
    return format_flags(merge(*(parse_flags(s) for s in strs)))


def jax_imported() -> bool:
    """True once jax is in ``sys.modules`` -- past that point XLA_FLAGS
    edits no longer reach the backend."""
    return "jax" in sys.modules


def apply_preset(name: Optional[str], *, extra: Optional[FlagDict] = None,
                 env: Optional[Dict[str, str]] = None,
                 force: bool = False) -> str:
    """Merge ``PRESETS[name]`` (then ``extra``, then the user's existing
    ``XLA_FLAGS`` -- the user wins) into ``env['XLA_FLAGS']``.

    Must run before jax is imported: raises ``RuntimeError`` otherwise
    (``force=True`` skips the guard for tests that only inspect the
    produced string).  ``name=None`` applies only ``extra``.  Returns
    the final flag string.
    """
    if name is not None and name not in PRESETS:
        raise ValueError(
            f"unknown XLA preset {name!r}; available: {sorted(PRESETS)}")
    env = os.environ if env is None else env
    if not force and env is os.environ and jax_imported():
        raise RuntimeError(
            "apply_preset() after jax was imported: XLA reads XLA_FLAGS "
            "at backend init, so the preset would silently do nothing. "
            "Apply it before the first jax import (the sweep_serve/serve "
            "CLIs do this for --xla-preset).")
    merged = merge_flag_strings(
        format_flags(PRESETS.get(name or "none", {})),
        format_flags(extra or {}),
        env.get("XLA_FLAGS", ""))
    if merged:
        env["XLA_FLAGS"] = merged
    return merged
