"""Serving launcher: batched generate with optional KV compression.

    python -m repro.launch.serve --arch granite-3-2b --smoke \\
        --batch 4 --prompt-len 32 --steps 16 --kv-compress

With ``--kv-gate-service`` the engine's KV-cache gate CRs are served by
the shared :class:`repro.serve.sweep_service.SweepService` through its
registered ``kv_gate`` method instead of the engine's private jit --
concurrent engines coalesce their gate scoring into batched launches and
repeated KV blocks ride the cross-request cache.
"""
import argparse
import time

from repro.launch import xla_flags as XF


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--kv-compress", action="store_true")
    ap.add_argument("--kv-gate-service", action="store_true",
                    help="serve KV-gate CR predictions through the "
                         "shared sweep service (kv_gate method) instead "
                         "of the engine's private jit")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--xla-preset", default=None,
                    choices=sorted(XF.PRESETS),
                    help="apply a curated per-backend XLA_FLAGS preset "
                         "(launch.xla_flags) before jax initializes; "
                         "user-exported XLA_FLAGS still win on conflicts")
    args = ap.parse_args()

    if args.xla_preset:
        XF.apply_preset(args.xla_preset)

    # deferred so --xla-preset lands before the first jax import reads
    # XLA_FLAGS
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_arch, get_smoke
    from repro.dist import sharding as S
    from repro.serve.engine import Engine, ServeConfig
    from repro.train import train_step as TS

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    params = TS.init_state(cfg, jax.random.PRNGKey(0)).params
    scfg = ServeConfig(max_len=args.max_len, kv_compress=args.kv_compress)

    svc = None
    if args.kv_gate_service:
        # construct OUTSIDE the (data, model) serving mesh context: the
        # gate's int8-CR launcher is a plain vmapped jit, and the service
        # must not capture the token engine's mesh for its own launches
        from repro.serve.sweep_service import ServiceConfig, SweepService
        svc = SweepService(ServiceConfig(max_wait_ms=1.0), mesh=None)

    def run():
        eng = Engine(cfg, params, scfg, sweep_service=svc)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len),
            0, cfg.vocab_size, dtype=jnp.int32)}
        t0 = time.time()
        out = eng.generate(batch, steps=args.steps)
        dt = time.time() - t0
        toks = args.batch * args.steps
        print(f"{cfg.name}: generated {out.shape} in {dt:.1f}s "
              f"({toks / dt:.1f} tok/s)")
        if args.kv_compress:
            print(f"KV gate: {eng.kv_saved_bytes:,}/{eng.kv_total_bytes:,} "
                  f"bytes saved")

    try:
        if args.mesh:
            shape = tuple(int(x) for x in args.mesh.split("x"))
            axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
            with S.use_mesh(jax.make_mesh(shape, axes)):
                run()
        else:
            run()
    finally:
        if svc is not None:
            gate = svc.stats()["methods"].get("kv_gate")
            if gate is not None:
                print(f"kv_gate service: {gate['completed']} requests, "
                      f"{gate['rows']} leaves, p50={gate['p50_ms']:.1f}ms "
                      f"p95={gate['p95_ms']:.1f}ms")
            svc.close()


if __name__ == "__main__":
    main()
