import os

from repro.launch import xla_flags as XF

# Dedup-merged (NOT concatenated: the old string concat silently clobbered
# the ordering of user-exported flags).  Later sources win, so a user's
# exported XLA_FLAGS overrides both the 512-device default and any
# _REPRO_EXTRA_XLA additions.  Must run before the jax import below.
os.environ["XLA_FLAGS"] = XF.merge_flag_strings(
    "--xla_force_host_platform_device_count=512",
    os.environ.get("_REPRO_EXTRA_XLA", ""),
    os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the full production step function (train_step /
prefill / decode_step) against ShapeDtypeStruct inputs on the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh, compiles it, and records:

  * memory_analysis()      -- proves the cell fits per-device HBM,
  * cost_analysis()        -- HLO FLOPs / bytes for the roofline,
  * collective statistics  -- parsed from the per-partition HLO text
                              (all-gather / all-reduce / reduce-scatter /
                              all-to-all / collective-permute bytes),

written to benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                get_arch)
from repro.launch.mesh import make_production_mesh, HW
from repro.dist import sharding as S
from repro.models import model as M
from repro.models import params as PRM
from repro.train import optimizer as OPT
from repro.train import train_step as TS

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# Gradient-accumulation depth per (arch, train shape): keeps per-device
# live activations inside v5e HBM (16 GB).
MICROBATCHES = {
    "deepseek-v2-236b": 16, "qwen2-vl-72b": 16, "phi3.5-moe-42b-a6.6b": 8,
    "codeqwen1.5-7b": 8, "granite-8b": 8,
    "stablelm-3b": 4, "granite-3-2b": 4, "whisper-large-v3": 4,
    "mamba2-370m": 4, "hymba-1.5b": 4,
}

# long_500k runs only for sub-quadratic archs (DESIGN.md section 6).
def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("skipped: full-attention arch cannot serve a 524k dense KV "
                "cache (sub-quadratic archs only; see DESIGN.md)")
    return None


COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\((?:[^()]|\([^()]*\))*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum per-device message bytes per collective kind from SPMD HLO."""
    stats = {k: {"count": 0, "bytes": 0} for k in
             ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")}
    tops = []
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        shapes = SHAPE_RE.findall(m.group(2))
        nbytes = 0
        for dt, dims in shapes:
            numel = 1
            for d in dims.split(","):
                if d:
                    numel *= int(d)
            nbytes += numel * DTYPE_BYTES[dt]
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += nbytes
        tops.append((nbytes, kind, line.strip()[:220]))
    tops.sort(reverse=True)
    total_wire = 0
    for kind, st in stats.items():
        factor = 2.0 if kind == "all-reduce" else 1.0
        st["wire_bytes"] = int(st["bytes"] * factor)
        total_wire += st["wire_bytes"]
    stats["total_wire_bytes"] = total_wire
    stats["top"] = [{"bytes": b, "kind": k, "hlo": l} for b, k, l in tops[:12]]
    return stats


def batch_shardings(cfg: ModelConfig, specs: Dict[str, Any], mesh):
    out = {}
    for k, v in specs.items():
        if k == "mrope_positions":
            out[k] = S.named_sharding(v.shape, (None, "batch", None), mesh)
        elif k == "frames":
            out[k] = S.named_sharding(v.shape, ("batch", None, None), mesh)
        elif k in ("tokens", "labels", "token"):
            out[k] = S.named_sharding(v.shape, ("batch", None), mesh)
        elif k == "pos":
            out[k] = NamedSharding(mesh, P())
        elif k == "cache":
            axes = M.cache_logical_axes(cfg, v)
            out[k] = jax.tree.map(
                lambda leaf, a: S.named_sharding(leaf.shape, a, mesh),
                v, axes)
        else:
            out[k] = NamedSharding(mesh, P())
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mode: str = "pjit", extra_tag: str = "",
               unroll: bool = False, microbatch_override=None) -> Dict[str, Any]:
    if unroll:
        os.environ["REPRO_UNROLL_SCAN"] = "1"
    else:
        os.environ.pop("REPRO_UNROLL_SCAN", None)
    if mode.startswith("podsync"):
        os.environ["REPRO_EMBED_REPLICATED"] = "1"
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    skip = cell_supported(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": skip}

    t0 = time.time()
    with S.use_mesh(mesh):
        specs = M.input_specs(cfg, shape)
        param_tree = M.abstract_params(cfg)
        param_shard = PRM.param_specs(M.param_table(cfg), mesh)
        in_b = batch_shardings(cfg, specs, mesh)

        if shape.kind == "train":
            mb = (microbatch_override if microbatch_override is not None
                  else (1 if unroll else MICROBATCHES.get(arch, 8)))
            opt_abs = jax.eval_shape(OPT.init, param_tree)
            opt_shard = OPT.OptState(
                step=NamedSharding(mesh, P()),
                mu=jax.tree.map(lambda s: s, param_shard),
                nu=jax.tree.map(lambda s: s, param_shard))
            state_abs = TS.TrainState(param_tree, opt_abs, None)
            state_shard = TS.TrainState(param_shard, opt_shard, None)
            if mode.startswith("podsync"):
                # pod-stacked state layout (see train_step.stack_for_podsync)
                from repro.train.grad_compress import (CompressConfig,
                                                       init_ef)
                n_pods = mesh.shape["pod"]
                compress = (CompressConfig(enabled=True, gate_ratio=0.0)
                            if mode == "podsync_comp" else None)
                if compress is not None:
                    state_abs = TS.TrainState(
                        state_abs.params, state_abs.opt,
                        jax.eval_shape(init_ef, param_tree))
                state_abs = jax.eval_shape(
                    lambda st: TS.stack_for_podsync(st, n_pods), state_abs)
                def stack_spec(ns):
                    return NamedSharding(
                        mesh, P(*(("pod",) + tuple(ns.spec))))
                ef_shard = (jax.tree.map(stack_spec, TS.GC.EFState(
                    jax.tree.map(lambda s: s, param_shard)))
                    if compress is not None else None)
                state_shard = TS.TrainState(
                    jax.tree.map(stack_spec, param_shard),
                    OPT.OptState(
                        step=NamedSharding(mesh, P()),
                        mu=jax.tree.map(stack_spec, param_shard),
                        nu=jax.tree.map(stack_spec, param_shard)),
                    ef_shard)
                step = TS.make_train_step(cfg, microbatches=mb,
                                          mode="podsync", mesh=mesh,
                                          compress=compress,
                                          param_specs=param_shard)
            else:
                step = TS.make_train_step(cfg, microbatches=mb,
                                          mode=mode, mesh=mesh)
            jitted = jax.jit(
                step,
                in_shardings=(state_shard,
                              {k: in_b[k] for k in specs}),
                out_shardings=(state_shard, None),
                donate_argnums=(0,),     # state buffers reused in place
            )
            args = (state_abs, specs)
        elif shape.kind == "prefill":
            def fn(params, batch):
                return M.prefill(params, batch, cfg, shape.seq_len)
            jitted = jax.jit(fn, in_shardings=(param_shard, in_b),
                             out_shardings=None)
            args = (param_tree, specs)
        else:  # decode
            def fn(params, cache, token, pos, *extra):
                mrope = extra[0] if extra else None
                return M.decode_step(params, cache, token, pos, cfg,
                                     mrope_positions=mrope)
            extra_in = ()
            extra_sh = ()
            if cfg.family == "vlm":
                extra_in = (specs["mrope_positions"],)
                extra_sh = (in_b["mrope_positions"],)
            jitted = jax.jit(
                fn,
                in_shardings=(param_shard, in_b["cache"], in_b["token"],
                              NamedSharding(mesh, P())) + extra_sh,
                out_shardings=None,
                donate_argnums=(1,))     # KV cache updated in place
            args = (param_tree, specs["cache"], specs["token"],
                    specs["pos"]) + extra_in

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)

    chips = int(np.prod(list(mesh.shape.values())))
    mem_dict = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            mem_dict[attr] = int(getattr(mem, attr, 0) or 0)
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    bta = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    n_params = M.count_params(cfg)
    n_active = M.active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    # MODEL_FLOPS: 6ND train, 2ND forward-only
    fl_factor = 6.0 if shape.kind == "train" else 2.0
    model_flops = fl_factor * n_active * tokens

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mode": mode + extra_tag,
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_dict,
        "flops_per_device": flops,
        "bytes_per_device": bta,
        "collectives": coll,
        "params": n_params, "active_params": n_active,
        "model_flops_total": model_flops,
        "microbatches": MICROBATCHES.get(arch, 8) if shape.kind == "train" else 1,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="pjit")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--unroll", action="store_true",
                    help="cost-accounting build: unrolled scans, mb=1")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape, args.multi_pod))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        mesh_tag = "multi" if mp else "single"
        tag = f"__{args.mode}" if args.mode != "pjit" else ""
        tag += "__unroll" if args.unroll else ""
        tag += f"__{args.tag}" if args.tag else ""
        out = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_tag}{tag}.json")
        if args.skip_done and os.path.exists(out):
            print(f"[skip] {arch} x {shape} x {mesh_tag}")
            continue
        print(f"[dryrun] {arch} x {shape} x {mesh_tag} ...", flush=True)
        try:
            res = lower_cell(arch, shape, mp, mode=args.mode,
                             extra_tag=f"__{args.tag}" if args.tag else "",
                             unroll=args.unroll)
        except Exception as e:
            traceback.print_exc()
            res = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                   "status": "failed", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"  -> {res['status']} "
              f"(compile {res.get('compile_s', '-')}s, "
              f"flops/dev {res.get('flops_per_device', 0):.3g}, "
              f"wire {res.get('collectives', {}).get('total_wire_bytes', 0):.3g}B)",
              flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
