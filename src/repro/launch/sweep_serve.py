"""Sweep-service launcher: synthetic multi-client UC1/UC2/featurize load.

Drives ``repro.serve.sweep_service.SweepService`` with concurrent client
threads issuing a mixed request stream over a small set of hot fields --
the production traffic shape the coalescing layers target -- and prints
throughput, latency quantiles, and cache/launch statistics.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    python -m repro.launch.sweep_serve --clients 8 --requests 64 --mesh auto

Multi-process fabric (one command per process; process 0 is the leader
that trains the models and runs the clients, the rest serve as
followers)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    python -m repro.launch.sweep_serve --mesh auto \\
        --coordinator 127.0.0.1:7654 --num-processes 2 --process-id 0 &
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    python -m repro.launch.sweep_serve --mesh auto \\
        --coordinator 127.0.0.1:7654 --num-processes 2 --process-id 1

For leader-death tolerance, host the coordination service in its own
process (``--coordinator-only``) and join every worker with
``--external-coordinator``::

    python -m repro.launch.sweep_serve --coordinator 127.0.0.1:7654 \\
        --num-processes 2 --coordinator-only &
    ... --coordinator 127.0.0.1:7654 --num-processes 2 --process-id 0 \\
        --external-coordinator ...

``--launch-timeout-s`` bounds each collective launch on the leader
(size it to cover a first launch's executable compile);
``--queue-rows`` enables bounded-queue admission control (overload
rejects with ``RetryAfter`` instead of queueing without limit);
``--chaos SPEC`` arms ``repro.dist.faultinject`` on this process for
recovery drills (e.g. ``--chaos follower_launch:kill:2``).
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def _exit_barrier():
    """Align process teardown on the multi-process fabric: a final
    collective barrier guarantees every in-flight gloo op has completed
    on all processes before any of them starts closing transports
    (otherwise a fast-exiting peer can reset connections under a slower
    one and abort it at interpreter shutdown)."""
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices("sweep_serve_exit")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=64,
                    help="total requests across all clients")
    ap.add_argument("--fields", default="miranda-vx,scale-u")
    ap.add_argument("--hot-slices", type=int, default=4,
                    help="distinct slices per field the clients hammer")
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--compressor", default="zfp")
    ap.add_argument("--train-slices", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=3.0)
    ap.add_argument("--min-wait-ms", type=float, default=0.0,
                    help="adaptive micro-batch window floor under "
                         "sustained load")
    ap.add_argument("--max-live-batches", type=int, default=2,
                    help="launched-but-not-post-processed batches in "
                         "flight (admission control)")
    ap.add_argument("--no-adaptive-window", action="store_true",
                    help="pin the micro-batch window at --max-wait-ms "
                         "instead of adapting it to load")
    ap.add_argument("--cache-bytes", type=int, default=4 << 20)
    ap.add_argument("--mesh", default=None,
                    help="'auto' = 1-D all-device sweep mesh")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 -> join the "
                         "jax.distributed multi-process fabric")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--external-coordinator", action="store_true",
                    help="the coordination service runs out-of-process "
                         "(see --coordinator-only); workers survive "
                         "leader death")
    ap.add_argument("--coordinator-only", action="store_true",
                    help="host the standalone coordination service at "
                         "--coordinator and exit on SIGINT (not a "
                         "fabric worker)")
    ap.add_argument("--launch-timeout-s", type=float, default=60.0,
                    help="leader's bound per collective launch; must "
                         "cover a first launch's executable compile")
    ap.add_argument("--heartbeat-s", type=float, default=0.5,
                    help="fabric liveness publish interval")
    ap.add_argument("--queue-rows", type=int, default=0,
                    help="bounded-queue admission control: reject with "
                         "RetryAfter beyond this many queued rows "
                         "(0 = unbounded)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="arm repro.dist.faultinject on this process, "
                         "e.g. follower_launch:kill:2")
    from repro.launch import xla_flags as XF
    ap.add_argument("--xla-preset", default=None,
                    choices=sorted(XF.PRESETS),
                    help="apply a curated per-backend XLA_FLAGS preset "
                         "(launch.xla_flags) before jax initializes; "
                         "user-exported XLA_FLAGS still win on conflicts")
    args = ap.parse_args()

    if args.xla_preset:
        # must precede the mesh import below -- that is the first jax
        # import of this process, where XLA_FLAGS is read
        XF.apply_preset(args.xla_preset)

    from repro.launch import mesh as M
    if args.coordinator_only:
        if args.coordinator is None or args.num_processes is None:
            raise SystemExit("--coordinator-only needs --coordinator "
                             "and --num-processes")
        print(f"# serving coordination service at {args.coordinator} "
              f"for {args.num_processes} processes ...", flush=True)
        M.serve_coordinator(args.coordinator, args.num_processes)
        return
    if args.chaos is not None:
        from repro.dist import faultinject
        faultinject.configure(args.chaos)
        print(f"# chaos armed: {args.chaos}")
    if args.coordinator is not None:
        # must run before any other jax use (device counts lock at init)
        pid, nproc = M.dist_init(
            args.coordinator, num_processes=args.num_processes,
            process_id=args.process_id,
            external_coordinator=args.external_coordinator)
        print(f"# joined fabric: process {pid}/{nproc}")

    import jax
    import jax.numpy as jnp
    from repro import compressors as C
    from repro.core import pipeline as PL, usecases as UC
    from repro.data import scientific
    from repro.serve.sweep_service import ServiceConfig, SweepService

    mesh = None
    if args.mesh == "auto" and len(jax.devices()) > 1:
        mesh = M.make_sweep_mesh()
    elif args.mesh and args.mesh != "auto":
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh(shape, ("data",) if len(shape) == 1
                             else ("data", "model"))

    if args.coordinator is not None:
        from repro.dist import sweep as DS
        if not DS.mesh_spans_processes(mesh):
            # fail loudly on every process: a non-spanning mesh would
            # leave followers serving a service that never stops and the
            # leader blocked in the exit barrier
            raise SystemExit(
                "--coordinator needs a process-spanning mesh: pass "
                "--mesh auto (or a shape covering every process's "
                "devices)")

    scfg = ServiceConfig(max_batch_slices=args.max_batch,
                         max_wait_ms=args.max_wait_ms,
                         min_wait_ms=args.min_wait_ms,
                         adapt_window=not args.no_adaptive_window,
                         max_live_batches=args.max_live_batches,
                         cache_bytes=args.cache_bytes,
                         launch_timeout_s=args.launch_timeout_s,
                         heartbeat_s=args.heartbeat_s,
                         max_queue_rows=args.queue_rows)

    if args.coordinator is not None and jax.process_index() != 0:
        # follower: contribute this process's devices until the leader
        # closes the fabric -- no local clients, no model training
        svc = SweepService(scfg, mesh=mesh)
        print(f"# follower {jax.process_index()} serving ...", flush=True)
        try:
            svc.serve()
        except Exception as e:
            # typed fabric fault (leader death, eviction): the fabric is
            # gone, so no exit barrier -- report and leave
            print(f"# follower {jax.process_index()} fabric error: {e}")
            svc.close()
            return
        print(f"# follower {jax.process_index()} done "
              f"({svc.launches} collective launches joined)")
        if svc.stats()["transport"] == "gloo":
            # post-recovery fabrics exchange over KV (gloo is poisoned
            # after a faulted collective), so only an unfaulted run may
            # align teardown with a gloo barrier
            _exit_barrier()
        return

    # construct the service BEFORE model training: the leader's heartbeat
    # starts at construction, so followers joining the fabric can already
    # distinguish "leader busy training" from "leader dead"
    svc = SweepService(scfg, mesh=mesh)

    fields = args.fields.split(",")
    print(f"# training {args.compressor} grid models on {fields} ...")
    hot, grid_models, uc2_models = {}, {}, {}
    for f in fields:
        slices = scientific.field_slices(
            f, count=args.train_slices + args.hot_slices, n=args.n)
        rng = float(jnp.max(slices) - jnp.min(slices))
        ebs = [r * rng for r in (1e-5, 1e-4, 1e-3, 1e-2)]
        train = slices[:args.train_slices]
        grid_models[f] = UC.EbGridModel.train(train, args.compressor, ebs)
        eps = ebs[2]
        models = {}
        for name in (args.compressor, "bitgrooming"):
            comp = C.get(name)
            crs = jnp.asarray([comp.cr(s, eps) for s in train])
            models[name] = PL.CRPredictor.train(train, crs, eps)
        uc2_models[f] = (models, eps)
        hot[f] = slices[args.train_slices:]

    lat, lock = [], threading.Lock()

    def client(svc, cid: int, count: int):
        rnd = np.random.default_rng(cid)
        for i in range(count):
            f = fields[int(rnd.integers(len(fields)))]
            x = hot[f][int(rnd.integers(args.hot_slices))]
            t0 = time.perf_counter()
            if rnd.random() < 0.5:
                svc.find_eb(grid_models[f], x,
                            target_cr=float(rnd.uniform(3.0, 12.0)))
            else:
                models, eps = uc2_models[f]
                svc.best_compressor(models, x, eps)
            with lock:
                lat.append(time.perf_counter() - t0)

    per_client = max(1, args.requests // args.clients)
    with svc:
        svc.warmup([(args.n, args.n)], grid_sizes=(1, 4),
                   row_buckets=(1, args.clients))
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(svc, c, per_client))
                   for c in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = svc.stats()

    done = len(lat)
    lat_ms = np.sort(np.asarray(lat)) * 1e3
    print(f"served {done} requests from {args.clients} clients in "
          f"{wall:.2f}s -> {done / wall:.1f} req/s")
    print(f"latency p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms "
          f"max={lat_ms[-1]:.1f}ms")
    cache = stats["cache"]
    total_probes = cache["hits"] + cache["misses"]
    print(f"launches={stats['launches']} rows={stats['rows_launched']} "
          f"pad_rows={stats['pad_rows']} batches={stats['batches']} "
          f"executables={stats['executables']} "
          f"window_ms={stats['window_ms']:.3f} "
          f"(shrinks={stats['window_shrinks']})")
    for name, m in sorted(stats["methods"].items()):
        print(f"method {name}: {m['completed']} done ({m['failed']} "
              f"failed), {m['rows']} rows, p50={m['p50_ms']:.1f}ms "
              f"p95={m['p95_ms']:.1f}ms")
    print(f"cache: hit_rate={cache['hits'] / max(total_probes, 1):.2%} "
          f"({cache['hits']}/{total_probes}), entries={cache['entries']}, "
          f"bytes={cache['bytes']}", flush=True)
    if stats["recoveries"]:
        print(f"recoveries={stats['recoveries']} epoch={stats['epoch']} "
              f"transport={stats['transport']} procs={stats['procs']} "
              f"rejected={stats['rejected']}")
    if args.coordinator is not None and stats["transport"] == "gloo":
        _exit_barrier()    # see the follower-side note on faulted fabrics


if __name__ == "__main__":
    main()
