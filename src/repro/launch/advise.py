"""Compression advisor CLI: sweep every variable of a dataset, emit a
per-field (compressor, error bound) recommendation report.

The paper's production story (UC1 + UC2 at dataset scale): instead of
trial-and-error compressor runs, stream every variable of a file-backed
dataset through the chunked featurization sweep (``core.stream``), train
one ``EbGridModel`` per candidate compressor on a small leading sample
of each variable (the ONLY compressor executions anywhere in the run),
and report per CR target the compressor reaching it at the smallest
error bound -- the workflow enstools ships as its analyzer's
``compression="lossy,sz,abs,0.001"`` spec strings.

    python -m repro.launch.advise DATASET --targets 4,8,16 \\
        --compressors sz3-interp,zfp --budget-mb 64 --out report.json

``DATASET`` is a ``tools/make_dataset.py`` output (memmap directory or
``.npz``).  Variables larger than device memory stream within
``--budget-mb``; features are bit-equal to an in-memory sweep
(``bench_stream`` gates it).  ``--service`` routes every chunk through
an in-process ``SweepService`` ``advise`` method, so advisor traffic
rides the coalesced launches and the cross-request feature cache;
either way each variable's streaming content digest (``slice_digest``
of the never-materialized variable) lands in the report, keying future
cache hits.

Per-variable recommendation
---------------------------
Per-row predicted CRs (``AdviseMethod.cr_table``) aggregate across the
variable by HARMONIC mean per (compressor, grid eb) -- rows share one
uncompressed size, so the harmonic mean is the variable's total-bytes
CR.  Per target the eb hitting it interpolates log-log along the
(monotonized) CR-vs-eb curve; among compressors reaching the target the
SMALLEST eb (least distortion) wins, and when none reaches it the
closest-achieving compressor at the grid ceiling is reported with
``feasible: false``.

``--psnr-floor DB`` adds the quality axis (UC3): the SAME streamed pass
also emits the fused per-(row, eb) PSNR/NRMSE tensor (``quality=True``
-- one read covers both halves of the ratio-quality frontier), the
variable's worst-row PSNR curve turns the floor into an eb ceiling, and
recommendations only call a setting feasible when it meets the CR
target INSIDE the quality-feasible region.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Optional

import numpy as np

from repro import compressors as C
from repro.core import stream as ST
from repro.core import usecases as UC
from repro.core.predictors import PredictorConfig
from repro.data import source as SRC
from repro.serve.method import AdviseMethod

DEFAULT_GRID_RELS = (1e-4, 1e-3, 1e-2)
DEFAULT_TARGETS = (4.0, 8.0, 16.0)


def harmonic_cr(cr_rows: np.ndarray) -> np.ndarray:
    """(k, n_comp, e) per-row CRs -> (n_comp, e) variable-level CRs.
    Rows have equal uncompressed size, so total_bytes / total_compressed
    is the harmonic mean of the per-row ratios."""
    return cr_rows.shape[0] / np.sum(1.0 / np.maximum(cr_rows, 1e-12),
                                     axis=0)


def eb_for_target(ebs: np.ndarray, crs: np.ndarray,
                  target: float) -> Optional[tuple[float, float]]:
    """Smallest grid-interpolated eb at which the (monotonized) CR curve
    reaches ``target``; None when even the grid ceiling falls short.
    Returns (eb, predicted_cr at that eb)."""
    mono = np.maximum.accumulate(np.maximum(crs, 1e-12))
    if target > mono[-1]:
        return None
    if target <= mono[0]:
        return float(ebs[0]), float(mono[0])
    le = float(np.interp(np.log(target), np.log(mono), np.log(ebs)))
    cr = float(np.exp(np.interp(le, np.log(ebs), np.log(mono))))
    return float(np.exp(le)), cr


def recommend(names, ebs: np.ndarray, var_cr: np.ndarray, targets, *,
              psnr_floor: Optional[float] = None,
              var_psnr: Optional[np.ndarray] = None) -> Dict[str, dict]:
    """Per-target pick from a (n_comp, e) variable CR table: the
    feasible compressor with the smallest eb, else the closest.

    With ``psnr_floor`` + ``var_psnr`` (the variable's worst-row PSNR
    per grid eb, compressor-independent -- it measures the quantization
    proxy), the pick is UC3-shaped: PSNR is monotonized nonincreasing in
    eb, the floor becomes an eb CEILING (the largest log-eb still
    meeting it), and only settings at or below the ceiling count as
    feasible.  Each recommendation then also reports ``predicted_psnr``
    at its eb and ``psnr_ok``.  When the floor is unreachable even at
    the finest grid eb every target is infeasible and reports the
    finest-eb setting (the least-distorted achievable one)."""
    lg = np.log(ebs)
    le_ceil = None
    pm = None
    if psnr_floor is not None and var_psnr is not None:
        pm = np.minimum.accumulate(np.asarray(var_psnr, np.float64))
        if pm[0] < psnr_floor:
            out = {}
            for t in targets:
                ci = int(np.argmax(var_cr[:, 0]))
                out[f"{float(t):g}"] = {
                    "compressor": names[ci], "eb": float(ebs[0]),
                    "predicted_cr": float(var_cr[ci, 0]),
                    "predicted_psnr": float(pm[0]), "psnr_ok": False,
                    "feasible": False}
            return out
        if pm[-1] >= psnr_floor:
            le_ceil = float(lg[-1])
        else:
            # pm is nonincreasing: reversed it is nondecreasing, the
            # shape np.interp wants
            le_ceil = float(np.interp(psnr_floor, pm[::-1], lg[::-1]))

    def psnr_at(le: float) -> Optional[float]:
        return None if pm is None else float(np.interp(le, lg, pm))

    out: Dict[str, dict] = {}
    for t in targets:
        hits = []
        for ci, name in enumerate(names):
            hit = eb_for_target(ebs, var_cr[ci], float(t))
            if hit is None:
                continue
            if le_ceil is not None and np.log(hit[0]) > le_ceil + 1e-12:
                continue                # reaches the CR only past the floor
            hits.append((hit[0], name, hit[1]))
        if hits:
            eb, name, cr = min(hits)
            rec = {"compressor": name, "eb": eb,
                   "predicted_cr": cr, "feasible": True}
        elif le_ceil is None:
            ci = int(np.argmax(var_cr[:, -1]))
            rec = {"compressor": names[ci], "eb": float(ebs[-1]),
                   "predicted_cr": float(var_cr[ci, -1]), "feasible": False}
        else:
            # best achievable CR inside the quality-feasible region:
            # CR is (monotonized) nondecreasing in eb, so it sits at the
            # ceiling itself
            le_cap = min(le_ceil, float(lg[-1]))
            caps = [float(np.exp(np.interp(
                le_cap, lg,
                np.log(np.maximum.accumulate(np.maximum(var_cr[ci], 1e-12))))))
                for ci in range(len(names))]
            ci = int(np.argmax(caps))
            rec = {"compressor": names[ci], "eb": float(np.exp(le_cap)),
                   "predicted_cr": caps[ci], "feasible": False}
        if pm is not None:
            p = psnr_at(float(np.log(rec["eb"])))
            rec["predicted_psnr"] = p
            rec["psnr_ok"] = bool(p >= psnr_floor - 1e-9)
        out[f"{float(t):g}"] = rec
    return out


def advise_variable(source: SRC.DatasetSource, name: str, *,
                    compressors, grid_rels, targets, train_rows: int,
                    cfg: PredictorConfig, stream: ST.StreamConfig,
                    mesh=None, service=None,
                    psnr_floor: Optional[float] = None) -> dict:
    """Train sample models + stream the full variable -> report entry.

    ``psnr_floor``: also stream the fused quality tensor (same pass,
    ``quality=True`` -- on the service path each chunk pairs its advise
    submission with a ``submit_quality`` riding the same batch windows)
    and recommend only quality-feasible settings (see
    :func:`recommend`)."""
    meta = source.meta(name)
    ndim = len(meta.shape) - 1
    sample = source.read_rows(name, 0, min(int(train_rows), meta.rows))
    rng = float(np.max(sample) - np.min(sample))
    if rng <= 0:
        return {"shape": list(meta.shape), "skipped": "constant sample"}
    ebs = np.asarray([r * rng for r in grid_rels], np.float64)

    # the ONLY compressor executions of the whole run: the training
    # sample (the paper's UC1/UC2 speedup structure -- everything else
    # is predictor sweeps + model evaluations)
    models = {comp: UC.EbGridModel.train(sample, comp, ebs, cfg=cfg,
                                         ndim=ndim)
              for comp in compressors}

    digest = SRC.StreamingDigest()
    var_psnr = None
    if service is not None:
        # chunks ride the service's coalesced launches; futures overlap
        # the next chunk's read exactly like the direct driver's
        # in-flight window.  With a quality floor each chunk pairs its
        # advise submission with a quality submission over the same
        # rows/ebs, riding the same batch windows.
        futs, qfuts = [], []
        for _, chunk in source.chunks(name,
                                      budget_bytes=stream.budget_bytes):
            digest.update(chunk)
            futs.append(service.submit_advise(models, chunk))
            if psnr_floor is not None:
                qfuts.append(service.submit_quality(chunk, ebs, cfg))
        cr_rows = np.concatenate([f.result()["cr"] for f in futs], axis=0)
        if qfuts:
            qual = np.concatenate([f.result() for f in qfuts], axis=0)
            var_psnr = qual[:, :, 0].min(axis=0)
    else:
        if psnr_floor is not None:
            feats, qual = ST.stream_features(
                source, name, ebs, cfg, stream=stream, mesh=mesh,
                digest=digest, quality=True)
            # worst row per eb: the variable meets the floor only when
            # every row does
            var_psnr = np.asarray(qual)[:, :, 0].min(axis=0)
        else:
            feats = ST.stream_features(source, name, ebs, cfg,
                                       stream=stream, mesh=mesh,
                                       digest=digest)
        cr_rows = AdviseMethod.cr_table(models, feats)

    var_cr = harmonic_cr(cr_rows)
    names = tuple(models)
    entry = {
        "shape": list(meta.shape), "rows": meta.rows,
        "digest": digest.digest(),
        "eb_grid": [float(e) for e in ebs],
        "value_range": rng,
        "cr_by_compressor": {n: [float(c) for c in var_cr[i]]
                             for i, n in enumerate(names)},
        "targets": recommend(names, ebs, var_cr, targets,
                             psnr_floor=psnr_floor, var_psnr=var_psnr),
    }
    if var_psnr is not None:
        entry["psnr_floor"] = float(psnr_floor)
        entry["psnr_by_eb"] = [float(p) for p in var_psnr]
    return entry


def advise_dataset(source: SRC.DatasetSource, *, compressors=None,
                   grid_rels=DEFAULT_GRID_RELS, targets=DEFAULT_TARGETS,
                   train_rows: int = 6,
                   cfg: PredictorConfig = PredictorConfig(),
                   stream: Optional[ST.StreamConfig] = None,
                   mesh=None, service=None,
                   fields=None,
                   psnr_floor: Optional[float] = None) -> dict:
    """The advisor as a library call (the CLI and ``bench_stream`` both
    route here).  Returns the full report dict."""
    stream = stream if stream is not None else ST.StreamConfig()
    report: dict = {"targets": [float(t) for t in targets],
                    "budget_bytes": stream.budget_bytes, "variables": {}}
    if psnr_floor is not None:
        report["psnr_floor"] = float(psnr_floor)
    for name in (fields if fields else source.variables()):
        meta = source.meta(name)
        comps = compressors if compressors else (
            C.STUDY_2D if len(meta.shape) == 3 else C.STUDY_3D)
        report["variables"][name] = advise_variable(
            source, name, compressors=comps, grid_rels=grid_rels,
            targets=targets, train_rows=train_rows, cfg=cfg,
            stream=stream, mesh=mesh, service=service,
            psnr_floor=psnr_floor)
    return report


def _print_report(report: dict, file=sys.stdout) -> None:
    print(f"# advisor report  (chunk budget "
          f"{report['budget_bytes'] / 2**20:.1f} MiB)", file=file)
    for name, var in report["variables"].items():
        if "skipped" in var:
            print(f"{name}: skipped ({var['skipped']})", file=file)
            continue
        print(f"{name}  shape={tuple(var['shape'])}  "
              f"digest={var['digest'][:12]}", file=file)
        for t, rec in var["targets"].items():
            note = "" if rec["feasible"] else "  (best achievable)"
            q = ""
            if "predicted_psnr" in rec:
                mark = "" if rec["psnr_ok"] else " <floor"
                q = f"  psnr={rec['predicted_psnr']:.1f}dB{mark}"
            print(f"  CR>={t:>4}: {rec['compressor']:<16} "
                  f"eb={rec['eb']:.3e}  predicted_cr={rec['predicted_cr']:.2f}"
                  f"{q}{note}", file=file)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.advise",
        description="Per-field compression recommendations for a "
                    "file-backed dataset via streamed predictor sweeps.")
    ap.add_argument("dataset", help="memmap dataset dir or .npz archive "
                                    "(tools/make_dataset.py output)")
    ap.add_argument("--fields", default="",
                    help="comma-separated variable subset (default: all)")
    ap.add_argument("--compressors", default="",
                    help="comma-separated candidate set (default: the "
                         "full STUDY_2D/STUDY_3D set per variable rank)")
    ap.add_argument("--targets", default=",".join(
        f"{t:g}" for t in DEFAULT_TARGETS),
        help="comma-separated CR targets")
    ap.add_argument("--grid-rels", default=",".join(
        f"{r:g}" for r in DEFAULT_GRID_RELS),
        help="eb grid as fractions of each variable's value range")
    ap.add_argument("--train-rows", type=int, default=6,
                    help="leading rows per variable the models train on "
                         "(the only compressor executions)")
    ap.add_argument("--psnr-floor", type=float, default=None,
                    help="minimum acceptable PSNR (dB) of the "
                         "quantization proxy; recommendations then pick "
                         "the cheapest quality-feasible setting (UC3)")
    ap.add_argument("--budget-mb", type=float, default=64.0,
                    help="per-chunk f32 byte budget (device memory cap)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="chunks the reader stages ahead (0 = synchronous)")
    ap.add_argument("--mesh", default="auto",
                    help="'auto' (all devices when >1), 'none', or a "
                         "device count")
    ap.add_argument("--service", action="store_true",
                    help="route chunks through an in-process SweepService "
                         "advise method (coalesced launches + feature "
                         "cache)")
    ap.add_argument("--out", default="", help="write the JSON report here")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh != "none":
        import jax
        from repro.launch import mesh as M
        n = len(jax.devices()) if args.mesh == "auto" else int(args.mesh)
        if n > 1:
            mesh = M.make_sweep_mesh(n)

    source = SRC.open_dataset(args.dataset)
    stream = ST.StreamConfig(budget_bytes=int(args.budget_mb * 2**20),
                             prefetch=args.prefetch)
    fields = [f for f in args.fields.split(",") if f]
    comps = [c for c in args.compressors.split(",") if c]
    targets = [float(t) for t in args.targets.split(",") if t]
    grid_rels = sorted(float(r) for r in args.grid_rels.split(",") if r)

    svc = None
    if args.service:
        from repro.serve.sweep_service import ServiceConfig, SweepService
        svc = SweepService(ServiceConfig(), mesh=mesh)
    try:
        report = advise_dataset(
            source, compressors=comps or None, grid_rels=grid_rels,
            targets=targets, train_rows=args.train_rows, stream=stream,
            mesh=mesh, service=svc, fields=fields or None,
            psnr_floor=args.psnr_floor)
    finally:
        if svc is not None:
            svc.close()
    _print_report(report)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    return report


if __name__ == "__main__":
    main()
