"""Training launcher: --arch <id> on the production mesh (or CPU smoke).

    python -m repro.launch.train --arch granite-8b --smoke --steps 20
    python -m repro.launch.train --arch granite-8b --mesh 16x16 \\
        --batch 256 --seq 4096 --microbatches 8 --compress

On real hardware the mesh axes map onto the pod slice; on this container
use --smoke (reduced config, single device) or the dry-run entry point.
"""
import argparse

import jax

from repro.configs.base import get_arch, get_smoke
from repro.ckpt.checkpoint import LossyPolicy
from repro.data.tokens import make_data_iter
from repro.dist import sharding as S
from repro.train import loop as LOOP
from repro.train import optimizer as OPT
from repro.train import train_step as TS
from repro.train.grad_compress import CompressConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 (data x model)")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--lossy-ckpt", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    compress = CompressConfig(enabled=True) if args.compress else None

    def run():
        state = TS.init_state(cfg, jax.random.PRNGKey(0),
                              compress=compress is not None)
        step = jax.jit(TS.make_train_step(
            cfg, OPT.AdamWConfig(lr=args.lr), microbatches=args.microbatches,
            compress=compress))
        data = make_data_iter(cfg, args.batch, args.seq)
        lc = LOOP.LoopConfig(
            total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
            ckpt_dir=args.ckpt_dir,
            lossy=LossyPolicy(enabled=args.lossy_ckpt))
        state, res = LOOP.run(cfg, state, step, data, lc)
        ks = sorted(res.losses)
        print(f"{cfg.name}: steps {ks[0]}..{ks[-1]} "
              f"loss {res.losses[ks[0]]:.3f} -> {res.losses[ks[-1]]:.3f}")

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
        mesh = jax.make_mesh(shape, axes)
        with S.use_mesh(mesh):
            run()
    else:
        run()


if __name__ == "__main__":
    main()
