"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod
slice); multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import time

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def _probe_coordinator_port(address: str, attempts: int = 10,
                            wait_s: float = 0.3) -> None:
    """Pre-flight the coordinator bind: the embedded coordination
    service CHECK-aborts the whole process (uncatchable) when its port
    is taken, so probe with a plain socket first and retry a bounded
    number of times (a just-released port clears TIME_WAIT quickly).
    Raises a *catchable* RuntimeError when the port stays busy, which
    harnesses translate into a relaunch on a fresh port."""
    import socket
    host, _, port = address.rpartition(":")
    last = None
    for _ in range(max(1, attempts)):
        try:
            with socket.socket() as s:
                s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
                s.bind((host or "127.0.0.1", int(port)))
            return
        except OSError as e:
            last = e
            time.sleep(wait_s)
    raise RuntimeError(
        f"coordinator port {address} is already in use "
        f"(after {attempts} probes): {last}")


def dist_init(coordinator_address: str | None = None, *,
              num_processes: int | None = None,
              process_id: int | None = None,
              cpu_collectives: str = "gloo",
              external_coordinator: bool = False,
              init_timeout_s: float = 60.0) -> tuple[int, int]:
    """Join the multi-process sweep fabric: ``jax.distributed`` init.

    Call ONCE per process, before any other jax use, on every process
    that will participate in a process-spanning sweep mesh.  Arguments
    left ``None`` fall back to jax's environment autodetection
    (``JAX_COORDINATOR_ADDRESS`` etc., or the cluster plugin on managed
    fleets).  On the CPU backend the collective implementation defaults
    to gloo, which is what the two-process test harness and the
    ``bench_multihost`` gate run on; combine with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (exported
    before jax is imported) for N virtual devices per process.

    Process 0 normally EMBEDS the coordination service; if that process
    dies, every other process's coordination client hard-aborts, so a
    follower can never outlive its leader.  For leader-death tolerance
    pass ``external_coordinator=True`` on every process (including
    process 0) and host the service elsewhere with
    :func:`serve_coordinator` -- the processes then build plain
    coordination clients against it, and losing any *worker* process
    (leader included) leaves the others functional.

    Returns ``(process_index, process_count)``.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation",
                          cpu_collectives)
    except Exception:
        pass                 # older jax: CPU collectives not configurable
    if external_coordinator:
        if (coordinator_address is None or num_processes is None or
                process_id is None):
            raise ValueError(
                "external_coordinator=True needs explicit "
                "coordinator_address, num_processes and process_id")
        from jax._src import distributed as _dist
        from jaxlib import xla_extension as _xe
        client = _xe.get_distributed_runtime_client(
            coordinator_address, process_id,
            init_timeout=max(1, int(init_timeout_s)), use_compression=True)
        client.connect()
        gs = _dist.global_state
        gs.client = client
        gs.process_id = process_id
        gs.num_processes = num_processes
        gs.coordinator_address = coordinator_address
        return jax.process_index(), jax.process_count()
    if coordinator_address is not None and process_id == 0:
        # only the coordinator-hosting process races for the bind
        _probe_coordinator_port(coordinator_address)
    kw = {}
    if coordinator_address is not None:
        kw["coordinator_address"] = coordinator_address
    if num_processes is not None:
        kw["num_processes"] = num_processes
    if process_id is not None:
        kw["process_id"] = process_id
    jax.distributed.initialize(**kw)
    return jax.process_index(), jax.process_count()


def serve_coordinator(address: str, num_processes: int,
                      block: bool = True):
    """Host a standalone ``jax.distributed`` coordination service.

    Run this in its OWN process (it should never be a fabric worker:
    the point is that worker deaths -- the leader's included -- leave
    the coordination service up for the survivors' KV store, barriers
    and fault detection).  Workers join with
    ``dist_init(address, ..., external_coordinator=True)``.

    ``block=True`` serves until the process is killed; ``block=False``
    returns the service handle (caller keeps it alive).
    """
    from jaxlib import xla_extension as _xe
    _probe_coordinator_port(address)
    host, _, port = address.rpartition(":")
    service = _xe.get_distributed_runtime_service(
        f"[::]:{port}", int(num_processes))
    if not block:
        return service
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    return service


def make_sweep_mesh(num_devices: int | None = None):
    """1-D ("data",) mesh for distributed featurization sweeps.

    The sweep engine shards its slice axis over "data" (logical axis
    "slices"; see ``repro.dist.sweep``), so a flat all-device data mesh
    serves one sweep from every device/host.  On a CPU dev box export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax is
    imported to get N virtual devices.

    Process-aware: under an initialized ``jax.distributed`` runtime (see
    :func:`dist_init`) ``jax.devices()`` is the GLOBAL device list
    (``jax.process_count() x local_device_count``), so the default mesh
    spans every process and each process later feeds its own block of
    the slice axis (``repro.dist.sweep`` handles the per-process
    ingestion and gather).  Asking for more devices than the runtime has
    -- in particular asking for a process-spanning mesh when
    ``jax.distributed`` was never initialized -- raises immediately with
    a clear error instead of hanging in a half-joined collective.
    """
    devs = jax.devices()
    n = num_devices if num_devices is not None else len(devs)
    if n < 1:
        raise ValueError(f"make_sweep_mesh needs >= 1 device, got {n}")
    if n > len(devs):
        local = jax.local_device_count()
        hint = ""
        if jax.process_count() == 1 and n > local:
            hint = (" -- a mesh spanning more than this process's "
                    f"{local} local device(s) needs the multi-process "
                    "fabric: call repro.launch.mesh.dist_init(...) on "
                    "every participating process before building the mesh")
        raise ValueError(
            f"make_sweep_mesh({n}) exceeds the {len(devs)} visible "
            f"device(s) of this runtime{hint}")
    return jax.make_mesh((n,), ("data",), devices=devs[:n])


# TPU v5e hardware model used by the roofline analysis (per chip).
# Compute/bandwidth peaks live in the backend-keyed kernels.tune table;
# interconnect and HBM capacity are mesh-level concerns kept here.
from repro.kernels.tune import BACKEND_HW as _BHW  # noqa: E402

HW = {
    "peak_flops_bf16": _BHW["tpu-v5e"]["peak_flops"],   # FLOP/s
    "hbm_bw": _BHW["tpu-v5e"]["mem_bw"],                # bytes/s
    "ici_bw": 50e9,                # bytes/s per link
    "hbm_bytes": 16e9,             # capacity
}
