"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod
slice); multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_sweep_mesh(num_devices: int | None = None):
    """1-D ("data",) mesh for distributed featurization sweeps.

    The sweep engine shards its slice axis over "data" (logical axis
    "slices"; see ``repro.dist.sweep``), so a flat all-device data mesh
    serves one sweep from every device/host.  On a CPU dev box export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax is
    imported to get N virtual devices.
    """
    n = num_devices if num_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), ("data",))


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # bytes/s
    "ici_bw": 50e9,                # bytes/s per link
    "hbm_bytes": 16e9,             # capacity
}
