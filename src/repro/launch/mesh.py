"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod
slice); multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def dist_init(coordinator_address: str | None = None, *,
              num_processes: int | None = None,
              process_id: int | None = None,
              cpu_collectives: str = "gloo") -> tuple[int, int]:
    """Join the multi-process sweep fabric: ``jax.distributed`` init.

    Call ONCE per process, before any other jax use, on every process
    that will participate in a process-spanning sweep mesh.  Arguments
    left ``None`` fall back to jax's environment autodetection
    (``JAX_COORDINATOR_ADDRESS`` etc., or the cluster plugin on managed
    fleets).  On the CPU backend the collective implementation defaults
    to gloo, which is what the two-process test harness and the
    ``bench_multihost`` gate run on; combine with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (exported
    before jax is imported) for N virtual devices per process.

    Returns ``(process_index, process_count)``.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation",
                          cpu_collectives)
    except Exception:
        pass                 # older jax: CPU collectives not configurable
    kw = {}
    if coordinator_address is not None:
        kw["coordinator_address"] = coordinator_address
    if num_processes is not None:
        kw["num_processes"] = num_processes
    if process_id is not None:
        kw["process_id"] = process_id
    jax.distributed.initialize(**kw)
    return jax.process_index(), jax.process_count()


def make_sweep_mesh(num_devices: int | None = None):
    """1-D ("data",) mesh for distributed featurization sweeps.

    The sweep engine shards its slice axis over "data" (logical axis
    "slices"; see ``repro.dist.sweep``), so a flat all-device data mesh
    serves one sweep from every device/host.  On a CPU dev box export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax is
    imported to get N virtual devices.

    Process-aware: under an initialized ``jax.distributed`` runtime (see
    :func:`dist_init`) ``jax.devices()`` is the GLOBAL device list
    (``jax.process_count() x local_device_count``), so the default mesh
    spans every process and each process later feeds its own block of
    the slice axis (``repro.dist.sweep`` handles the per-process
    ingestion and gather).  Asking for more devices than the runtime has
    -- in particular asking for a process-spanning mesh when
    ``jax.distributed`` was never initialized -- raises immediately with
    a clear error instead of hanging in a half-joined collective.
    """
    devs = jax.devices()
    n = num_devices if num_devices is not None else len(devs)
    if n < 1:
        raise ValueError(f"make_sweep_mesh needs >= 1 device, got {n}")
    if n > len(devs):
        local = jax.local_device_count()
        hint = ""
        if jax.process_count() == 1 and n > local:
            hint = (" -- a mesh spanning more than this process's "
                    f"{local} local device(s) needs the multi-process "
                    "fabric: call repro.launch.mesh.dist_init(...) on "
                    "every participating process before building the mesh")
        raise ValueError(
            f"make_sweep_mesh({n}) exceeds the {len(devs)} visible "
            f"device(s) of this runtime{hint}")
    return jax.make_mesh((n,), ("data",), devices=devs[:n])


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # bytes/s
    "ici_bw": 50e9,                # bytes/s per link
    "hbm_bytes": 16e9,             # capacity
}
