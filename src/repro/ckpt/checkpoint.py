"""Sharded checkpointing with optional paper-driven lossy compression.

Layout on disk:
  <dir>/step_<N>/manifest.json        tensor index, shapes, dtypes, codec
  <dir>/step_<N>/<leaf>.npz | .lossy  payload per tensor (per host-shard in
                                      a multi-host deployment; single shard
                                      here)

Lossy path (the paper as a first-class framework feature):
  * UC2: the trained per-compressor CR models rank candidate compressors per
    tensor group from its statistics alone -- no trial compression;
  * UC1-style bound selection: error bound = ``rel_eb`` x tensor value range;
  * predicted vs achieved CR is recorded in the manifest for every tensor.

Restart / elasticity: ``load`` reshapes nothing -- tensors are stored whole,
so restoring onto a *different mesh* works by re-sharding at placement time
(jax.device_put against the new sharding), which is the elastic-scaling
path exercised in tests.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import queue
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class LossyPolicy:
    enabled: bool = False
    rel_eb: float = 1e-4                  # error bound = rel_eb * value range
    compressor: str = "sz3-lorenzo"       # fallback when no predictor given
    predictors: Optional[Dict[str, Any]] = None   # name -> CRPredictor (UC2)
    min_size: int = 65536                 # small tensors stay lossless
    skip_moments: bool = True             # optimizer moments stay lossless


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = leaf
    return out


def _pack2d(arr: np.ndarray) -> np.ndarray:
    """View an arbitrary tensor as a 2-D slice for the compressor suite."""
    n = arr.size
    w = 1
    for cand in (4096, 2048, 1024, 512, 256, 128, 64):
        if n % cand == 0:
            w = cand
            break
    return arr.reshape(-1, w) if w > 1 else arr.reshape(1, -1)


def _compress_tensor(arr: np.ndarray, policy: LossyPolicy) -> Tuple[bytes, Dict]:
    from repro import compressors as C
    from repro.core import pipeline as PL
    data2d = jnp.asarray(_pack2d(arr.astype(np.float32)))
    rng = float(np.max(arr) - np.min(arr)) if arr.size else 0.0
    eps = max(policy.rel_eb * rng, 1e-12)
    name = policy.compressor
    pred_cr = None
    if policy.predictors:
        feats = PL.featurize_slices(data2d[None], eps)
        preds = {n: float(m.predict_from_features(feats)[0])
                 for n, m in policy.predictors.items()}
        name = max(preds, key=preds.get)
        pred_cr = preds[name]
    comp = C.get(name)
    codes, aux = comp.encode(data2d, eps)
    size = comp.size_bytes(codes, aux, eps)
    recon = np.asarray(comp.decode(codes, aux, eps), np.float32)
    payload = pickle.dumps({
        "recon": recon.astype(np.float32),  # stored decompressed-form for
                                            # simplicity; size metered above
        "shape": arr.shape, "dtype": str(arr.dtype),
    }, protocol=4)
    meta = {"codec": name, "eps": eps, "metered_bytes": int(size),
            "raw_bytes": int(arr.size * 4),
            "achieved_cr": float(arr.size * 4 / max(size, 1)),
            "predicted_cr": pred_cr}
    return payload, meta


def save(directory: str, step: int, tree, policy: LossyPolicy = LossyPolicy(),
         extra_meta: Optional[Dict] = None) -> Dict:
    """Blocking sharded save; returns the manifest."""
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    leaves = _leaf_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "tensors": {}, "time": time.time()}
    if extra_meta:
        manifest.update(extra_meta)
    for key, leaf in leaves.items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "__")
        lossy_ok = (policy.enabled and arr.size >= policy.min_size
                    and arr.dtype in (np.float32, np.dtype("bfloat16"))
                    and not (policy.skip_moments and ("mu/" in key or "nu/" in key)))
        if lossy_ok:
            payload, meta = _compress_tensor(arr.astype(np.float32), policy)
            with open(os.path.join(d, fname + ".lossy"), "wb") as f:
                f.write(payload)
            manifest["tensors"][key] = {"file": fname + ".lossy", **meta}
        else:
            np.savez(os.path.join(d, fname + ".npz"),
                     data=arr.astype(np.float32) if arr.dtype == np.dtype("bfloat16") else arr)
            manifest["tensors"][key] = {
                "file": fname + ".npz", "codec": "raw",
                "dtype": str(arr.dtype), "shape": list(arr.shape)}
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, default=str)
    # atomic completion marker (crash-consistent restart)
    with open(os.path.join(d, "COMMITTED"), "w") as f:
        f.write(str(step))
    return manifest


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(directory, name, "COMMITTED")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load(directory: str, step: int, like_tree) -> Any:
    """Restore into the structure of ``like_tree`` (dtypes preserved)."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = _leaf_paths(like_tree)
    out = {}
    for key, leaf in leaves.items():
        info = manifest["tensors"][key]
        path = os.path.join(d, info["file"])
        if info["file"].endswith(".lossy"):
            with open(path, "rb") as f:
                blob = pickle.loads(f.read())
            arr = blob["recon"].reshape(blob["shape"])
        else:
            arr = np.load(path)["data"]
        out[key] = jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape)
    # rebuild the pytree
    flat, tdef = jax.tree_util.tree_flatten_with_path(like_tree)
    rebuilt = []
    for pathspec, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in pathspec)
        rebuilt.append(out[key])
    return jax.tree_util.tree_unflatten(jax.tree.structure(like_tree), rebuilt)


class AsyncCheckpointer:
    """Background-thread writer: train loop hands off host copies and keeps
    stepping while the previous checkpoint serializes."""

    def __init__(self, directory: str, policy: LossyPolicy = LossyPolicy()):
        self.directory = directory
        self.policy = policy
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.last_manifest: Optional[Dict] = None

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            self.last_manifest = save(self.directory, step, host_tree,
                                      self.policy, extra)
            self._q.task_done()

    def submit(self, step: int, tree, extra: Optional[Dict] = None):
        host_tree = jax.tree.map(np.asarray, tree)   # device -> host copy
        self._q.put((step, host_tree, extra))

    def wait(self):
        self._q.join()

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=30)
