"""Logical-axis sharding: a thin helper layer over ``jax.sharding``.

Models annotate arrays with *logical* axis names ("batch", "model",
"fsdp", ...).  A rules table maps logical names to physical mesh axes; the
helpers here resolve those rules against the active mesh, with a
per-dimension divisibility fallback to replication (a 25-head tensor on a
4-way model axis silently replicates instead of erroring).

Also hosts the jax version-compat shims for APIs the call sites use
unconditionally (``shard_map`` with ``axis_names``, ``pvary``,
abstract-mesh lookup).

Distributed featurization sweeps
--------------------------------
The sweep engine (``repro.core.predictors.features_sweep``) shards its
slice axis through the logical axis ``"slices"`` (mapped to the physical
``"data"`` axis by :data:`DEFAULT_RULES`).  Activating any mesh whose
``"data"`` extent exceeds 1 makes every sweep entering through the engine
run as a ``shard_map`` over the slice axis (see ``repro.dist.sweep``)::

    # 8 virtual CPU devices: set BEFORE importing jax
    #   XLA_FLAGS=--xla_force_host_platform_device_count=8
    from repro.dist import sharding as S
    from repro.launch import mesh as M
    with S.use_mesh(M.make_sweep_mesh()):          # 1-D ("data",) mesh
        feats = engine.sweep(slices, ebs)          # sharded over slices

Slice counts that don't divide the mesh are padded and the pad rows are
dropped (gather) or masked (sharded-out); see
``repro.dist.sweep.features_sweep_sharded``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis name -> tuple of physical mesh axes.  A logical axis maps to
# nothing ("layers": the scan dimension) or to one mesh axis; multi-axis
# mappings are supported for meshes that split e.g. data across pods.
DEFAULT_RULES = {
    "batch": ("data",),
    "fsdp": ("data",),
    "model": ("model",),
    "seq_model": ("model",),
    "layers": (),
    # featurization sweeps: the slice axis of a (k, m, n) stack
    "slices": ("data",),
}

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def current_rules() -> dict:
    return getattr(_STATE, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    """Activate ``mesh`` (and optional rule overrides) for the block.

    Enters the jax mesh context too, so ``with_sharding_constraint`` with
    bare PartitionSpecs resolves inside jit.
    """
    prev = (current_mesh(), current_rules())
    _STATE.mesh = mesh
    _STATE.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        with mesh:
            yield mesh
    finally:
        _STATE.mesh, _STATE.rules = prev


def _mesh_extent(mesh: Mesh, axes) -> int:
    """Product of the mesh sizes of ``axes`` (missing axes count as 1)."""
    if isinstance(axes, str):
        axes = (axes,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ext = 1
    for a in axes:
        ext *= sizes.get(a, 1)
    return ext


def _physical_axes(logical: Optional[str], mesh: Mesh) -> Tuple[str, ...]:
    if logical is None:
        return ()
    axes = current_rules().get(logical, ())
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in mesh.axis_names)


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
             mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for ``shape`` under the logical->physical rules.

    Any dimension whose size does not divide the mapped mesh extent falls
    back to replication (None) for that dimension only.
    """
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return P(*([None] * len(shape)))
    parts = []
    for dim, name in zip(shape, logical_axes):
        axes = _physical_axes(name, mesh)
        ext = _mesh_extent(mesh, axes)
        if axes and ext > 1 and dim % ext == 0:
            parts.append(axes[0] if len(axes) == 1 else axes)
        else:
            parts.append(None)
    return P(*parts)


def named_sharding(shape: Sequence[int], logical_axes, mesh: Optional[Mesh] = None
                   ) -> NamedSharding:
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        raise ValueError("named_sharding needs a mesh (arg or use_mesh)")
    return NamedSharding(mesh, spec_for(shape, logical_axes, mesh))


def in_manual_context() -> bool:
    """True while tracing inside a shard_map body (any jax version).

    The old-jax adapter marks its bodies via the ``manual_depth`` flag;
    native ``jax.shard_map`` is detected through the abstract mesh's
    Manual axis types (same probe ``pvary_manual`` uses).
    """
    if getattr(_STATE, "manual_depth", 0) > 0:
        return True
    try:
        am = jax.sharding.get_abstract_mesh()
        return any(str(am._axis_types_dict.get(n, "")) == "Manual"
                   for n in am.axis_names)
    except Exception:
        return False


def shard(x, *logical_axes):
    """Constrain ``x`` to the sharding implied by its logical axes; no-op
    when no mesh is active (single-host tests, CPU smoke runs) or while
    tracing inside a compat full-manual shard_map body (old jax cannot
    express auto-axis constraints there)."""
    mesh = current_mesh()
    if mesh is None or getattr(_STATE, "manual_depth", 0) > 0:
        return x
    spec = spec_for(x.shape, logical_axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# jax version-compat shims
# ---------------------------------------------------------------------------

def pvary_manual(tree):
    """Mark ``tree`` as varying over the currently-manual shard_map axes.

    On jax releases with the vma type system this applies ``jax.lax.pvary``
    so scan carries type-check; older releases have no pvary (and our
    shard_map adapter disables replication checking), so identity is
    exactly equivalent there.
    """
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is None:
        return tree
    try:
        am = jax.sharding.get_abstract_mesh()
        manual = tuple(n for n in am.axis_names
                       if str(am._axis_types_dict.get(n, "")) == "Manual")
    except Exception:
        manual = ()
    if not manual:
        return tree
    return jax.tree.map(lambda a: pvary(a, manual), tree)


# The native jax.shard_map at import time (None on old jax, where the
# polyfill below installs an adapter -- keep the original to avoid
# dispatching the adapter to itself).
_NATIVE_SHARD_MAP = getattr(jax, "shard_map", None)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=frozenset()):
    """Modern ``jax.shard_map`` spelling (manual over ``axis_names``, auto
    over the rest) adapted to ``jax.experimental.shard_map`` on old jax.

    Old-jax note: the partial-auto mode (``auto=``) crashes the 0.4.x SPMD
    partitioner, so the adapter runs the body FULL-manual over every mesh
    axis with replication checking off.  Axes the caller wanted auto are
    simply unsharded inside the body (redundant compute, identical
    values), and ``shard()`` constraints inside the body become no-ops via
    the manual-depth flag.
    """
    if _NATIVE_SHARD_MAP is not None:
        return _NATIVE_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs,
                                 axis_names=frozenset(axis_names))
    from jax.experimental.shard_map import shard_map as _sm

    def body(*args):
        _STATE.manual_depth = getattr(_STATE, "manual_depth", 0) + 1
        try:
            return f(*args)
        finally:
            _STATE.manual_depth -= 1

    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def abstract_mesh_or(mesh: Optional[Mesh] = None):
    """The tracing-time abstract mesh when available, else the concrete
    mesh (old jax builds NamedShardings from concrete meshes only)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        am = get()
        if am is not None and getattr(am, "axis_names", ()):
            return am
    return mesh if mesh is not None else current_mesh()


def _install_compat() -> None:
    if not hasattr(jax, "shard_map"):
        def _jax_shard_map(f, *, mesh, in_specs, out_specs,
                           axis_names=frozenset(), **kw):
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names)
        jax.shard_map = _jax_shard_map


_install_compat()
