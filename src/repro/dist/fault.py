"""Elastic fault tolerance for the multi-process sweep fabric.

Two layers live here:

1. **Re-meshing** -- ``remesh_state`` moves a (possibly sharded) state
   tree onto a new mesh, e.g. after shrinking an axis when a slice of
   devices is lost; ``shrink_mesh`` drops trailing device slices along
   one mesh axis; ``surviving_submesh`` rebuilds a 1-D sweep mesh over
   the devices of the processes that are still alive.

2. **Failure detection plumbing** for the serving fabric
   (``repro.serve.sweep_service``): a typed :class:`FabricError`, a
   :class:`Heartbeat` publisher + :class:`PeerMonitor` staleness
   tracker over the jax coordination-service key-value store, a
   barrier-with-timeout (:func:`fabric_barrier`), and chunked KV
   payload helpers (:func:`kv_put_bytes` / :func:`kv_get_bytes`) the
   post-recovery launch transport uses.

The KV store is served by the ``jax.distributed`` coordinator, so it
keeps working among the *surviving* processes after a peer dies as long
as the coordinator process itself is alive (for leader-death tolerance
run the coordinator out-of-process: ``launch.mesh.serve_coordinator`` +
``dist_init(external_coordinator=True)``).
"""
from __future__ import annotations

import threading
import time
from typing import Iterable, Optional, Sequence

import numpy as np
import jax
from jax.sharding import Mesh

from repro.dist import sharding as S


class FabricError(RuntimeError):
    """A failure of the multi-process fabric itself (vs one request).

    ``kind`` classifies the fault:

    * ``"follower_lost"`` -- one or more followers died or wedged; the
      leader shrinks the mesh and retries (``retriable=True``).
    * ``"leader_lost"``   -- the leader stopped heartbeating; followers
      cannot continue (restart the fabric to recover).
    * ``"evicted"``       -- this (live) process was dropped from the
      recovered fabric; restart it to rejoin.
    * ``"timeout"``       -- a bounded collective/recovery wait expired
      without an identifiable peer fault.
    * ``"failed"``        -- recovery itself is impossible (e.g. the
      coordination service is unreachable).

    ``lost`` names the process indices believed dead/wedged (may be
    empty when the fault could not be attributed).  ``retriable`` tells
    the leader's launch loop whether shrinking the mesh and relaunching
    can succeed; non-retriable errors propagate to every pending future
    with restart guidance in the message.
    """

    def __init__(self, message: str, *, kind: str = "failed",
                 lost: Sequence[int] = (), retriable: bool = False):
        self.kind = kind
        self.lost = tuple(lost)
        self.retriable = retriable
        detail = f" [kind={kind}"
        if self.lost:
            detail += f", lost processes={list(self.lost)}"
        detail += ", retriable]" if retriable else \
            "; restart the affected process(es) to rejoin the fabric]"
        super().__init__(message + detail)


# ---------------------------------------------------------------------------
# Coordination-service key-value helpers
# ---------------------------------------------------------------------------

KV_CHUNK = 1 << 20               # chunk large payloads (1 MiB per KV value)


def kv_client():
    """The jax coordination-service client, or None outside a
    ``jax.distributed`` runtime."""
    try:
        from jax._src import distributed as _dist
        return _dist.global_state.client
    except Exception:
        return None


def kv_set(client, key: str, value: str) -> bool:
    """Best-effort overwrite-set; False when the store is unreachable."""
    try:
        client.key_value_set(key, value, allow_overwrite=True)
        return True
    except Exception:
        return False


def kv_get(client, key: str, timeout_ms: int) -> Optional[str]:
    """Blocking get; None when the key never appears within the timeout
    (the coordination service raises DEADLINE_EXCEEDED on missing keys)."""
    try:
        return client.blocking_key_value_get(key, int(timeout_ms))
    except Exception:
        return None


def kv_dir(client, prefix: str) -> dict:
    """{key: value} under ``prefix`` (empty on any store error)."""
    try:
        return dict(client.key_value_dir_get(prefix))
    except Exception:
        return {}


def kv_delete(client, key: str) -> None:
    try:
        client.key_value_delete(key)
    except Exception:
        pass


def kv_put_bytes(client, key: str, data: bytes) -> None:
    """Store ``data`` chunked under ``key`` (``key/n`` + ``key/<i>``)."""
    n = max(1, -(-len(data) // KV_CHUNK))
    for i in range(n):
        client.key_value_set_bytes(
            f"{key}/{i}", data[i * KV_CHUNK:(i + 1) * KV_CHUNK],
            allow_overwrite=True)
    client.key_value_set(f"{key}/n", str(n), allow_overwrite=True)


def kv_get_bytes(client, key: str, timeout_ms: int) -> Optional[bytes]:
    """Read a :func:`kv_put_bytes` payload; None on timeout."""
    n = kv_get(client, f"{key}/n", timeout_ms)
    if n is None:
        return None
    try:
        parts = [client.blocking_key_value_get_bytes(
            f"{key}/{i}", int(timeout_ms)) for i in range(int(n))]
    except Exception:
        return None
    return b"".join(parts)


# ---------------------------------------------------------------------------
# Heartbeats + peer staleness
# ---------------------------------------------------------------------------

class Heartbeat:
    """Publishes a monotonically increasing counter to
    ``{prefix}/hb/{pid}`` every ``interval_s`` from a daemon thread.

    A peer's counter freezing is the liveness signal
    (:class:`PeerMonitor`): value-change tracking is clock-skew free,
    unlike publishing wall-clock timestamps.
    """

    def __init__(self, client, prefix: str, pid: int,
                 interval_s: float = 0.5):
        self._client = client
        self._key = f"{prefix}/hb/{pid}"
        self._interval = max(0.05, float(interval_s))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "Heartbeat":
        if self._client is None or self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._run, name="fabric-heartbeat", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        beat, misses = 0, 0
        while not self._stop.is_set():
            beat += 1
            if kv_set(self._client, self._key, str(beat)):
                misses = 0
            else:
                # a transient RPC failure must not silence the publisher
                # forever (observers would declare this process dead);
                # only a persistently unreachable store ends the thread
                misses += 1
                if misses >= 10:
                    return
            self._stop.wait(self._interval)

    def stop(self) -> None:
        self._stop.set()


class PeerMonitor:
    """Observer-side staleness tracking over the heartbeat keys.

    ``poll()`` snapshots ``{prefix}/hb/``; a peer is *stale* once its
    counter has been observed unchanged for ``stale_after`` seconds
    (never-published peers age from their first poll).  All ages are
    relative to this monitor's own observations, so detecting a fresh
    death takes one ``stale_after`` observation window.
    """

    def __init__(self, client, prefix: str):
        self._client = client
        self._prefix = f"{prefix}/hb/"
        self._state: dict = {}       # pid -> (last value, t_last_change)

    def poll(self) -> None:
        now = time.monotonic()
        seen = kv_dir(self._client, self._prefix)
        vals = {}
        for key, val in seen.items():
            try:
                vals[int(key.rsplit("/", 1)[-1])] = val
            except ValueError:
                continue
        for pid, val in vals.items():
            prev = self._state.get(pid)
            if prev is None or prev[0] != val:
                self._state[pid] = (val, now)

    def track(self, pids: Iterable[int]) -> None:
        """Start aging ``pids`` even if they never published a beat."""
        now = time.monotonic()
        for pid in pids:
            self._state.setdefault(pid, (None, now))

    def seen(self, pid: int) -> bool:
        """True once ``pid`` has published at least one beat."""
        ent = self._state.get(pid)
        return ent is not None and ent[0] is not None

    def age(self, pid: int) -> float:
        ent = self._state.get(pid)
        if ent is None:
            self.track([pid])
            return 0.0
        return time.monotonic() - ent[1]

    def stale(self, pids: Iterable[int], stale_after: float) -> list:
        return [p for p in pids if self.age(p) > stale_after]

    def observe_stale(self, pids: Sequence[int], stale_after: float,
                      poll_s: float = 0.1) -> list:
        """Watch ``pids`` for one full ``stale_after`` window and return
        the ones whose heartbeat never advanced (the dead/wedged set).
        Blocking for ~``stale_after`` seconds; used right after a
        collective fault to attribute it."""
        self.track(pids)
        self.poll()
        deadline = time.monotonic() + stale_after + poll_s
        while time.monotonic() < deadline:
            time.sleep(poll_s)
            self.poll()
        return self.stale(pids, stale_after * 0.9)


def fabric_barrier(client, name: str, timeout_s: float,
                   procs: Sequence[int]) -> bool:
    """Barrier among ``procs`` only (survivors), bounded by
    ``timeout_s``; False on timeout / store error instead of raising so
    recovery loops can shrink the set and retry."""
    try:
        client.wait_at_barrier(name, int(timeout_s * 1000),
                               process_ids=list(procs))
        return True
    except Exception:
        return False


# ---------------------------------------------------------------------------
# Re-meshing
# ---------------------------------------------------------------------------

def remesh_state(tree, axes, mesh: Mesh):
    """Place every leaf of ``tree`` on ``mesh`` per its logical ``axes``.

    ``axes`` mirrors ``tree``'s structure with a tuple of logical axis
    names where ``tree`` has an array (``params.logical_axes`` output).
    Layout-preserving in value: every leaf is device_put onto the
    sharding its logical axes imply on the target mesh (gathering /
    re-slicing as needed).
    """
    def place(a, ax):
        return jax.device_put(a, S.named_sharding(a.shape, ax, mesh))
    return jax.tree.map(place, tree, axes)


def shrink_mesh(mesh: Mesh, axis: str, new_size: int) -> Mesh:
    """A mesh with ``axis`` reduced to its first ``new_size`` slices."""
    if axis not in mesh.axis_names:
        raise ValueError(
            f"shrink_mesh: mesh has axes {mesh.axis_names}, not {axis!r}")
    i = mesh.axis_names.index(axis)
    if not 1 <= int(new_size) <= mesh.devices.shape[i]:
        raise ValueError(
            f"shrink_mesh: new_size={new_size} outside [1, "
            f"{mesh.devices.shape[i]}] for axis {axis!r}")
    devs = np.take(mesh.devices, np.arange(int(new_size)), axis=i)
    return Mesh(devs, mesh.axis_names)


def surviving_submesh(mesh: Mesh, alive: Iterable[int]) -> Mesh:
    """A 1-D sweep mesh over ``mesh``'s devices owned by the ``alive``
    processes, in original mesh order (keeps per-process device blocks
    contiguous, which ``dist.sweep`` requires)."""
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"surviving_submesh supports 1-D sweep meshes, got axes "
            f"{mesh.axis_names}")
    alive = set(alive)
    devs = [d for d in mesh.devices.flat if d.process_index in alive]
    if not devs:
        raise ValueError(
            f"surviving_submesh: no devices left for processes "
            f"{sorted(alive)}")
    return Mesh(np.asarray(devs, object), mesh.axis_names)
