"""Elastic re-meshing: move a (possibly sharded) state tree onto a new
mesh, e.g. after shrinking an axis when a slice of devices is lost.

``remesh_state`` is layout-preserving in value: every leaf is device_put
onto the sharding its logical axes imply on the target mesh (gathering /
re-slicing as needed).  ``shrink_mesh`` drops trailing device slices along
one mesh axis.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh

from repro.dist import sharding as S


def remesh_state(tree, axes, mesh: Mesh):
    """Place every leaf of ``tree`` on ``mesh`` per its logical ``axes``.

    ``axes`` mirrors ``tree``'s structure with a tuple of logical axis
    names where ``tree`` has an array (``params.logical_axes`` output).
    """
    def place(a, ax):
        return jax.device_put(a, S.named_sharding(a.shape, ax, mesh))
    return jax.tree.map(place, tree, axes)


def shrink_mesh(mesh: Mesh, axis: str, new_size: int) -> Mesh:
    """A mesh with ``axis`` reduced to its first ``new_size`` slices."""
    i = mesh.axis_names.index(axis)
    assert 1 <= new_size <= mesh.devices.shape[i], (axis, new_size)
    devs = np.take(mesh.devices, np.arange(new_size), axis=i)
    return Mesh(devs, mesh.axis_names)
