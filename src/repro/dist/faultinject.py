"""Deterministic, opt-in fault injection for the sweep fabric.

Disabled unless armed -- ``fire()`` is a dict-lookup no-op in
production.  Armed via the ``REPRO_FAULT_INJECT`` environment variable
(read at import, so child processes arm themselves before jax starts)
or :func:`configure` in tests.  The spec is a comma-separated list of

    site:mode:nth[:arg]

* ``site`` -- a named hook point on the broadcast/launch path; the
  serving fabric fires ``leader_launch`` (leader, before each
  collective launch), ``follower_launch`` (follower, after decoding a
  launch header, inside the bounded collective join), ``kv_launch``
  (follower, after reading a post-recovery KV launch descriptor) and
  ``bcast`` (every payload broadcast on either side).
* ``mode`` -- ``kill`` (SIGKILL self: a crash-stop), ``exit``
  (``os._exit(17)``: abrupt but not signal-terminated), ``hang``
  (sleep ``arg`` seconds, default 3600: a wedged peer whose heartbeat
  thread keeps running), ``slow`` (sleep ``arg`` seconds, default 1.0:
  degraded but alive).
* ``nth`` -- fire on exactly the nth call of that site (1-based), so
  chaos tests pick the precise launch to break.
* ``arg`` -- optional float parameter for hang/slow.

Example: kill this process the second time it joins a launch::

    REPRO_FAULT_INJECT=follower_launch:kill:2
"""
from __future__ import annotations

import os
import signal
import time
from typing import Dict, List, Optional, Tuple

MODES = ("kill", "exit", "hang", "slow")

_specs: Dict[str, List[Tuple[str, int, float]]] = {}
_counts: Dict[str, int] = {}


def parse(spec: str) -> Dict[str, List[Tuple[str, int, float]]]:
    """``"site:mode:nth[:arg],..."`` -> {site: [(mode, nth, arg)]}."""
    out: Dict[str, List[Tuple[str, int, float]]] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        bits = part.split(":")
        if len(bits) not in (3, 4):
            raise ValueError(
                f"fault-inject spec {part!r} is not site:mode:nth[:arg]")
        site, mode, nth = bits[0], bits[1], bits[2]
        if mode not in MODES:
            raise ValueError(
                f"fault-inject mode {mode!r} not in {MODES}")
        try:
            n = int(nth)
            arg = float(bits[3]) if len(bits) == 4 else \
                (3600.0 if mode == "hang" else 1.0)
        except ValueError as e:
            raise ValueError(f"fault-inject spec {part!r}: {e}") from None
        if n < 1:
            raise ValueError(f"fault-inject nth must be >= 1, got {n}")
        out.setdefault(site, []).append((mode, n, arg))
    return out


def configure(spec: Optional[str]) -> None:
    """(Re)arm from ``spec``; None/"" disarms.  Resets all counters."""
    global _specs
    _specs = parse(spec) if spec else {}
    _counts.clear()


def fire(site: str) -> None:
    """Hook point: counts the call and executes any armed fault whose
    ``nth`` matches.  No-op (one dict lookup) when disarmed."""
    if not _specs:
        return
    armed = _specs.get(site)
    if not armed:
        return
    _counts[site] = n = _counts.get(site, 0) + 1
    for mode, nth, arg in armed:
        if nth != n:
            continue
        if mode == "kill":
            os.kill(os.getpid(), signal.SIGKILL)
        elif mode == "exit":
            os._exit(17)
        elif mode in ("hang", "slow"):
            time.sleep(arg)


def counts() -> Dict[str, int]:
    return dict(_counts)


configure(os.environ.get("REPRO_FAULT_INJECT"))
