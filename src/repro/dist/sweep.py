"""Multi-device sharded featurization sweeps (the distributed sweep layer).

Production fields don't fit one device and sweep requests arrive
concurrently, so the batched sweep engine
(``repro.core.predictors.features_sweep``) gains a ``shard_map`` path over
its slice axis here: the (k, m, n) slice stack -- or (k, d, m, n) volume
stack, sharded identically over k -- is split across the mesh axis the
logical ``"slices"`` axis maps to (``"data"`` under the default rules of
``repro.dist.sharding``), each device runs the fused single-device sweep
body on its local shard -- one batched Gram + eigvalsh per 2-D stack (one
per HOSVD mode for volumes) and one multi-eps q-ent pass per shard, grid
dim 0 of both batched kernels -- and the per-device ``(k_local, e, 2)``
results are reassembled into the global ``(k, e, 2)`` tensor.

Slice counts that don't divide the mesh extent are padded with copies of
the last slice; the pad rows are dropped from the gathered result
(``gather=True``) or zero-masked in the still-sharded padded result
(``gather=False``, for pipelines whose downstream stages stay
distributed).

Typical invocation on a multi-device CPU host (the flag must be exported
before jax is imported)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...

    from repro.dist import sharding as S
    from repro.launch import mesh as M
    with S.use_mesh(M.make_sweep_mesh()):
        feats = predictors.features_sweep(slices, ebs)   # auto-sharded

Multi-process fabric
--------------------
The same entry points accept PROCESS-SPANNING meshes: after
``repro.launch.mesh.dist_init(...)`` on every process,
``make_sweep_mesh()`` covers ``jax.process_count() x
local_device_count`` devices and the sweep runs as one collective
launch.  Two ingestion contracts:

* **SPMD (default)** -- every process passes the identical global
  (k, ...) stack; each process uploads only its contiguous block of
  rows to its own devices (``jax.make_array_from_process_local_data``),
  so no process ever materializes the stack on-device.
* **process-local** (``process_local=True, global_k=``) -- each process
  passes ONLY the rows :func:`process_block` assigns it (scale-out
  ingestion: each host reads its own rows from disk/network).

Padding generalizes across processes: the global stack is padded to a
multiple of the mesh extent and real row *i* always lives at global
position *i*, so the pad rows occupy the trailing positions -- they
live on the LAST process -- and ``gather=True`` drops them /
``gather=False`` masks them exactly like the single-process path.  The
gather is a ``multihost_utils.process_allgather``, so every process
returns the full (k, e, 2) tensor.

Training support: ``training_crs`` partitions the *compressor* runs an
``EbGridModel`` fit needs over the processes of the SAME sweep mesh
(each host compresses only its contiguous block of slices) and
all-gathers the (k, e) CR table, matching the sweep's
features-all-gathered / CRs-computed-locally cost structure.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as S


def active_sweep_mesh(mesh: Optional[Mesh] = None) -> Optional[Mesh]:
    """The mesh a sweep should shard over, or None for single-device.

    Returns ``mesh`` (or the active ``use_mesh`` mesh) when the logical
    "slices" axis resolves to a physical extent > 1 and we are not already
    inside a manual shard_map body (where the engine must run locally).
    """
    mesh = mesh if mesh is not None else S.current_mesh()
    if mesh is None or S.in_manual_context():
        return None
    axes = S._physical_axes("slices", mesh)
    if S._mesh_extent(mesh, axes) <= 1:
        return None
    return mesh


def slice_axes(mesh: Mesh) -> tuple:
    """Physical mesh axes the slice axis shards over (non-empty tuple)."""
    axes = S._physical_axes("slices", mesh)
    if not axes:
        raise ValueError(
            f"mesh {mesh.axis_names} has no axis for the logical 'slices' "
            "axis; add a rules entry mapping 'slices' to a mesh axis")
    return axes


# ---------------------------------------------------------------------------
# Multi-process fabric helpers
# ---------------------------------------------------------------------------

def mesh_spans_processes(mesh: Optional[Mesh]) -> bool:
    """True when ``mesh`` places devices on more than one process."""
    if mesh is None:
        return False
    return len({d.process_index for d in mesh.devices.flat}) > 1


def mesh_processes(mesh: Mesh) -> list[int]:
    """Sorted process indices participating in ``mesh``."""
    return sorted({d.process_index for d in mesh.devices.flat})


def _process_position(mesh: Mesh) -> tuple[int, int]:
    """(position of this process among the mesh's processes, #processes).

    Raises when the calling process owns none of the mesh's devices --
    such a process cannot join the collective launch and silently
    continuing would hang the others.
    """
    procs = mesh_processes(mesh)
    me = jax.process_index()
    if me not in procs:
        raise ValueError(
            f"process {me} has no devices in mesh {mesh.axis_names} "
            f"(processes {procs}); every participating process must build "
            "the mesh over devices it contributes")
    return procs.index(me), len(procs)


def _device_spans(mesh: Mesh) -> dict:
    """{process index: (first flat device position, device count)} for
    ``mesh``, requiring each process's devices to be CONTIGUOUS in mesh
    order (true for ``make_sweep_mesh``: ``jax.devices()`` is
    process-ordered) so per-process row blocks are contiguous too."""
    spans: dict = {}
    for i, d in enumerate(mesh.devices.flat):
        p = d.process_index
        if p not in spans:
            spans[p] = (i, 1)
        else:
            first, n = spans[p]
            if first + n != i:
                raise ValueError(
                    f"mesh {mesh.axis_names} interleaves process {p}'s "
                    "devices with other processes'; the sweep fabric "
                    "needs contiguous per-process device blocks (build "
                    "the mesh with launch.mesh.make_sweep_mesh)")
            spans[p] = (first, n + 1)
    return spans


def process_block(k: int, mesh: Mesh) -> tuple[int, int]:
    """[lo, hi) rows of a k-row global stack THIS process ingests.

    The padded global row count ``k_pad = ceil(k / extent) * extent``
    distributes ``k_pad / extent`` rows per device, so each process's
    contiguous block is proportional to the devices it contributes
    (processes may own UNEQUAL device counts, e.g. a mesh built over a
    prefix of the global device list); blocks are clipped to the real
    ``k``, which keeps real row *i* at global position *i* and pushes
    every pad row to the trailing positions -- the pad lives on the
    last process(es).
    """
    axes = slice_axes(mesh)
    ext = S._mesh_extent(mesh, axes)
    _process_position(mesh)          # membership check (clear error)
    first, ndev = _device_spans(mesh)[jax.process_index()]
    k_pad = -(-k // ext) * ext
    rpd = k_pad // ext               # rows per device
    return min(first * rpd, k), min((first + ndev) * rpd, k)


def gather_rows(out) -> np.ndarray:
    """Bring a (possibly process-spanning) sweep result to the host.

    Fully-addressable arrays transfer directly; global arrays with
    non-addressable shards are collectively all-gathered first (every
    participating process must call this -- it is the sweep fabric's one
    synchronization point).  The all-gather is RUNTIME-global
    (``multihost_utils.process_allgather``), so it requires every
    process of the ``jax.distributed`` runtime to be alive; on a
    recovered fabric whose mesh no longer spans every runtime process
    use :func:`replicate_rows` instead.
    """
    if isinstance(out, jax.Array) and not out.is_fully_addressable:
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(out, tiled=True))
    return np.asarray(out)


def replicate_rows(out, mesh: Mesh) -> np.ndarray:
    """MESH-scoped gather: replicate ``out`` across ``mesh`` and read
    the local copy.

    Equivalent in value to :func:`gather_rows` but the collective is
    scoped to ``mesh``'s processes only (a jitted identity with
    replicated out_shardings), so it works on a shrunken survivor
    submesh while dead runtime peers would wedge/abort the
    runtime-global ``process_allgather``.  Every process owning devices
    in ``mesh`` must make this call.
    """
    if not isinstance(out, jax.Array) or out.is_fully_addressable:
        return np.asarray(out)
    sh = NamedSharding(mesh, P(*([None] * out.ndim)))
    rep = jax.jit(lambda a: a, out_shardings=sh)(out)
    return np.asarray(rep.addressable_shards[0].data)


def invalidate_mesh_caches() -> None:
    """Drop every cached sharded-sweep executable.

    Called after elastic recovery rebuilds the mesh: executables
    compiled for the OLD mesh are keyed by it and would never be hit
    again, but they pin compiled programs (and device references) that
    include lost processes -- clear the lot so the shrunken fabric
    recompiles only what it uses.
    """
    _sharded_sweep_fn.cache_clear()


def _global_stack(local: np.ndarray, global_shape: tuple, mesh: Mesh,
                  axes: tuple):
    """Assemble the global padded (k_pad, ...) device array from this
    process's padded block (``jax.make_array_from_process_local_data``:
    each process uploads only its own rows)."""
    part = axes[0] if len(axes) == 1 else axes
    spec = P(part, *([None] * (len(global_shape) - 1)))
    return jax.make_array_from_process_local_data(
        NamedSharding(mesh, spec), local, global_shape)


def _replicated(x: np.ndarray, mesh: Mesh):
    """A globally-replicated device array from identical per-process
    host values (error-bound vectors, masks)."""
    sh = NamedSharding(mesh, P(*([None] * x.ndim)))
    return jax.make_array_from_process_local_data(sh, x, x.shape)


def _pad_block(block: np.ndarray, per: int, shape_tail: tuple,
               dtype) -> np.ndarray:
    """Pad a process's local row block to its ``per``-row device block.

    Pad rows repeat the block's last real row (keeps the eigensolve and
    the q-ent sort numerically unexceptional); a process with NO real
    rows (k far below the mesh extent) feeds zeros -- pad rows are
    dropped or masked downstream, so their values never surface.
    """
    n = block.shape[0]
    if n == per:
        return np.ascontiguousarray(block)
    if n == 0:
        return np.zeros((per,) + shape_tail, dtype)
    return np.concatenate(
        [block, np.broadcast_to(block[-1:], (per - n,) + shape_tail)], axis=0)


def _features_sweep_multihost(slices, epss, cfg, mesh: Mesh, gather: bool,
                              process_local: bool, global_k: Optional[int],
                              donate: bool = False, mode: str = "features"):
    """Process-spanning sweep launch (see module docstring): per-process
    ingestion -> one collective shard_map -> ``process_allgather``."""
    from repro.core import predictors as PRED
    axes = slice_axes(mesh)
    ext = S._mesh_extent(mesh, axes)
    _process_position(mesh)          # membership check (clear error)
    host = np.asarray(slices)

    if process_local:
        if global_k is None:
            raise ValueError(
                "process_local=True needs global_k= (the total row count "
                "across processes; each process passes only the rows "
                "process_block(global_k, mesh) assigns it)")
        k = int(global_k)
        lo, hi = process_block(k, mesh)
        if host.shape[0] != hi - lo:
            raise ValueError(
                f"process {jax.process_index()} must ingest rows "
                f"[{lo}, {hi}) of the {k}-row global stack, got "
                f"{host.shape[0]} rows (use process_block to split)")
        local = host
    else:
        k = host.shape[0]
        lo, hi = process_block(k, mesh)
        local = host[lo:hi]

    k_pad = -(-k // ext) * ext
    # this process's device block is proportional to the devices it
    # contributes (per-process shares may be unequal)
    _, ndev = _device_spans(mesh)[jax.process_index()]
    per = (k_pad // ext) * ndev
    local = _pad_block(local, per, host.shape[1:], host.dtype)
    garr = _global_stack(local, (k_pad,) + host.shape[1:], mesh, axes)
    eps_np = np.asarray(epss, np.float32).reshape(-1)
    eps_g = _replicated(eps_np, mesh)

    out = _sharded_sweep_fn(
        mesh, axes, host.ndim,
        PRED.variance_fraction_for(cfg, host.ndim), cfg.qent_bins,
        cfg.use_kernels, cfg.tune,
        # garr is assembled fresh from host memory every launch, so
        # donating it back to XLA is always safe here
        donate, mode)(garr, eps_g)

    if gather:
        return jnp.asarray(gather_rows(out)[:k])
    if k_pad > k:                                       # mask pad rows
        mask = (np.arange(k_pad) < k).astype(np.float32).reshape(-1, 1, 1)
        out = out * _replicated(mask, mesh)
    return out


@functools.lru_cache(maxsize=32)
def _sharded_sweep_fn(mesh: Mesh, axes: tuple, rank: int, vf: float,
                      bins: int, use_kernels: bool, tune=None,
                      donate: bool = False, mode: str = "features"):
    """jit'd shard_map sweep for one (mesh, stack rank, config); cached so
    repeated sweeps (serving, training grids) reuse the compiled
    executable.  ``rank`` is the stack's ndim: 3 for (k, m, n) slice
    stacks, 4 for (k, d, m, n) volume stacks -- only dim 0 is sharded
    either way.  ``donate=True`` compiles a variant that donates the
    input stack's buffer (identical math; serving hot path).  ``mode``
    selects the emitted tensor ("features" | "quality" | "both", see
    ``predictors.SWEEP_MODE_WIDTHS``) -- the output stays rank-3 at
    every width, so the specs below are mode-agnostic."""
    from repro.core import predictors as PRED

    part = axes[0] if len(axes) == 1 else axes

    def body(local_slices, epss):
        # each device featurizes its (k_local, ...) shard with the exact
        # single-device sweep body: sharded == single-device to f32 tol
        return PRED._features_sweep_impl(
            local_slices, epss, vf=vf, bins=bins, use_kernels=use_kernels,
            tune=tune, mode=mode)

    f = S.shard_map(
        body, mesh=mesh,
        in_specs=(P(part, *([None] * (rank - 1))), P(None)),
        out_specs=P(part, None, None),
        axis_names=frozenset(axes))
    return jax.jit(f, donate_argnums=(0,) if donate else ())


def features_sweep_sharded(
    slices: jnp.ndarray,
    epss,
    cfg=None,
    *,
    mesh: Optional[Mesh] = None,
    gather: bool = True,
    process_local: bool = False,
    global_k: Optional[int] = None,
    donate: bool = False,
    mode: str = "features",
) -> jnp.ndarray:
    """``features_sweep`` sharded over the slice axis of ``mesh``.

    (k, m, n) or (k, d, m, n) x (e,) -> (k, e, 2) [``gather=True``] or the
    padded (k_pad, e, 2) result still sharded over the mesh with pad rows
    zeroed [``gather=False``]; ``k_pad = ceil(k / extent) * extent``.
    Volume stacks shard the k axis exactly like slice stacks do (each
    device runs the batched HOSVD + q-ent body on its local shard).

    Process-spanning meshes run the collective multihost path (module
    docstring): every participating process must make this call with the
    same shapes.  ``process_local=True`` (with ``global_k=``) switches
    the ingestion contract from "identical global stack on every
    process" to "each process passes only its :func:`process_block`
    rows"; with ``gather=True`` every process still returns the full
    (k, e, 2) tensor (``process_allgather``).

    Falls back to the single-device engine when no mesh (or an extent-1
    mesh) is available, so callers can route unconditionally.

    ``donate=True`` donates the input stack's device buffer to the
    launch (zero-copy serving hot path).  The result is bit-identical;
    the caller's ``slices`` array is consumed and must not be reused
    (numpy inputs are unaffected -- only their fresh device upload is
    donated).

    ``mode`` selects the emitted tensor exactly as in
    ``predictors._features_sweep_impl`` ("features" | "quality" |
    "both"); pad-row masking and gathering are width-agnostic.
    """
    from repro.core import predictors as PRED
    cfg = cfg if cfg is not None else PRED.PredictorConfig()
    mesh = active_sweep_mesh(mesh)
    if mesh is None:
        if process_local:
            raise ValueError(
                "process_local=True needs a process-spanning mesh "
                "(dist_init + make_sweep_mesh); no usable mesh is active")
        return PRED._sweep_dispatch(jnp.asarray(slices), epss, cfg,
                                    sharded=False, mesh=None, gather=True,
                                    mode=mode)
    if slices.ndim not in (3, 4):
        raise ValueError(
            f"features_sweep_sharded expects (k, m, n) or (k, d, m, n), "
            f"got {slices.shape}")
    PRED._validate_eps_positive(epss)
    if mesh_spans_processes(mesh):
        return _features_sweep_multihost(
            slices, epss, cfg, mesh, gather, process_local, global_k, donate,
            mode)
    if process_local:
        raise ValueError(
            "process_local=True is only meaningful on a process-spanning "
            f"mesh; mesh {mesh.axis_names} lives on one process")
    epss = jnp.asarray(epss, jnp.float32).reshape(-1)

    axes = slice_axes(mesh)
    ext = S._mesh_extent(mesh, axes)
    k = slices.shape[0]
    pad = (-k) % ext
    if pad:
        # pad with the last slice (real data: keeps the eigensolve and the
        # q-ent sort on the padded rows numerically unexceptional); the
        # concat result is owned here, so its buffer is donatable
        slices = jnp.concatenate(
            [slices, jnp.broadcast_to(slices[-1:], (pad,) + slices.shape[1:])],
            axis=0)
        donate = True

    out = _sharded_sweep_fn(
        mesh, axes, slices.ndim,
        PRED.variance_fraction_for(cfg, slices.ndim), cfg.qent_bins,
        cfg.use_kernels, cfg.tune, donate, mode)(slices, epss)

    if gather:
        out = out[:k]                                   # drop pad rows
        return jax.device_put(
            out, NamedSharding(mesh, P(None, None, None)))
    if pad:                                             # mask pad rows
        mask = (jnp.arange(k + pad) < k).astype(out.dtype)
        out = out * mask[:, None, None]
    return out


# ---------------------------------------------------------------------------
# Serve-side coalescing: padded bucketed launches + per-request scatter-back
# ---------------------------------------------------------------------------

def sweep_padded(
    slices: jnp.ndarray,
    epss,
    cfg=None,
    *,
    k_pad: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    donate: bool = False,
    mode: str = "features",
) -> jnp.ndarray:
    """One coalesced sweep launch over a padded request batch.

    The sweep service stacks several requests' slices (or volumes: any
    shared trailing shape) into one (k, m, n) / (k, d, m, n) batch, pads
    it to a *bucketed* ``k_pad`` (so a small set of compiled
    executables serves every batch size), and launches once:

    * ``k_pad`` a multiple of the mesh's slice extent -> the ``shard_map``
      path with ``gather=False`` (each device keeps its shard; no
      reshard/gather between launch and scatter-back, and the bucket pad
      doubles as the mesh pad so no second padding happens inside);
    * otherwise (no mesh, or a bucket below the extent) -> the
      single-device fused engine.

    Process-spanning meshes launch collectively: every participating
    process calls ``sweep_padded`` with the same (stack, epss, k_pad)
    -- the sweep service's leader/follower mode broadcasts exactly these
    -- and the returned global array's shards stay on their processes
    until ``gather_rows``/``scatter_requests`` all-gathers them.  A
    bucket below the global extent drops every process to the identical
    local computation, so the branch stays deadlock-free.

    Returns the PADDED (k_pad, e, 2) result; rows past the true batch are
    garbage-by-construction (copies of the last slice) and the caller
    scatters only real rows back to requests (``scatter_requests``).
    Every kept row is bit-identical to a single-request launch of that
    slice because the sweep body is row-independent.

    ``donate=True`` donates the stack's device buffer to the launch (the
    sweep service always passes it: the packed batch is service-owned
    staging memory).  When padding happens here the padded copy is owned
    and donated regardless.  Donation never changes the result -- only
    buffer lifetime -- and donated launches are asserted bit-equal to
    non-donated ones in tests/test_tune.py.

    ``mode`` selects the emitted tensor ("features" | "quality" |
    "both") -- the quality launcher in ``serve/method.py`` rides this
    exact entry point with ``mode="quality"``.
    """
    from repro.core import predictors as PRED
    cfg = cfg if cfg is not None else PRED.PredictorConfig()
    if slices.ndim not in (3, 4):
        raise ValueError(
            f"sweep_padded expects (k, m, n) or (k, d, m, n), "
            f"got {slices.shape}")
    PRED._validate_eps_positive(epss)
    k = slices.shape[0]
    k_pad = k if k_pad is None else int(k_pad)
    if k_pad < k:
        raise ValueError(f"k_pad={k_pad} smaller than batch k={k}")
    if k_pad > k:
        slices = jnp.concatenate(
            [slices,
             jnp.broadcast_to(slices[-1:], (k_pad - k,) + slices.shape[1:])],
            axis=0)
        donate = True            # the padded copy is owned here
    epss = jnp.asarray(epss, jnp.float32).reshape(-1)
    mesh = active_sweep_mesh(mesh)
    if mesh is not None:
        ext = S._mesh_extent(mesh, slice_axes(mesh))
        if k_pad >= ext and k_pad % ext == 0:
            return features_sweep_sharded(
                slices, epss, cfg, mesh=mesh, gather=False, donate=donate,
                mode=mode)
    fn = (PRED._features_sweep_donated if donate
          else PRED._features_sweep_traced)
    return fn(
        slices, epss, vf=PRED.variance_fraction_for(cfg, slices.ndim),
        bins=cfg.qent_bins, use_kernels=cfg.use_kernels, tune=cfg.tune,
        mode=mode)


def scatter_requests(out, sizes: Sequence[int]) -> list:
    """Scatter a coalesced (k_pad, e, 2) sweep result back into
    per-request row blocks.

    ONE host transfer for the whole batch (for the ``gather=False``
    sharded layout this is the only gather point; process-spanning
    results are collectively all-gathered, so every participating
    process must reach this call); ``sizes`` are the per-request row
    counts in stacking order, and trailing pad rows are dropped.
    Returns a list of (sizes[i], e, 2) numpy arrays.
    """
    host = gather_rows(out)
    total = int(np.sum(sizes)) if len(sizes) else 0
    if total > host.shape[0]:
        raise ValueError(
            f"request sizes sum to {total} but the result has only "
            f"{host.shape[0]} rows")
    blocks, off = [], 0
    for s in sizes:
        blocks.append(host[off:off + s])
        off += s
    return blocks


# ---------------------------------------------------------------------------
# Training-side distribution: compressor runs over local slice shards
# ---------------------------------------------------------------------------

def _even_bounds(k: int, parts: int, index: int) -> tuple[int, int]:
    """Contiguous [lo, hi) block of ``k`` items for shard ``index`` of
    ``parts`` (remainder spread over the leading shards)."""
    base, rem = divmod(k, parts)
    lo = index * base + min(index, rem)
    return lo, lo + base + (1 if index < rem else 0)


def training_crs(comp, slices, ebs: Sequence[float], *,
                 mesh: Optional[Mesh] = None) -> np.ndarray:
    """The (k, e) compression-ratio table an ``EbGridModel`` fit needs,
    with the compressor executions partitioned over processes.

    Each process runs the (host-side, numpy) compressor only on its
    contiguous block of slices and the table is all-gathered, so the
    expensive training-time compressor runs scale out with hosts exactly
    like the featurization sweep scales out with devices.  The partition
    is MESH-driven: pass the same process-spanning sweep mesh the
    featurization sharded over and the compressor runs split across that
    mesh's processes (every one of them must make this call -- the
    gather is collective).  Without a process-spanning mesh this is the
    plain full local loop, so single-process callers (tests, CI, a
    service leader training models on the side) never block on a
    collective.
    """
    k = len(slices)
    if mesh_spans_processes(mesh):
        index, parts = _process_position(mesh)
    else:
        parts, index = 1, 0
    lo, hi = _even_bounds(k, parts, index)
    table = np.zeros((k, len(ebs)), np.float64)
    for i in range(lo, hi):
        for j, eps in enumerate(ebs):
            table[i, j] = float(comp.cr(slices[i], float(eps)))
    if parts == 1:
        return table
    from jax.experimental import multihost_utils
    # non-local rows are zero, so summing the per-process tables
    # reconstructs the full (k, e) table.  The gather moves the raw f64
    # BYTES (uint8 payload): jnp would silently downcast float64 to f32
    # under the default x64-disabled config, and training tables must be
    # identical to the serial loop.
    payload = np.frombuffer(table.tobytes(), np.uint8)
    stacked = np.asarray(multihost_utils.process_allgather(payload))
    return sum(np.frombuffer(stacked[p].tobytes(), np.float64)
               .reshape(table.shape) for p in range(stacked.shape[0]))
