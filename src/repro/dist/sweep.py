"""Multi-device sharded featurization sweeps (the distributed sweep layer).

Production fields don't fit one device and sweep requests arrive
concurrently, so the batched sweep engine
(``repro.core.predictors.features_sweep``) gains a ``shard_map`` path over
its slice axis here: the (k, m, n) slice stack -- or (k, d, m, n) volume
stack, sharded identically over k -- is split across the mesh axis the
logical ``"slices"`` axis maps to (``"data"`` under the default rules of
``repro.dist.sharding``), each device runs the fused single-device sweep
body on its local shard -- one batched Gram + eigvalsh per 2-D stack (one
per HOSVD mode for volumes) and one multi-eps q-ent pass per shard, grid
dim 0 of both batched kernels -- and the per-device ``(k_local, e, 2)``
results are reassembled into the global ``(k, e, 2)`` tensor.

Slice counts that don't divide the mesh extent are padded with copies of
the last slice; the pad rows are dropped from the gathered result
(``gather=True``) or zero-masked in the still-sharded padded result
(``gather=False``, for pipelines whose downstream stages stay
distributed).

Typical invocation on a multi-device CPU host (the flag must be exported
before jax is imported)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...

    from repro.dist import sharding as S
    from repro.launch import mesh as M
    with S.use_mesh(M.make_sweep_mesh()):
        feats = predictors.features_sweep(slices, ebs)   # auto-sharded

Training support: ``training_crs`` partitions the *compressor* runs an
``EbGridModel`` fit needs over processes (each host compresses only its
contiguous block of slices) and all-gathers the (k, e) CR table, matching
the sweep's features-all-gathered / CRs-computed-locally cost structure.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as S


def active_sweep_mesh(mesh: Optional[Mesh] = None) -> Optional[Mesh]:
    """The mesh a sweep should shard over, or None for single-device.

    Returns ``mesh`` (or the active ``use_mesh`` mesh) when the logical
    "slices" axis resolves to a physical extent > 1 and we are not already
    inside a manual shard_map body (where the engine must run locally).
    """
    mesh = mesh if mesh is not None else S.current_mesh()
    if mesh is None or S.in_manual_context():
        return None
    axes = S._physical_axes("slices", mesh)
    if S._mesh_extent(mesh, axes) <= 1:
        return None
    return mesh


def slice_axes(mesh: Mesh) -> tuple:
    """Physical mesh axes the slice axis shards over (non-empty tuple)."""
    axes = S._physical_axes("slices", mesh)
    if not axes:
        raise ValueError(
            f"mesh {mesh.axis_names} has no axis for the logical 'slices' "
            "axis; add a rules entry mapping 'slices' to a mesh axis")
    return axes


@functools.lru_cache(maxsize=32)
def _sharded_sweep_fn(mesh: Mesh, axes: tuple, rank: int, vf: float,
                      bins: int, use_kernels: bool):
    """jit'd shard_map sweep for one (mesh, stack rank, config); cached so
    repeated sweeps (serving, training grids) reuse the compiled
    executable.  ``rank`` is the stack's ndim: 3 for (k, m, n) slice
    stacks, 4 for (k, d, m, n) volume stacks -- only dim 0 is sharded
    either way."""
    from repro.core import predictors as PRED

    part = axes[0] if len(axes) == 1 else axes

    def body(local_slices, epss):
        # each device featurizes its (k_local, ...) shard with the exact
        # single-device sweep body: sharded == single-device to f32 tol
        return PRED._features_sweep_impl(
            local_slices, epss, vf=vf, bins=bins, use_kernels=use_kernels)

    f = S.shard_map(
        body, mesh=mesh,
        in_specs=(P(part, *([None] * (rank - 1))), P(None)),
        out_specs=P(part, None, None),
        axis_names=frozenset(axes))
    return jax.jit(f)


def features_sweep_sharded(
    slices: jnp.ndarray,
    epss,
    cfg=None,
    *,
    mesh: Optional[Mesh] = None,
    gather: bool = True,
) -> jnp.ndarray:
    """``features_sweep`` sharded over the slice axis of ``mesh``.

    (k, m, n) or (k, d, m, n) x (e,) -> (k, e, 2) [``gather=True``] or the
    padded (k_pad, e, 2) result still sharded over the mesh with pad rows
    zeroed [``gather=False``]; ``k_pad = ceil(k / extent) * extent``.
    Volume stacks shard the k axis exactly like slice stacks do (each
    device runs the batched HOSVD + q-ent body on its local shard).

    Falls back to the single-device engine when no mesh (or an extent-1
    mesh) is available, so callers can route unconditionally.
    """
    from repro.core import predictors as PRED
    cfg = cfg if cfg is not None else PRED.PredictorConfig()
    mesh = active_sweep_mesh(mesh)
    if mesh is None:
        return PRED.features_sweep(slices, epss, cfg, sharded=False)
    if slices.ndim not in (3, 4):
        raise ValueError(
            f"features_sweep_sharded expects (k, m, n) or (k, d, m, n), "
            f"got {slices.shape}")
    PRED._validate_eps_positive(epss)
    epss = jnp.asarray(epss, jnp.float32).reshape(-1)

    axes = slice_axes(mesh)
    ext = S._mesh_extent(mesh, axes)
    k = slices.shape[0]
    pad = (-k) % ext
    if pad:
        # pad with the last slice (real data: keeps the eigensolve and the
        # q-ent sort on the padded rows numerically unexceptional)
        slices = jnp.concatenate(
            [slices, jnp.broadcast_to(slices[-1:], (pad,) + slices.shape[1:])],
            axis=0)

    out = _sharded_sweep_fn(
        mesh, axes, slices.ndim,
        PRED.variance_fraction_for(cfg, slices.ndim), cfg.qent_bins,
        cfg.use_kernels)(slices, epss)

    if gather:
        out = out[:k]                                   # drop pad rows
        return jax.device_put(
            out, NamedSharding(mesh, P(None, None, None)))
    if pad:                                             # mask pad rows
        mask = (jnp.arange(k + pad) < k).astype(out.dtype)
        out = out * mask[:, None, None]
    return out


# ---------------------------------------------------------------------------
# Serve-side coalescing: padded bucketed launches + per-request scatter-back
# ---------------------------------------------------------------------------

def sweep_padded(
    slices: jnp.ndarray,
    epss,
    cfg=None,
    *,
    k_pad: Optional[int] = None,
    mesh: Optional[Mesh] = None,
) -> jnp.ndarray:
    """One coalesced sweep launch over a padded request batch.

    The sweep service stacks several requests' slices (or volumes: any
    shared trailing shape) into one (k, m, n) / (k, d, m, n) batch, pads
    it to a *bucketed* ``k_pad`` (so a small set of compiled
    executables serves every batch size), and launches once:

    * ``k_pad`` a multiple of the mesh's slice extent -> the ``shard_map``
      path with ``gather=False`` (each device keeps its shard; no
      reshard/gather between launch and scatter-back, and the bucket pad
      doubles as the mesh pad so no second padding happens inside);
    * otherwise (no mesh, or a bucket below the extent) -> the
      single-device fused engine.

    Returns the PADDED (k_pad, e, 2) result; rows past the true batch are
    garbage-by-construction (copies of the last slice) and the caller
    scatters only real rows back to requests (``scatter_requests``).
    Every kept row is bit-identical to a single-request launch of that
    slice because the sweep body is row-independent.
    """
    from repro.core import predictors as PRED
    cfg = cfg if cfg is not None else PRED.PredictorConfig()
    if slices.ndim not in (3, 4):
        raise ValueError(
            f"sweep_padded expects (k, m, n) or (k, d, m, n), "
            f"got {slices.shape}")
    PRED._validate_eps_positive(epss)
    k = slices.shape[0]
    k_pad = k if k_pad is None else int(k_pad)
    if k_pad < k:
        raise ValueError(f"k_pad={k_pad} smaller than batch k={k}")
    if k_pad > k:
        slices = jnp.concatenate(
            [slices,
             jnp.broadcast_to(slices[-1:], (k_pad - k,) + slices.shape[1:])],
            axis=0)
    epss = jnp.asarray(epss, jnp.float32).reshape(-1)
    mesh = active_sweep_mesh(mesh)
    if mesh is not None:
        ext = S._mesh_extent(mesh, slice_axes(mesh))
        if k_pad >= ext and k_pad % ext == 0:
            return features_sweep_sharded(
                slices, epss, cfg, mesh=mesh, gather=False)
    return PRED._features_sweep_traced(
        slices, epss, vf=PRED.variance_fraction_for(cfg, slices.ndim),
        bins=cfg.qent_bins, use_kernels=cfg.use_kernels)


def scatter_requests(out, sizes: Sequence[int]) -> list:
    """Scatter a coalesced (k_pad, e, 2) sweep result back into
    per-request row blocks.

    ONE host transfer for the whole batch (for the ``gather=False``
    sharded layout this is the only gather point); ``sizes`` are the
    per-request row counts in stacking order, and trailing pad rows are
    dropped.  Returns a list of (sizes[i], e, 2) numpy arrays.
    """
    host = np.asarray(out)
    total = int(np.sum(sizes)) if len(sizes) else 0
    if total > host.shape[0]:
        raise ValueError(
            f"request sizes sum to {total} but the result has only "
            f"{host.shape[0]} rows")
    blocks, off = [], 0
    for s in sizes:
        blocks.append(host[off:off + s])
        off += s
    return blocks


# ---------------------------------------------------------------------------
# Training-side distribution: compressor runs over local slice shards
# ---------------------------------------------------------------------------

def _even_bounds(k: int, parts: int, index: int) -> tuple[int, int]:
    """Contiguous [lo, hi) block of ``k`` items for shard ``index`` of
    ``parts`` (remainder spread over the leading shards)."""
    base, rem = divmod(k, parts)
    lo = index * base + min(index, rem)
    return lo, lo + base + (1 if index < rem else 0)


def training_crs(comp, slices, ebs: Sequence[float]) -> np.ndarray:
    """The (k, e) compression-ratio table an ``EbGridModel`` fit needs,
    with the compressor executions partitioned over processes.

    Each process runs the (host-side, numpy) compressor only on its
    contiguous block of slices and the table is all-gathered, so the
    expensive training-time compressor runs scale out with hosts exactly
    like the featurization sweep scales out with devices.  Single-process
    (tests, CI) reduces to the plain full loop.
    """
    k = len(slices)
    parts, index = jax.process_count(), jax.process_index()
    lo, hi = _even_bounds(k, parts, index)
    table = np.zeros((k, len(ebs)), np.float64)
    for i in range(lo, hi):
        for j, eps in enumerate(ebs):
            table[i, j] = float(comp.cr(slices[i], float(eps)))
    if parts == 1:
        return table
    from jax.experimental import multihost_utils
    # non-local rows are zero, so summing the per-process tables
    # reconstructs the full (k, e) table
    stacked = multihost_utils.process_allgather(jnp.asarray(table))
    return np.asarray(stacked).sum(axis=0)
