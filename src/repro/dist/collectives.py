"""Compressed cross-pod collectives.

Cross-pod links are the scarcest bandwidth in a multi-pod job, so the pod
gradient sync ships int8 blocks (amax-scaled along the last axis) instead
of f32: a 4x wire-byte reduction for <1% relative error on gradient-scale
tensors.  Used inside ``shard_map`` bodies that are manual over "pod".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Per-row symmetric int8 quantization along the last axis."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_pod_allreduce(x: jnp.ndarray, axis_name: str = "pod") -> jnp.ndarray:
    """Mean of ``x`` across ``axis_name`` via an int8 all-gather.

    Quantize locally, all-gather the int8 payload + f32 scales (the only
    cross-pod transfer), dequantize and average on the receiver.  Must be
    called inside a shard_map manual over ``axis_name``.
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)
    ss = jax.lax.all_gather(scale, axis_name)
    mean = jnp.mean(dequantize_int8(qs, ss), axis=0)
    return mean.astype(x.dtype)
