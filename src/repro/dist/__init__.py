"""Distribution layer: logical-axis sharding, compressed collectives,
elastic fault handling.

Importing this package installs compatibility polyfills for older jax
releases (``jax.shard_map`` as a thin adapter over
``jax.experimental.shard_map``) so the call sites can use the modern
spelling unconditionally.
"""
from repro.dist import sharding  # noqa: F401  (installs jax compat shims)
