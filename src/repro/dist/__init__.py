"""Distribution layer: logical-axis sharding, sharded featurization
sweeps, compressed collectives, elastic fault handling.

Importing this package installs compatibility polyfills for older jax
releases (``jax.shard_map`` as a thin adapter over
``jax.experimental.shard_map``) so the call sites can use the modern
spelling unconditionally.

``repro.dist.sweep`` is the multi-device sweep layer: activate a mesh via
``sharding.use_mesh`` and every ``features_sweep`` shards its slice axis
across the mesh's "data" axis (padding non-divisible slice counts,
gathering -- or optionally keeping sharded -- the (k, e, 2) result).
"""
from repro.dist import sharding  # noqa: F401  (installs jax compat shims)
