"""Servable methods: the per-workload layer of the serving platform.

The sweep service used to hardcode exactly three request kinds; every
new prediction workload meant another bespoke ``submit_*`` path threaded
through the queue, the cache, and the leader/follower protocol.  This
module is the saxml-style answer: a :class:`ServableMethod` owns
everything workload-specific --

* **host-side ``pre_process``** -- argument validation, f32
  canonicalization and content digesting, run on the CALLER's thread at
  submit time (never on the device thread, and never inside the
  coalesced batch where a failure would poison other requests);
* a **``launcher``** -- the device-launch recipe.  Methods that share a
  launcher coalesce into the same batched launches (featurize/UC1/UC2
  all ride :class:`SweepLauncher`, exactly as before the refactor);
* **host-side ``post_process``** -- turning cached/launched feature rows
  into the request's result (UC1 bisection, UC2 ranking, ...), run on
  the service's post-processing pool, off the device thread;
* **sorted ``batch_buckets``** -- the method's batch-size ladder.
  ``None`` means the unbounded power-of-two ladder (:func:`_row_bucket`);
  an explicit sorted tuple pads batches to the smallest covering bucket
  and falls back to the power-of-two ladder past the largest bucket;
* a **dummy-data ``warmup_spec``** -- shapes x eps-grid sizes x row
  buckets the service precompiles so first requests don't pay compile
  latency.

The batching core (``repro.serve.sweep_service.SweepService``) knows
nothing about any of them: its queue/launch path handles only
:class:`MethodRequest` items and launcher ids, so registering a new
method (``repro.serve.registry``) never touches the core.

Launcher contract
-----------------
Every launcher computation MUST be row-independent (a row inside a
padded, deduplicated batch equals the same row launched alone) and
per-eps-independent (column ``j`` of an eps union equals that eps
launched alone).  The core relies on both for coalescing, in-batch
dedup, eps unioning, cross-request caching, and the row-partitioned
elastic-recovery transport -- all of which are bit-equal only because
of these two properties.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import predictors as P
from repro.core import usecases as UC
from repro.core.regression import predict_fast
from repro.data.source import StreamingDigest
from repro.dist import sweep as DS

_EPS_BUCKETS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)


def _row_bucket(k: int) -> int:
    """Smallest power-of-two >= k: row buckets are pow2 so any pow2 mesh
    extent divides every bucket at or above it (the sharded path never
    needs a second pad)."""
    b = 1
    while b < k:
        b *= 2
    return b


def _eps_bucket(e: int) -> int:
    for b in _EPS_BUCKETS:
        if e <= b:
            return b
    return -(-e // 16) * 16


def _f32(eps) -> float:
    """Canonical f32 error-bound key (features are computed in f32)."""
    return float(np.float32(eps))


def slice_digest(x) -> str:
    """Content hash of a slice's f32 bytes (featurization casts to f32,
    so a float64 array and its f32 round-trip share cache entries).

    Implemented as the one-chunk case of ``repro.data.source.
    StreamingDigest``, so a digest accumulated from chunked reads of an
    out-of-core variable (``core.stream.stream_features(digest=...)``)
    is bit-identical to this resident-array hash -- the FeatureCache can
    be probed/keyed for streamed variables without re-materializing
    them."""
    return StreamingDigest().update(x).digest()


@dataclasses.dataclass(frozen=True)
class WarmupSpec:
    """Dummy-data warmup coverage for one method: every (trailing shape,
    eps-grid size, row bucket) combination is compiled by ``warmup()``."""
    shapes: Tuple[Tuple[int, ...], ...]
    grid_sizes: Tuple[int, ...] = (1,)
    row_buckets: Tuple[int, ...] = (1,)


@dataclasses.dataclass
class Item:
    """One slice's launch needs within a request."""
    key: tuple                       # (digest, launch config)
    x: np.ndarray                    # f32 launch copy, any trailing shape
    eps_keys: Tuple[float, ...]      # f32 eps keys this request reads


@dataclasses.dataclass
class MethodRequest:
    """One accepted request, produced by ``ServableMethod.pre_process``
    and consumed generically by the batching core."""
    method: "ServableMethod"
    items: List[Item]
    future: Future
    payload: dict
    t_submit: float

    @property
    def rows(self) -> int:
        return len(self.items)

    @property
    def kind(self) -> str:
        return self.method.name


class Launcher:
    """Device-launch recipe shared by every method that coalesces with
    it.  See the module docstring for the row/eps-independence contract.
    Identity matters: methods registered with the SAME launcher instance
    batch into the same launches."""

    name = "launcher"
    row_width = 1                    # trailing feature width R of a row
    warmup_eps = 1.0                 # dummy eps value for warmup launches

    def launch(self, stack: np.ndarray, epss: np.ndarray, cfg,
               k_pad: int, mesh):
        """One padded device launch -> (k_pad, len(epss), row_width)."""
        raise NotImplementedError

    def gather(self, out) -> np.ndarray:
        """Bring a launch result to the host (collective gather point on
        a process-spanning mesh)."""
        return np.asarray(DS.gather_rows(out))

    def follower_cfg(self, scfg):
        """The launch config a FOLLOWER compiles against (launches carry
        no per-request config across the process boundary)."""
        return None

    def eps_bucket(self, e: int) -> int:
        return _eps_bucket(e)


class SweepLauncher(Launcher):
    """The paper's featurization sweep: (k, m, n) / (k, d, m, n) stack x
    (e,) eps vector -> (k, e, 2) feature rows via one persistent-mesh
    ``dist.sweep.sweep_padded`` launch.

    Launches donate the stack's device buffer: the service always hands
    this launcher service-owned memory (its staging buffer, or the
    follower's broadcast copy), so XLA may reuse the upload in place --
    zero per-batch device allocations in steady state.  Donation never
    changes results (bit-equality asserted in tests/test_tune.py)."""

    name = "sweep"
    row_width = 2

    def launch(self, stack, epss, cfg, k_pad, mesh):
        return DS.sweep_padded(stack, epss, cfg, k_pad=k_pad, mesh=mesh,
                               donate=True)

    def follower_cfg(self, scfg):
        return scfg.pcfg


class Int8CRLauncher(Launcher):
    """Predicted int8+entropy compression ratio per row (the KV-cache
    gate's in-graph size model, ``train.grad_compress.predicted_cr_int8``).

    Rows are FLATTENED leaves: the CR is flatten-invariant (the model
    reshapes to blocks internally), and 1-D rows keep any leaf rank
    inside the fabric's fixed-size launch header.  The launch is a plain
    jit -- no mesh collective -- so on a process-spanning fabric leader
    and followers each compute their broadcast copy locally, which keeps
    the generic protocol deadlock-free.
    """

    name = "int8cr"
    row_width = 1
    warmup_eps = 0.0

    def __init__(self, bins: int = 4096):
        self.bins = int(bins)
        self._fn = None
        self._scratch: Dict[Tuple[int, ...], np.ndarray] = {}
        import threading
        self._lock = threading.Lock()

    @property
    def cfg_key(self) -> tuple:
        return ("int8cr", self.bins)

    def launch(self, stack, epss, cfg, k_pad, mesh):
        import jax
        from repro.train import grad_compress as GC
        if self._fn is None:
            bins = self.bins
            # donate the packed rows: the input is always this
            # launcher's scratch buffer or the fabric's broadcast copy,
            # so XLA may overwrite the upload in place
            self._fn = jax.jit(jax.vmap(
                lambda x: GC.predicted_cr_int8(x, bins)),
                donate_argnums=(0,))
        k = stack.shape[0]
        with self._lock:         # scratch reuse: one launch at a time
            if k_pad > k:
                # pinned, re-used pad scratch: steady-state serving of a
                # bucketed shape allocates nothing per batch (the device
                # upload copies out of it before the next fill)
                shape = (k_pad,) + stack.shape[1:]
                buf = self._scratch.get(shape)
                if buf is None:
                    buf = self._scratch[shape] = np.empty(shape, np.float32)
                buf[:k] = stack
                buf[k:] = stack[-1]
                stack = buf
            crs = np.asarray(self._fn(stack), np.float32)   # (k_pad,)
        e = int(np.asarray(epss).reshape(-1).shape[0])
        return np.broadcast_to(
            crs[:, None, None], (k_pad, e, 1)).copy()

    def follower_cfg(self, scfg):
        return self.cfg_key


class ServableMethod:
    """Base class for registrable serving methods (module docstring has
    the full lifecycle).  Subclasses set ``name``, pass a launcher, and
    implement ``pre_process`` / ``post_process``."""

    name: str = ""
    batch_buckets: Optional[Tuple[int, ...]] = None

    def __init__(self, launcher: Launcher,
                 batch_buckets: Optional[Tuple[int, ...]] = None):
        self.launcher = launcher
        if batch_buckets is not None:
            self.batch_buckets = tuple(int(b) for b in batch_buckets)
        if self.batch_buckets is not None:
            bb = self.batch_buckets
            if not bb or list(bb) != sorted(set(bb)) or bb[0] < 1:
                raise ValueError(
                    f"method {self.name!r}: batch_buckets must be a "
                    f"sorted tuple of distinct positive sizes, got {bb}")

    # -- host-side hooks ----------------------------------------------

    def pre_process(self, svc, *args, **kwargs) -> MethodRequest:
        """Validate + digest a submission on the caller's thread."""
        raise NotImplementedError

    def post_process(self, req: MethodRequest,
                     rows_for: Callable[[Item], np.ndarray]):
        """Complete a request from its feature rows; ``rows_for(item)``
        returns the (len(eps_keys), row_width) rows for one item."""
        raise NotImplementedError

    def warmup_spec(self, scfg) -> WarmupSpec:
        """Dummy-data warmup coverage; override for method traffic."""
        return WarmupSpec(shapes=((32, 32),), grid_sizes=(1,),
                          row_buckets=(1, 2))


# ---------------------------------------------------------------------------
# The built-in methods (the pre-refactor request kinds + the KV gate)
# ---------------------------------------------------------------------------


class FeaturizeMethod(ServableMethod):
    """(k, m, n) / (k, d, m, n) stack x (e,) ebs -> (k, e, 2) rows,
    bit-equal to ``features_sweep(slices, epss)``."""

    name = "featurize"

    def pre_process(self, svc, slices, epss, cfg=None) -> MethodRequest:
        cfg = svc._check_cfg(cfg if cfg is not None else svc.scfg.pcfg)
        arr = np.asarray(slices, np.float32)
        if arr.ndim not in (3, 4):
            raise ValueError(
                f"submit_featurize expects (k, m, n) or (k, d, m, n), "
                f"got {arr.shape}")
        eps_keys = tuple(_f32(e) for e in np.asarray(epss).reshape(-1))
        if not eps_keys:
            raise ValueError("submit_featurize needs at least one eb")
        items = [Item((slice_digest(s), cfg), s, eps_keys) for s in arr]
        return MethodRequest(self, items, Future(),
                             {"eps_keys": eps_keys}, time.perf_counter())

    def post_process(self, req, rows_for):
        return np.stack([rows_for(it) for it in req.items])


class FindEbMethod(ServableMethod):
    """UC1: (eps, predicted_cr) hitting a target CR, bit-equal to
    ``usecases.find_error_bound_for_cr`` -- the grid featurization comes
    from the shared launch / cross-request cache."""

    name = "find_eb"

    def pre_process(self, svc, grid_model, data, target_cr,
                    tol: float = 0.02, max_iters: int = 32) -> MethodRequest:
        cfg = svc._check_cfg(grid_model.cfg)
        x = np.asarray(data, np.float32)
        if x.ndim != grid_model.ndim:
            # validate at submit time: a worker-side failure would poison
            # the whole coalesced batch, not just this request
            raise ValueError(
                f"submit_find_eb: grid model '{grid_model.name}' was "
                f"trained on {grid_model.ndim}-D data, got {x.shape}")
        eps_keys = tuple(_f32(e) for e in np.asarray(grid_model.ebs))
        item = Item((slice_digest(x), cfg), x, eps_keys)
        return MethodRequest(
            self, [item], Future(),
            {"grid_model": grid_model, "data": data,
             "target_cr": target_cr, "tol": tol, "max_iters": max_iters},
            time.perf_counter())

    def post_process(self, req, rows_for):
        gm = req.payload["grid_model"]
        feats = rows_for(req.items[0])                      # (e, 2)
        feat_cache = P.get_engine(gm.cfg).cached(
            req.payload["data"], features=feats, epss=gm.ebs)
        return UC.find_error_bound_for_cr(
            gm, req.payload["data"], req.payload["target_cr"],
            tol=req.payload["tol"], max_iters=req.payload["max_iters"],
            feat_cache=feat_cache)


class BestCompressorMethod(ServableMethod):
    """UC2: (best_name, preds) at an error bound, bit-equal to
    ``usecases.best_compressor``."""

    name = "best_compressor"

    def pre_process(self, svc, models: Dict[str, Any], data,
                    eps) -> MethodRequest:
        if not models:
            raise ValueError("submit_best_compressor needs trained models")
        cfg = svc._check_cfg(next(iter(models.values())).cfg)
        ndims = {m.ndim for m in models.values()}
        x = np.asarray(data, np.float32)
        if len(ndims) > 1 or x.ndim != next(iter(ndims)):
            raise ValueError(
                f"submit_best_compressor: models trained on "
                f"{sorted(ndims)}-D data must all match the request rank, "
                f"got {x.shape}")
        item = Item((slice_digest(x), cfg), x, (_f32(eps),))
        return MethodRequest(
            self, [item], Future(),
            {"models": models, "data": data, "eps": eps},
            time.perf_counter())

    def post_process(self, req, rows_for):
        feats = rows_for(req.items[0])                      # (1, 2)
        return UC.best_compressor(
            req.payload["models"], req.payload["data"],
            req.payload["eps"], feats=feats)


class AdviseMethod(ServableMethod):
    """Compression-advisor chunk: a (k, ...) row stack + per-compressor
    ``EbGridModel``s -> the per-row predicted-CR table over the shared
    eb grid, ``{"compressors", "ebs", "cr": (k, n_comp, e)}``.

    This is the ``launch.advise`` workload as a servable method: the
    advisor streams a dataset variable chunk by chunk and submits each
    chunk here, so advisor featurization rides the SAME coalesced sweep
    launches (and cross-request feature cache) as every other method --
    one launch per batch window covers every compressor, because the
    features are compressor-independent.  Per-variable aggregation
    across chunks (CR curves, per-target recommendations) stays with the
    caller; :meth:`cr_table` is the shared feats->CR kernel so the
    service path and the direct ``core.stream`` path cannot drift.
    """

    name = "advise"

    @staticmethod
    def check_models(models: Dict[str, Any]) -> Tuple[np.ndarray, int]:
        """Validate an advisor model set: non-empty, one shared eb grid,
        one shared training rank.  Returns (grid ebs, stack ndim)."""
        if not models:
            raise ValueError("advise needs at least one trained EbGridModel")
        grids = {tuple(np.asarray(m.ebs, np.float64).tolist())
                 for m in models.values()}
        if len(grids) > 1:
            raise ValueError(
                "advise models must share one eb grid (features are "
                f"shared per grid eb); got {len(grids)} distinct grids")
        ndims = {m.ndim for m in models.values()}
        if len(ndims) > 1:
            raise ValueError(
                f"advise models mix training ndims {sorted(ndims)}")
        return np.asarray(next(iter(models.values())).ebs,
                          np.float64), ndims.pop() + 1

    @staticmethod
    def cr_table(models: Dict[str, Any], feats: np.ndarray) -> np.ndarray:
        """(k, e, 2) feature rows -> (k, n_comp, e) predicted CRs, NaN/
        inf clamped exactly like ``EbGridModel.predict``."""
        feats = np.asarray(feats)
        k, e = feats.shape[0], feats.shape[1]
        cr = np.empty((k, len(models), e), np.float64)
        for ci, gm in enumerate(models.values()):
            for ei in range(e):
                preds = predict_fast(gm.models[ei].model, feats[:, ei, :])
                cr[:, ci, ei] = [UC._clamp_cr(v) for v in np.asarray(preds)]
        return cr

    def pre_process(self, svc, models: Dict[str, Any],
                    stack) -> MethodRequest:
        ebs, stack_ndim = self.check_models(models)
        cfg = svc._check_cfg(next(iter(models.values())).cfg)
        arr = np.asarray(stack, np.float32)
        if arr.ndim != stack_ndim:
            raise ValueError(
                f"submit_advise: models trained on {stack_ndim - 1}-D "
                f"data expect a rank-{stack_ndim} chunk, got {arr.shape}")
        eps_keys = tuple(_f32(e) for e in ebs)
        items = [Item((slice_digest(s), cfg), s, eps_keys) for s in arr]
        return MethodRequest(self, items, Future(),
                             {"models": dict(models), "ebs": ebs},
                             time.perf_counter())

    def post_process(self, req, rows_for):
        feats = np.stack([rows_for(it) for it in req.items])    # (k, e, 2)
        models = req.payload["models"]
        return {"compressors": tuple(models), "ebs": req.payload["ebs"],
                "cr": self.cr_table(models, feats)}


class KVGateMethod(ServableMethod):
    """KV-cache compression gate: a list of array leaves -> (k,) f32
    predicted int8 CRs, one per leaf, matching the serving engine's
    in-graph ``predicted_cr_int8`` size model.

    Leaves are flattened (CR is flatten-invariant) and digested like any
    other row, so identical KV blocks dedup within a batch and repeats
    can ride the cross-request cache under the standard admission
    policy.  There is no error bound; rows key on the sentinel eps 0.0.
    """

    name = "kv_gate"
    batch_buckets = (1, 2, 4, 8, 16, 32, 64, 128, 256)
    EPS_KEY = 0.0

    def __init__(self, launcher: Optional[Int8CRLauncher] = None,
                 batch_buckets=None):
        super().__init__(launcher if launcher is not None
                         else Int8CRLauncher(), batch_buckets)

    def pre_process(self, svc, leaves) -> MethodRequest:
        leaves = list(leaves)
        if not leaves:
            raise ValueError("submit_kv_gate needs at least one leaf")
        cfg_key = self.launcher.cfg_key
        items = []
        for leaf in leaves:
            arr = np.ascontiguousarray(
                np.asarray(leaf, np.float32).reshape(-1))
            if arr.size == 0:
                raise ValueError("submit_kv_gate: empty leaf")
            items.append(Item((slice_digest(arr), cfg_key), arr,
                              (self.EPS_KEY,)))
        return MethodRequest(self, items, Future(), {},
                             time.perf_counter())

    def post_process(self, req, rows_for):
        return np.asarray([rows_for(it)[0, 0] for it in req.items],
                          np.float32)

    def warmup_spec(self, scfg) -> WarmupSpec:
        return WarmupSpec(shapes=((256,),), grid_sizes=(1,),
                          row_buckets=(1, 2))


class QualityLauncher(Launcher):
    """The fused quality sweep (``mode="quality"``): (k, m, n) /
    (k, d, m, n) stack x (e,) eps vector -> (k, e, 2) [PSNR, NRMSE] rows
    of the quantization proxy, bit-equal to
    ``core.predictors.quality_sweep``.

    Quality rows are row-independent and per-eps-independent (PSNR/NRMSE
    of one slice at one eb reads nothing else), so the coalescing
    contract holds unchanged.  The wire config is the ``("quality",
    PredictorConfig)`` pair from the item keys -- a distinct key space
    from the feature sweep's bare config, so quality rows never collide
    with feature rows in the cross-request cache.
    """

    name = "quality"
    row_width = 2

    def launch(self, stack, epss, cfg, k_pad, mesh):
        return DS.sweep_padded(stack, epss, cfg[1], k_pad=k_pad, mesh=mesh,
                               donate=True, mode="quality")

    def follower_cfg(self, scfg):
        return ("quality", scfg.pcfg)


class QualityMethod(ServableMethod):
    """(k, m, n) / (k, d, m, n) stack x (e,) ebs -> (k, e, 2) [PSNR dB,
    NRMSE] rows, bit-equal to ``quality_sweep(slices, epss)``."""

    name = "quality"

    def __init__(self, launcher: Optional[QualityLauncher] = None,
                 batch_buckets=None):
        super().__init__(launcher if launcher is not None
                         else QualityLauncher(), batch_buckets)

    def pre_process(self, svc, slices, epss, cfg=None) -> MethodRequest:
        cfg = svc._check_cfg(cfg if cfg is not None else svc.scfg.pcfg)
        arr = np.asarray(slices, np.float32)
        if arr.ndim not in (3, 4):
            raise ValueError(
                f"submit_quality expects (k, m, n) or (k, d, m, n), "
                f"got {arr.shape}")
        eps_keys = tuple(_f32(e) for e in np.asarray(epss).reshape(-1))
        if not eps_keys:
            raise ValueError("submit_quality needs at least one eb")
        items = [Item((slice_digest(s), ("quality", cfg)), s, eps_keys)
                 for s in arr]
        return MethodRequest(self, items, Future(),
                             {"eps_keys": eps_keys}, time.perf_counter())

    def post_process(self, req, rows_for):
        return np.stack([rows_for(it) for it in req.items])


class FindSettingMethod(ServableMethod):
    """UC3: cheapest (compressor, eb) meeting a PSNR floor AND a CR
    floor, bit-equal to ``usecases.find_setting`` -- the grid
    featurization rides the shared sweep launch / cross-request cache
    (quality is PREDICTED from the same feature rows via each model's
    :class:`~repro.core.usecases.QualityTable`, so UC3 costs zero extra
    launches over UC1)."""

    name = "find_setting"

    def pre_process(self, svc, models: Dict[str, Any], data,
                    cr_floor: float, psnr_floor: float,
                    tol: float = 1e-3, max_iters: int = 48) -> MethodRequest:
        if not models:
            raise ValueError("submit_find_setting needs trained models")
        missing = sorted(n for n, m in models.items() if m.quality is None)
        if missing:
            raise ValueError(
                f"submit_find_setting needs a quality table on every "
                f"model; missing on {missing} (retrain with "
                f"EbGridModel.train)")
        cfgs = {m.cfg for m in models.values()}
        if len(cfgs) > 1:
            raise ValueError(
                "submit_find_setting models mix predictor configs; "
                "features are shared across models, so all must use one "
                "config")
        cfg = svc._check_cfg(next(iter(cfgs)))
        ndims = {m.ndim for m in models.values()}
        x = np.asarray(data, np.float32)
        if len(ndims) > 1 or x.ndim != next(iter(ndims)):
            raise ValueError(
                f"submit_find_setting: models trained on "
                f"{sorted(ndims)}-D data must all match the request rank, "
                f"got {x.shape}")
        # one item over the sorted UNION of every model's grid ebs: one
        # coalesced featurization covers every compressor's frontier
        union = sorted({_f32(e) for m in models.values()
                        for e in np.asarray(m.ebs)})
        item = Item((slice_digest(x), cfg), x, tuple(union))
        return MethodRequest(
            self, [item], Future(),
            {"models": dict(models), "data": data, "union": union,
             "cr_floor": cr_floor, "psnr_floor": psnr_floor,
             "tol": tol, "max_iters": max_iters},
            time.perf_counter())

    def post_process(self, req, rows_for):
        models = req.payload["models"]
        union = req.payload["union"]
        feats = rows_for(req.items[0])                     # (len(union), 2)
        cfg = next(iter(models.values())).cfg
        feat_cache = P.get_engine(cfg).cached(
            req.payload["data"], features=feats,
            epss=np.asarray(union, np.float64))
        return UC.find_setting(
            models, req.payload["data"],
            cr_floor=req.payload["cr_floor"],
            psnr_floor=req.payload["psnr_floor"],
            tol=req.payload["tol"], max_iters=req.payload["max_iters"],
            feat_cache=feat_cache)
