"""Batched serving engine: prefill + decode with KV-cache compression gate.

The paper integration on the serving side: decode-time KV blocks are scored
with the in-graph q-ent size model; blocks whose predicted CR clears the
threshold are stored int8-quantized (quantize-dequantize in the cache,
metering the saved bytes).  This is the runtime analogue of UC2: decide
*whether and how* to compress without trial-compressing.

The gate CRs come from one of two places: the engine's private
``_gate_crs`` jit (default), or -- when constructed with
``sweep_service=`` -- the shared :class:`repro.serve.sweep_service
.SweepService` via its registered ``kv_gate`` method, so concurrent
engines' gate scoring coalesces into the service's batched launches and
repeats ride its cross-request cache.  Either way the gated leaves are
re-written by ONE fused quantize-dequantize jit (``_qdq``) -- a single
dispatch and a single host sync for the whole cache, same style as
``_gate_crs`` -- with the saved-byte metering computed host-side from the
block geometry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train.grad_compress import (BLOCK, quantize_int8, dequantize_int8,
                                       predicted_cr_int8)


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    kv_compress: bool = False
    kv_gate_ratio: float = 2.5


class Engine:
    def __init__(self, cfg: ModelConfig, params,
                 scfg: Optional[ServeConfig] = None, *, sweep_service=None):
        # None sentinel: a dataclass default instance would be shared (and
        # mutated) across every Engine constructed without a config
        scfg = scfg if scfg is not None else ServeConfig()
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self._svc = sweep_service
        self._prefill = jax.jit(
            lambda p, batch: M.prefill(p, batch, cfg, scfg.max_len))
        self._decode = jax.jit(
            lambda p, cache, tok, pos: M.decode_step(p, cache, tok, pos, cfg))
        # all per-leaf gate CRs in ONE device computation, synced once
        self._gate_crs = jax.jit(lambda leaves: jnp.stack(
            [predicted_cr_int8(x.astype(jnp.float32)) for x in leaves]))
        # quantize-dequantize of ALL gated leaves fused into one jit: one
        # dispatch + one sync per cache rewrite instead of 2 per leaf
        self._qdq = jax.jit(lambda leaves: tuple(
            dequantize_int8(*quantize_int8(x.astype(jnp.float32)),
                            x.shape, x.dtype)
            for x in leaves))
        self.kv_saved_bytes = 0
        self.kv_total_bytes = 0

    def _predict_crs(self, leaves: List[Any]) -> np.ndarray:
        """Predicted int8 CR per leaf: through the shared sweep service's
        ``kv_gate`` method when one was attached, else the private jit."""
        if self._svc is not None:
            return np.asarray(self._svc.submit_kv_gate(leaves).result())
        return np.asarray(self._gate_crs(tuple(leaves)))

    def _maybe_compress_cache(self, cache):
        """Quantize-dequantize K/V leaves whose predicted CR clears the gate."""
        if not self.scfg.kv_compress:
            return cache

        leaves, tdef = jax.tree.flatten(cache)
        cand = [i for i, x in enumerate(leaves)
                if x.dtype in (jnp.bfloat16, jnp.float32) and x.ndim >= 4]
        if not cand:
            return cache
        crs = self._predict_crs([leaves[i] for i in cand])
        gated = []
        for cr, i in zip(crs, cand):
            x = leaves[i]
            self.kv_total_bytes += x.size * x.dtype.itemsize
            if float(cr) >= self.scfg.kv_gate_ratio:
                # quantize_int8 pads to BLOCK-sized blocks: nb blocks of
                # int8 codes plus one f32 scale each, metered host-side
                nb = -(-x.size // BLOCK)
                self.kv_saved_bytes += int(
                    x.size * x.dtype.itemsize - (nb * BLOCK + nb * 4))
                gated.append(i)
        if gated:
            rewritten = self._qdq(tuple(leaves[i] for i in gated))
            for i, leaf in zip(gated, rewritten):
                leaves[i] = leaf
        return jax.tree.unflatten(tdef, leaves)

    def generate(self, batch: Dict[str, jnp.ndarray], steps: int,
                 greedy: bool = True) -> jnp.ndarray:
        """Prefill then decode ``steps`` tokens; returns (B, steps) ids."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        logits, cache = self._prefill(self.params, batch)
        cache = self._maybe_compress_cache(cache)
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for i in range(steps):
            out.append(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(s + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return jnp.stack(out, axis=1)
