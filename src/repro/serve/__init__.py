"""Serving layer: the token engine and the servable-method platform.

``repro.serve.method`` / ``repro.serve.registry``
    The saxml-style workload layer: a :class:`ServableMethod` owns
    host-side ``pre_process`` (validation + digesting, caller thread), a
    shared device ``Launcher``, host-side ``post_process`` (completion,
    post-processing pool), per-method sorted batch-size buckets, and a
    dummy-data warmup spec.  The default registry serves four methods --
    ``featurize``, ``find_eb`` (UC1), ``best_compressor`` (UC2) over one
    shared sweep launcher, plus ``kv_gate`` (the engine's int8-CR gate)
    -- and a new prediction workload is a registry entry, not a service
    change.

``repro.serve.engine``
    Batched prefill/decode engine with the UC2-style KV-cache compression
    gate (predicted CR decides which KV blocks are stored int8); with
    ``sweep_service=`` its gate scoring rides the service's coalesced
    ``kv_gate`` launches.

Sweep service (``repro.serve.sweep_service``)
    The method-agnostic batching core under every registered method.
    One dispatch per request is the naive serving story; the service
    instead coalesces concurrent requests into single batched launches on
    a persistent mesh:

    * a micro-batching queue (max batch size + load-adaptive wait window)
      stacks pending requests' rows along the launch batch axis and
      issues ONE launch per (launcher, shape, config) group, scattering
      the result rows back to per-request futures on a post-processing
      pool off the device thread (``max_live_batches`` admission
      control);
    * a cross-request feature cache (content hash of row bytes + launch
      config -> per-eps feature rows, LRU with a byte budget) lets
      repeated UC1 bisections, UC2 rankings, and KV-gate scores over hot
      fields skip launching entirely;
    * launches are padded to the contributing methods' sorted batch
      buckets so a few persistent jitted executables serve every traffic
      mix without recompiles (``warmup()`` precompiles every registered
      method's declared coverage).

    Coalesced results are bit-identical to per-request dispatch because
    every launcher is row- and per-eps-independent by contract (asserted
    by ``tests/test_sweep_service.py`` / ``tests/test_methods.py`` and
    gated by ``benchmarks/bench_serve.py``).

    On a process-spanning mesh (``repro.launch.mesh.dist_init`` +
    ``make_sweep_mesh``) the service runs leader/follower: the mesh's
    first process owns the queue and the public API, every other
    process joins the collective launches via ``serve()`` -- the launch
    header carries the launcher's registry wire id, so every method
    crosses the process boundary through the same protocol
    (bit-exactness gated by ``benchmarks/bench_multihost.py``; lifecycle
    and sizing guidance in ``docs/serving.md``).
"""
