"""Serving layer: the token engine and the paper's sweep service.

``repro.serve.engine``
    Batched prefill/decode engine with the UC2-style KV-cache compression
    gate (predicted CR decides which KV blocks are stored int8).

Sweep service (``repro.serve.sweep_service``)
    The production entry point for concurrent featurize/UC1/UC2 traffic.
    One dispatch per request is the naive serving story; the service
    instead coalesces concurrent requests into single batched launches on
    a persistent mesh:

    * a micro-batching queue (max batch size + max wait deadline) stacks
      pending requests' slices along the sweep's slice axis and issues ONE
      ``dist.sweep`` launch with ``gather=False``, scattering the
      (k, e, 2) result rows back to per-request futures;
    * a cross-request feature cache (content hash of slice bytes + engine
      config -> per-eb feature rows, LRU with a byte budget) lets repeated
      UC1 bisections and UC2 rankings over hot fields skip featurization
      entirely;
    * launches are padded to a small set of bucketed batch shapes so a few
      persistent jitted executables serve every traffic mix without
      recompiles.

    Coalesced results are bit-identical to per-request dispatch because
    the sweep body is row-independent (asserted by
    ``tests/test_sweep_service.py`` and gated by
    ``benchmarks/bench_serve.py``).

    On a process-spanning mesh (``repro.launch.mesh.dist_init`` +
    ``make_sweep_mesh``) the service runs leader/follower: the mesh's
    first process owns the queue and the public API, every other
    process joins the collective launches via ``serve()``
    (bit-exactness across the process boundary gated by
    ``benchmarks/bench_multihost.py``; lifecycle and sizing guidance in
    ``docs/serving.md``).
"""
