"""Method-agnostic serving core: micro-batching + cache + launch fabric.

This module is the batching half of the servable-method platform.  The
workload half lives in ``repro.serve.method`` (ServableMethod: host-side
``pre_process``, a shared device ``Launcher``, host-side
``post_process``, per-method sorted batch buckets, dummy-data warmup
specs) and ``repro.serve.registry`` (name -> method).  ``SweepService``
itself knows nothing about featurize/UC1/UC2/KV-gating: its queue,
cache, launch, and leader/follower paths handle only
:class:`~repro.serve.method.MethodRequest` items and launcher wire ids,
so a new prediction workload is a registry entry, not a service change.

The serving gap this closes: ``find_error_bound_for_cr`` (UC1) and
``best_compressor`` (UC2) each pay one full featurization dispatch per
request, and under a mesh each request triggers its own ``shard_map``
launch.  The paper's speedups assume featurization cost is *amortized*
across queries, so the service batches the amortization in three layers:

1. **Micro-batching queue** -- concurrent ``submit*`` calls enqueue
   pre-processed requests; a single worker thread flushes when the
   pending row count reaches ``max_batch_slices`` or the oldest request
   has waited the current micro-batch window.  Every flushed batch
   becomes ONE launch per (launcher, trailing shape, launch config)
   group -- methods sharing a launcher coalesce across method
   boundaries -- and the result rows are scattered to the per-request
   futures by the post-processing pool, off the device thread.

2. **Cross-request feature cache** -- content hash of the row's f32
   bytes + launch config -> per-eps-key feature rows, LRU with a byte
   budget.  A repeated UC1 bisection or UC2 ranking over a hot field is
   served from the cache with ZERO launches.  Within one batch, rows for
   the same digest are deduplicated before launch and their eps grids
   are unioned into one eps vector (rows are per-eps independent, so the
   union launch is bit-equal to separate ones).

3. **Persistent bucketed executables** -- batches are padded to the
   contributing methods' sorted batch-size buckets (power-of-two by
   default) and a small set of eps-vector lengths, so the jitted
   executables (keyed by launcher + mesh + padded batch shape) are
   compiled once per bucket and reused for every traffic mix.
   ``warmup()`` with no arguments precompiles every registered method's
   ``warmup_spec`` buckets.

Results are bit-identical to per-request serial dispatch: launchers are
row-independent and per-eps-independent by contract, UC1 bisection runs
the exact ``usecases`` code on a seeded ``SliceCache``, and UC2 ranking
feeds the shared rows through the exact ``best_compressor`` model
evaluation.

Adaptive micro-batch window
---------------------------
The flush deadline is load-aware (``adapt_window``, on by default): a
flush that found the queue saturated (the row cap tripped, or rows were
still pending afterwards) HALVES the window toward ``min_wait_ms`` --
under sustained depth there is no point waiting for companions that are
already queued -- while an idle deadline flush grows it back toward the
configured ``max_wait_ms`` ceiling.  ``max_wait_ms`` is therefore the
ceiling a lone request can ever wait, so latency-sensitive idle traffic
is unaffected; only saturated traffic trades the wait for immediate
launches.  ``stats()["window_ms"]`` exposes the live window.

Admission control
-----------------
Two bounds keep an overloaded service from queueing unboundedly:

* ``max_queue_rows`` -- ``submit*`` raises :class:`RetryAfter` instead
  of enqueueing when the fabric falls behind.  The backoff hint is
  load-proportional: pending rows divided by the recent drain rate
  (EMA of rows/s over completed batches), floored at the current
  micro-batch window, so clients under 10x load back off realistically
  instead of hammering at a fixed interval.
* ``max_live_batches`` -- at most this many flushed batches may be in
  flight (launched but not yet post-processed).  Pre-processing runs on
  the caller's thread at submit time and post-processing on a small
  pool, so the device thread does nothing but launch; the live-batch
  bound keeps that pipeline from racing arbitrarily far ahead of the
  host-side completion work.

Cache admission: one-shot cold fields are NOT cached.  A digest's rows
are admitted only once its content hash has been sighted by
``cache_admit_after`` distinct requests (default 2) -- concurrent
requests for the same digest inside one batch count individually, so a
hot field entering with simultaneous UC1+UC2 traffic is admitted on its
very first launch, while a scan over thousands of distinct cold slices
never evicts the working set.

Multi-process leader/follower mode
----------------------------------
Constructed on a PROCESS-SPANNING mesh (``repro.launch.mesh.dist_init``
+ ``make_sweep_mesh``), the service splits roles: the mesh's first
process is the **leader** -- it owns the micro-batching queue, the
cache, and the public ``submit*`` API -- and every other process is a
**follower** that blocks in :meth:`serve` joining each collective
launch.  Per launch the leader broadcasts a fixed-size header (batch
rows, trailing shape, eps length, ``k_pad``, launcher wire id) and then
the row stack + eps union (``multihost_utils.broadcast_one_to_all``);
both sides enter the same launcher computation, and the scatter-back
gather is the single synchronization point.  ``close()`` on the leader
drains the queue and broadcasts a shutdown header that releases the
followers.  All processes must construct the service with the same
``ServiceConfig`` AND the same method registry (launcher ids are
assigned in registration order; the engine config is not re-broadcast
per launch).

Elastic fault tolerance
-----------------------
The fabric survives follower loss.  Every process heartbeats through
the ``jax.distributed`` coordination-service KV store
(``repro.dist.fault``); every collective launch runs in a sacrificial
thread, bounded on the LEADER by ``ServiceConfig.launch_timeout_s``
(size it to cover a first launch's executable compile -- the deadline
cannot tell a slow compile from a wedged peer).  Followers carry no
own-time deadline: every fault they must react to arrives as an epoch
advance, leader heartbeat staleness, or the shutdown marker.  When a
launch faults (a gloo peer raises "connection closed", or the deadline
expires on a wedged peer) the leader attributes the fault by watching
heartbeats, SHRINKS the mesh to the surviving processes
(``fault.surviving_submesh``), bumps the fabric *epoch*, invalidates
every executable compiled for the old mesh, and relaunches the
in-flight batch -- pending futures complete bit-equal on the shrunken
mesh (launchers are row/eps independent, so the result does not depend
on which devices computed it).  Post-recovery launches move off gloo
entirely: a faulted gloo collective leaves stale pair connections that
poison every later cross-process device collective in the cohort, so
the recovered transport partitions each batch's rows across the
survivors (contiguous blocks, proportional to their device share of
the ``fault.surviving_submesh``), every process runs its block's
launcher computation locally -- unsharded, since the poisoned gloo
state breaks even process-local multi-device collectives -- and the
row blocks travel back through the coordination-service KV store, so
no device collective of any kind runs again on that fabric.
Shrunk to one process, the leader degrades to the single-process path
and keeps serving.  Followers mirror the epoch state machine: a
follower that faults rejoins the published epoch at a bounded barrier,
learns it was evicted (:class:`repro.dist.fault.FabricError` with
``kind="evicted"``), or detects leader death by heartbeat staleness
(``kind="leader_lost"``) instead of blocking forever.  Fabric-scoped
failures fail ALL pending futures with the typed ``FabricError`` and
release :meth:`serve`; request-scoped failures still fail only their
batch.

Usage::

    from repro.serve.sweep_service import SweepService, ServiceConfig
    with SweepService(mesh=my_mesh) as svc:        # or under use_mesh(...)
        f1 = svc.submit_find_eb(grid_model, slice_a, target_cr=8.0)
        f2 = svc.submit_best_compressor(models, slice_b, eps)
        f3 = svc.submit_featurize(stack, ebs)
        f4 = svc.submit_kv_gate(kv_leaves)         # = svc.submit("kv_gate", ...)
        eps, cr = f1.result()

    # multi-process: leader (process 0) runs the block above; followers:
    svc = SweepService(mesh=my_mesh)
    svc.serve()                                    # until leader close()
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import json
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import predictors as P
from repro.dist import fault as F
from repro.dist import faultinject as FI
from repro.dist import sweep as DS
from repro.serve.method import (Item, Launcher, MethodRequest, ServableMethod,
                                _eps_bucket, _f32, _row_bucket, slice_digest)
from repro.serve.registry import MethodRegistry, default_registry

try:                                  # runtime/collective failure type
    from jax._src.lib import xla_client as _xc
    _XLA_ERRORS: tuple = (_xc.XlaRuntimeError,)
except Exception:                     # pragma: no cover - very old jax
    _XLA_ERRORS = ()


# multi-process services in one program take KV-namespace numbers from a
# process-local counter: lockstep construction order is already required
# by the collective fabric, so the counters agree across processes and a
# second service never reads the first one's shutdown/epoch keys
_FABRIC_COUNTER = itertools.count()

_LAT_RING = 512                       # per-method latency samples kept


class RetryAfter(RuntimeError):
    """Backpressure rejection: the service's bounded request queue is
    full (``ServiceConfig.max_queue_rows``).  ``retry_after_s`` is the
    service's load-proportional backoff hint (pending rows over the
    recent drain rate, floored at the micro-batch window);
    ``pending_rows`` is the queue depth that triggered the rejection.
    Raised from ``submit*`` -- nothing was enqueued."""

    def __init__(self, message: str, *, retry_after_s: float,
                 pending_rows: int):
        self.retry_after_s = float(retry_after_s)
        self.pending_rows = int(pending_rows)
        super().__init__(
            f"{message} ({pending_rows} rows pending; retry after "
            f"~{self.retry_after_s:.3f}s)")


class _Boxed:
    """Run ``fn`` on a sacrificial daemon thread so a hung collective
    can be *abandoned*: gloo/XLA collectives are not interruptible, so
    the bounded waits in the fabric park them here and walk away when
    the deadline expires (the thread dies with the process)."""

    def __init__(self, fn, name: str):
        self.value = None
        self.error: Optional[BaseException] = None
        self.done = threading.Event()

        def run():
            try:
                self.value = fn()
            except BaseException as exc:          # noqa: BLE001
                self.error = exc
            finally:
                self.done.set()

        self.thread = threading.Thread(target=run, name=name, daemon=True)
        self.thread.start()

    def wait(self, timeout: float) -> bool:
        return self.done.wait(timeout)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    max_batch_slices: int = 64       # flush when this many rows are pending
    max_wait_ms: float = 2.0         # micro-batch window CEILING (idle value)
    min_wait_ms: float = 0.0         # adaptive window floor under load
    adapt_window: bool = True        # load-aware window (see module docs)
    max_live_batches: int = 2        # launched-but-not-post-processed bound
    post_workers: int = 2            # host-side post-processing pool size
    cache_bytes: int = 4 << 20       # cross-request feature-cache budget
    max_eps_per_launch: int = 32     # chunk wider eps unions across launches
    cache_admit_after: int = 2       # sightings before a digest is cached
    launch_timeout_s: float = 60.0   # leader's bound per collective launch
    #   (must cover a first launch's compile; followers have no own bound)
    heartbeat_s: float = 0.5         # fabric liveness publish interval
    max_queue_rows: int = 0          # 0 = unbounded; else RetryAfter beyond
    pcfg: P.PredictorConfig = dataclasses.field(
        default_factory=P.PredictorConfig)


class FeatureCache:
    """Cross-request feature cache: (row digest, launch config) ->
    {f32 eps key -> feature row}, LRU over digests with a byte budget.
    Rows are small f32 vectors whose width is the launcher's
    ``row_width`` (2 for the sweep, 1 for the int8-CR gate); accounting
    uses each row's actual ``nbytes``.

    Admission policy: a digest's rows are stored only once it has been
    *sighted* (``record_sighting``, one count per request touching the
    digest) at least ``admit_after`` times, so one-shot cold fields pass
    through without polluting the LRU ring.  ``admit_after=1`` (the
    class default, kept for direct users) admits on first touch; the
    sweep service passes ``ServiceConfig.cache_admit_after`` (default
    2).  The sighting ring is a bounded FIFO of bare digests -- a few
    bytes per cold field, never row data.
    """

    ROW_BYTES = 2 * 4                # sweep-row estimate (sizing docs/tests)
    ENTRY_OVERHEAD = 128             # digest + dict bookkeeping estimate

    def __init__(self, max_bytes: int, admit_after: int = 1,
                 seen_capacity: int = 65536):
        self.max_bytes = int(max_bytes)
        self.admit_after = max(1, int(admit_after))
        self.seen_capacity = int(seen_capacity)
        self._entries: "collections.OrderedDict[tuple, dict]" = \
            collections.OrderedDict()
        self._seen: "collections.OrderedDict[tuple, int]" = \
            collections.OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.admissions_denied = 0
        self._lock = threading.Lock()

    def record_sighting(self, key: tuple, n: int = 1) -> int:
        """Count a request touching ``key``; returns the running total.
        Admitted digests stop counting (their entry is the signal)."""
        with self._lock:
            if key in self._entries:
                return self.admit_after
            seen = self._seen.get(key, 0) + n
            self._seen[key] = seen
            self._seen.move_to_end(key)
            while len(self._seen) > self.seen_capacity:
                self._seen.popitem(last=False)
            return seen

    def get(self, key: tuple, eps_key: float) -> Optional[np.ndarray]:
        with self._lock:
            ent = self._entries.get(key)
            if ent is None or eps_key not in ent:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return ent[eps_key]

    def put(self, key: tuple, eps_key: float, row: np.ndarray) -> bool:
        """Store one (digest, eps) row; returns False when the admission
        policy rejects the (cold, under-sighted) digest."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                if self.admit_after > 1 and \
                        self._seen.get(key, 0) < self.admit_after:
                    self.admissions_denied += 1
                    return False
                self._seen.pop(key, None)
                ent = self._entries[key] = {}
                self._bytes += self.ENTRY_OVERHEAD
            old = ent.get(eps_key)
            self._bytes += row.nbytes - (0 if old is None else old.nbytes)
            ent[eps_key] = row
            self._entries.move_to_end(key)
            # never evict the slice just written: it may still be needed
            # to complete the in-flight batch
            while self._bytes > self.max_bytes and len(self._entries) > 1:
                _, dropped = self._entries.popitem(last=False)
                self._bytes -= self.ENTRY_OVERHEAD + sum(
                    r.nbytes for r in dropped.values())
                self.evictions += 1
            return True

    @property
    def nbytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "entries": len(self),
                    "bytes": self._bytes,
                    "admissions_denied": self.admissions_denied,
                    "pending_sightings": len(self._seen)}


class SweepService:
    """Coalesces concurrent requests of every registered servable method
    into single batched launches on a persistent mesh (module docstring
    has the full story).

    The mesh is captured at construction (explicit ``mesh=`` argument or
    the thread's active ``dist.sharding.use_mesh``) and reused for every
    launch -- the worker thread never depends on the caller's thread-local
    mesh context.  After elastic recovery the captured mesh is replaced
    by the survivor submesh (``self.mesh`` always names the CURRENT
    fabric; ``self._mesh0`` keeps the construction-time one).
    """

    HDR_LEN = 9                 # [op, k, k_pad, rank, t0, t1, t2, e_pad, gid]
    OP_SHUTDOWN, OP_LAUNCH = 0, 1

    def __init__(self, scfg: Optional[ServiceConfig] = None, *, mesh=None,
                 registry: Optional[MethodRegistry] = None):
        self.scfg = scfg if scfg is not None else ServiceConfig()
        self.registry = registry if registry is not None else \
            default_registry()
        self.mesh = DS.active_sweep_mesh(mesh)
        self.cache = FeatureCache(self.scfg.cache_bytes,
                                  admit_after=self.scfg.cache_admit_after)
        self._queue: "collections.deque[MethodRequest]" = collections.deque()
        self._cond = threading.Condition()
        self._stop = False
        self._closed = False
        self._launches = 0
        self._rows_launched = 0
        self._pad_rows = 0
        self._batches = 0
        self._requests = collections.Counter()
        self._executables: set = set()   # (mesh, launcher, k_pad, shape, ...)
        # pinned host staging buffers, keyed by padded stack shape: the
        # worker packs each coalesced batch into a re-used buffer instead
        # of np.stack-allocating per launch, so steady-state serving of a
        # warm bucket allocates nothing host-side per batch (the launch
        # donates the staged upload device-side; see SweepLauncher)
        self._staging: Dict[Tuple[int, ...], np.ndarray] = {}
        self._fabric_error: Optional[BaseException] = None
        # adaptive micro-batch window (module docstring): starts at the
        # ceiling, halves on loaded flushes, grows back when idle
        self._window_ms = float(self.scfg.max_wait_ms)
        self._window_shrinks = 0
        self._window_grows = 0
        # admission-control + host-side completion pipeline
        self._live = threading.Semaphore(max(1, self.scfg.max_live_batches))
        self._live_now = 0
        self._post = ThreadPoolExecutor(
            max_workers=max(1, self.scfg.post_workers),
            thread_name_prefix="sweep-post")
        # per-method latency/throughput counters (stats()["methods"])
        self._mlock = threading.Lock()
        self._mstats: Dict[str, dict] = {}
        # leader/follower roles on a process-spanning mesh: the mesh's
        # first process owns the queue, everyone else joins collectives
        self._multiproc = DS.mesh_spans_processes(self.mesh)
        self._mesh0 = self.mesh
        self._epoch = 0              # bumps on every elastic recovery
        self._seq = 0                # post-recovery KV launch sequence
        self._transport = "gloo"     # "gloo" (epoch 0) | "kv" (recovered)
        self._recoveries = 0
        self._last_recovery_s = 0.0
        self._rejected = 0
        self._ema_batch_s = 0.0      # drain-time estimate for RetryAfter
        self._ema_rows_per_s = 0.0   # drain-rate estimate for RetryAfter
        if self._multiproc:
            import jax
            self._me = jax.process_index()
            self._procs = list(DS.mesh_processes(self.mesh))
            self._leader_pid = self._procs[0]
            self.role = ("leader" if self._me == self._leader_pid
                         else "follower")
            self._kv = F.kv_client()
            self._kvp = f"reprosvc/{next(_FABRIC_COUNTER)}"
        else:
            self._me, self._procs, self._leader_pid = 0, [0], 0
            self.role = "leader"
            self._kv, self._kvp = None, "reprosvc/-"
        self._procs0 = list(self._procs)
        self._local_mesh = None      # per-process compute mesh post-recovery
        self._proc_devs: dict = {}   # pid -> device share (set at recovery)
        self._hb: Optional[F.Heartbeat] = None
        self._monitor: Optional[F.PeerMonitor] = None
        if self._multiproc and self._kv is not None:
            self._hb = F.Heartbeat(self._kv, self._kvp, self._me,
                                   interval_s=self.scfg.heartbeat_s).start()
            self._monitor = F.PeerMonitor(self._kv, self._kvp)
            self._monitor.track(self._procs)
        # a follower must not declare leader_lost before the leader's
        # first beat could plausibly arrive (its process may still be
        # training models before constructing the service)
        self._first_beat_deadline = time.monotonic() + max(
            self.scfg.launch_timeout_s, 2 * self._stale_after)
        # serializes collective launches on the leader (worker batches vs
        # main-thread warmup/close): followers see one header stream
        self._launch_lock = threading.Lock()
        target = self._loop if self.role == "leader" else self._follower_loop
        self._worker = threading.Thread(
            target=target, name=f"sweep-service-{self.role}", daemon=True)
        self._worker.start()

    # ------------------------------------------------------------------
    # fabric timing policy
    # ------------------------------------------------------------------

    @property
    def _stale_after(self) -> float:
        """Heartbeat silence that marks a peer dead/wedged: a few missed
        beats, but never longer than one launch deadline."""
        return max(1.0, min(self.scfg.launch_timeout_s,
                            6 * self.scfg.heartbeat_s))

    @property
    def _barrier_timeout(self) -> float:
        return min(self.scfg.launch_timeout_s,
                   max(2.0, 2 * self._stale_after))

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def _check_cfg(self, cfg: P.PredictorConfig) -> P.PredictorConfig:
        """Leader/follower launches carry no per-request engine config
        (the header is fixed-size and followers compiled against the
        service config), so multi-process services accept only it."""
        if self._multiproc and cfg != self.scfg.pcfg:
            raise ValueError(
                "multi-process SweepService serves only its configured "
                "engine config (ServiceConfig.pcfg); per-request configs "
                "are a single-process feature")
        return cfg

    def submit(self, method: str, *args, **kwargs) -> Future:
        """Submit to any registered method by name.  The method's
        ``pre_process`` (validation + digesting) runs on the CALLER's
        thread; the returned Future resolves to the method's
        ``post_process`` result."""
        req = self.registry.get(method).pre_process(self, *args, **kwargs)
        return self._submit(req)

    # built-in method conveniences -------------------------------------

    def submit_featurize(self, slices, epss,
                         cfg: Optional[P.PredictorConfig] = None) -> Future:
        """(k, m, n) slice stack or (k, d, m, n) volume stack x (e,) ebs
        -> Future[(k, e, 2) np.ndarray], bit-equal to
        ``features_sweep(slices, epss)``.  Batching/digests are keyed by
        the trailing shape, so volume requests coalesce with each other
        exactly like slice requests do."""
        return self.submit("featurize", slices, epss, cfg)

    def submit_find_eb(self, grid_model, data, target_cr: float,
                       tol: float = 0.02, max_iters: int = 32) -> Future:
        """UC1 through the service: Future[(eps, predicted_cr)], bit-equal
        to ``usecases.find_error_bound_for_cr``.  The grid featurization
        comes from the shared launch / cross-request cache."""
        return self.submit("find_eb", grid_model, data, target_cr,
                           tol=tol, max_iters=max_iters)

    def submit_best_compressor(self, models: Dict[str, object], data,
                               eps: float) -> Future:
        """UC2 through the service: Future[(best_name, preds)], bit-equal
        to ``usecases.best_compressor``."""
        return self.submit("best_compressor", models, data, eps)

    def submit_kv_gate(self, leaves) -> Future:
        """KV-cache gate: list of array leaves -> Future[(k,) f32
        predicted int8 CRs], matching ``predicted_cr_int8`` per leaf."""
        return self.submit("kv_gate", leaves)

    def submit_advise(self, models: Dict[str, object], stack) -> Future:
        """Compression-advisor chunk (the ``launch.advise`` streaming
        workload): a (k, m, n) / (k, d, m, n) row chunk + per-compressor
        ``EbGridModel``s sharing one eb grid -> Future[{"compressors",
        "ebs", "cr": (k, n_comp, e)}] -- per-row predicted CRs for every
        (compressor, grid eb), from ONE coalesced featurization per
        batch window (features are compressor-independent)."""
        return self.submit("advise", models, stack)

    def submit_quality(self, slices, epss,
                       cfg: Optional[P.PredictorConfig] = None) -> Future:
        """Fused quality sweep through the service: (k, m, n) or
        (k, d, m, n) stack x (e,) ebs -> Future[(k, e, 2) [PSNR dB,
        NRMSE] np.ndarray], bit-equal to ``quality_sweep(slices,
        epss)``.  Quality rows coalesce on their own launcher and key
        space, so they never collide with feature rows in the
        cross-request cache."""
        return self.submit("quality", slices, epss, cfg)

    def submit_find_setting(self, models: Dict[str, object], data,
                            cr_floor: float, psnr_floor: float,
                            tol: float = 1e-3,
                            max_iters: int = 48) -> Future:
        """UC3 through the service: Future[JointSetting], bit-equal to
        ``usecases.find_setting``.  One coalesced featurization over the
        union of every model's grid ebs covers all compressors; quality
        is predicted from the same rows (zero extra launches)."""
        return self.submit("find_setting", models, data, cr_floor,
                           psnr_floor, tol=tol, max_iters=max_iters)

    # sync conveniences ------------------------------------------------

    def featurize(self, slices, epss, cfg=None) -> np.ndarray:
        return self.submit_featurize(slices, epss, cfg).result()

    def find_eb(self, grid_model, data, target_cr, **kw) -> tuple:
        return self.submit_find_eb(grid_model, data, target_cr, **kw).result()

    def best_compressor(self, models, data, eps) -> tuple:
        return self.submit_best_compressor(models, data, eps).result()

    def kv_gate(self, leaves) -> np.ndarray:
        return self.submit_kv_gate(leaves).result()

    def advise(self, models, stack) -> dict:
        return self.submit_advise(models, stack).result()

    def quality(self, slices, epss, cfg=None) -> np.ndarray:
        return self.submit_quality(slices, epss, cfg).result()

    def find_setting(self, models, data, cr_floor, psnr_floor, **kw):
        return self.submit_find_setting(models, data, cr_floor,
                                        psnr_floor, **kw).result()

    def stats(self) -> dict:
        with self._cond:
            queue_rows = sum(r.rows for r in self._queue)
            pending: collections.Counter = collections.Counter()
            for r in self._queue:
                pending[r.kind] += r.rows
        with self._mlock:
            methods = {}
            for name, st in self._mstats.items():
                lat = np.asarray(st["lat"], np.float64)
                methods[name] = {
                    "completed": st["completed"],
                    "failed": st["failed"],
                    "rows": st["rows"],
                    "pending_rows": int(pending.get(name, 0)),
                    "p50_ms": (float(np.percentile(lat, 50))
                               if lat.size else 0.0),
                    "p95_ms": (float(np.percentile(lat, 95))
                               if lat.size else 0.0),
                    "mean_ms": float(lat.mean()) if lat.size else 0.0,
                }
            live = self._live_now
        return {"role": self.role,
                "launches": self._launches,
                "rows_launched": self._rows_launched,
                "pad_rows": self._pad_rows,
                "batches": self._batches,
                "executables": len(self._executables),
                "requests": dict(self._requests),
                "methods": methods,
                "queue_rows": queue_rows,
                "window_ms": self._window_ms,
                "window_shrinks": self._window_shrinks,
                "window_grows": self._window_grows,
                "live_batches": live,
                "epoch": self._epoch,
                "transport": self._transport,
                "recoveries": self._recoveries,
                "last_recovery_s": self._last_recovery_s,
                "rejected": self._rejected,
                "procs": list(self._procs),
                "cache": self.cache.stats()}

    @property
    def launches(self) -> int:
        return self._launches

    def warmup(self, shapes: Optional[Sequence[Tuple[int, ...]]] = None,
               grid_sizes: Sequence[int] = (1,),
               row_buckets: Sequence[int] = (1,),
               cfg: Optional[P.PredictorConfig] = None) -> None:
        """Pre-compile the bucketed executables for the expected traffic
        so first requests don't pay compile latency.

        With explicit ``shapes`` (slice (m, n) / volume (d, m, n) shapes
        x eps-grid sizes x row buckets) this warms the shared SWEEP
        launcher, exactly as before the method-registry refactor.  With
        NO arguments it walks every registered method's ``warmup_spec``
        and compiles each (launcher, shape, grid size, bucket)
        combination once -- methods sharing a launcher dedup their
        overlapping specs.

        On a process-spanning mesh the leader's warmup launches ride the
        collective fabric, so followers precompile the same executables
        (followers themselves call :meth:`serve`, not ``warmup``).  A
        follower fault during warmup recovers exactly like one during
        serving: the warmup launch retries on the shrunken mesh."""
        if self.role == "follower":
            raise RuntimeError(
                "warmup runs on the leader; followers precompile by "
                "joining its collective warmup launches via serve()")
        if shapes is None:
            done: set = set()
            for m in self.registry.methods():
                spec = m.warmup_spec(self.scfg)
                # the launcher's service-bound config (what followers
                # compile against) is also the right warmup config
                wcfg = m.launcher.follower_cfg(self.scfg)
                for shape in spec.shapes:
                    for e in spec.grid_sizes:
                        for k in spec.row_buckets:
                            k_pad = self._k_pad((m,), int(k))
                            sig = self._sig(m.launcher, k_pad, tuple(shape),
                                            m.launcher.eps_bucket(int(e)),
                                            wcfg)
                            if sig in done:
                                continue
                            done.add(sig)
                            self._warm_one(m.launcher, tuple(shape), int(e),
                                           k_pad, wcfg)
            return
        cfg = self._check_cfg(cfg if cfg is not None else self.scfg.pcfg)
        sweep = self.registry.get("featurize").launcher
        for shape in shapes:
            for e in grid_sizes:
                for k in row_buckets:
                    self._warm_one(sweep, tuple(shape), int(e),
                                   _row_bucket(int(k)), cfg)

    def _warm_one(self, launcher: Launcher, shape: Tuple[int, ...],
                  e: int, k_pad: int, cfg) -> None:
        x = np.zeros((1,) + shape, np.float32)
        e_pad = launcher.eps_bucket(e)
        epss = np.full((e_pad,), launcher.warmup_eps, np.float32)
        out = self._collective_sweep(launcher, x, epss, cfg, k_pad)
        launcher.gather(out)
        self._executables.add(self._sig(launcher, k_pad, shape, e_pad, cfg))

    def serve(self) -> None:
        """Block until the service stops.

        The follower's main loop: joins collective launches until the
        leader's ``close()`` broadcasts shutdown.  On a leader this just
        waits for ``close()`` from another thread.  Raises the typed
        :class:`repro.dist.fault.FabricError` when the fabric failed
        (leader death, eviction, unrecoverable fault) instead of
        returning as if shutdown completed cleanly.
        """
        self._worker.join()
        err = self._fabric_error
        if err is not None:
            if isinstance(err, F.FabricError):
                raise err
            raise RuntimeError(
                f"sweep-service {self.role} worker died; the fabric is "
                "wedged (restart every process)") from err

    def close(self) -> None:
        """Flush pending requests and stop the worker thread.

        Leader of a multi-process service: after the queue drains, a
        shutdown header (gloo fabric) or KV shutdown marker (recovered
        fabric) releases every follower out of :meth:`serve`, then the
        leader waits -- bounded -- for the followers' goodbye markers so
        its embedded coordination service outlives their last KV reads.
        Idempotent, including after a fabric failure (no further
        collectives are attempted on a failed fabric).
        Follower: blocks until the leader shuts the fabric down.
        """
        if self.role == "follower":
            self._worker.join()
            self._post.shutdown(wait=True)
            if self._hb is not None:
                self._hb.stop()
            return
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._stop = True
            self._cond.notify_all()
        self._worker.join()
        self._post.shutdown(wait=True)   # drain host-side completions
        if len(self._procs0) > 1:
            if (self._transport == "gloo" and self._fabric_error is None
                    and len(self._procs) > 1):
                from jax.experimental import multihost_utils as MH
                with self._launch_lock:
                    box = _Boxed(
                        lambda: MH.broadcast_one_to_all(
                            np.zeros(self.HDR_LEN, np.int64)),  # OP_SHUTDOWN
                        "svc-shutdown-bcast")
                    box.wait(self.scfg.launch_timeout_s)
            if self._kv is not None:
                F.kv_set(self._kv, f"{self._kvp}/shutdown", "closed")
                self._wait_byes()
        if self._hb is not None:
            self._hb.stop()

    def __enter__(self) -> "SweepService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # worker: micro-batching loop
    # ------------------------------------------------------------------

    def _submit(self, req: MethodRequest) -> Future:
        if self.role == "follower":
            raise RuntimeError(
                "follower processes don't accept requests; submit to the "
                "leader (the mesh's first process) and call serve() here")
        with self._cond:
            if self._stop:
                err = self._fabric_error
                raise RuntimeError("SweepService is closed") from err
            limit = self.scfg.max_queue_rows
            pending = sum(r.rows for r in self._queue) if limit else 0
            # never reject into an empty queue: a single over-wide
            # request must still be servable (it flushes alone)
            if limit and pending and pending + req.rows > limit:
                self._rejected += 1
                raise RetryAfter(
                    "sweep-service queue is full",
                    retry_after_s=self._retry_after_estimate(pending),
                    pending_rows=pending)
            self._queue.append(req)
            self._requests[req.kind] += 1
            self._cond.notify_all()
        return req.future

    def _retry_after_estimate(self, pending: int) -> float:
        """Load-proportional backoff: queued rows over the recent drain
        rate, floored at the current micro-batch window (an idle service
        can't clear the queue faster than one window)."""
        window_s = self._window_ms / 1e3
        if self._ema_rows_per_s > 0:
            return max(window_s, pending / self._ema_rows_per_s)
        batches = -(-pending // max(1, self.scfg.max_batch_slices))
        return max(window_s, self._ema_batch_s * batches)

    def _loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            self._live.acquire()
            with self._mlock:
                self._live_now += 1
            t0 = time.perf_counter()
            try:
                self._process(batch)
            except F.FabricError as exc:
                # fabric-scoped: the collective launch path exhausted
                # recovery -- fail EVERYTHING and release serve()
                self._release_live()
                self._fail_fabric(exc, batch)
                return
            except Exception as exc:  # request-scoped: fail the batch only
                self._release_live()
                for req in batch:
                    if not req.future.done():
                        req.future.set_exception(exc)
                    self._note_done(req, ok=False)
            else:
                dt = time.perf_counter() - t0
                rows = sum(r.rows for r in batch)
                self._ema_batch_s = (dt if not self._ema_batch_s
                                     else 0.7 * self._ema_batch_s + 0.3 * dt)
                if dt > 0:
                    rps = rows / dt
                    self._ema_rows_per_s = (
                        rps if not self._ema_rows_per_s
                        else 0.7 * self._ema_rows_per_s + 0.3 * rps)

    def _release_live(self) -> None:
        with self._mlock:
            self._live_now -= 1
        self._live.release()

    def _note_done(self, req: MethodRequest, ok: bool = True) -> None:
        lat_ms = (time.perf_counter() - req.t_submit) * 1e3
        with self._mlock:
            st = self._mstats.setdefault(req.kind, {
                "completed": 0, "failed": 0, "rows": 0,
                "lat": collections.deque(maxlen=_LAT_RING)})
            st["completed" if ok else "failed"] += 1
            st["rows"] += req.rows
            st["lat"].append(lat_ms)

    def _fail_fabric(self, exc: BaseException,
                     batch: List[MethodRequest]) -> None:
        """Fabric-scoped failure: poison the service, fail every pending
        future (in-flight batch AND queued requests), release serve()."""
        self._fabric_error = exc
        with self._cond:
            self._stop = True
            drained = list(self._queue)
            self._queue.clear()
            self._cond.notify_all()
        for req in list(batch) + drained:
            if not req.future.done():
                req.future.set_exception(exc)
        if self._kv is not None:   # release any followers still joined
            F.kv_set(self._kv, f"{self._kvp}/shutdown", "fabric-error")

    def _next_batch(self) -> Optional[List[MethodRequest]]:
        """Block until a batch is ready: pending rows reach
        ``max_batch_slices``, or the OLDEST pending request has waited
        the current adaptive window (a single request flushes alone at
        the deadline), or the service is closing (drains what is left)."""
        with self._cond:
            while True:
                if self._queue:
                    rows = sum(r.rows for r in self._queue)
                    deadline = (self._queue[0].t_submit +
                                self._window_ms / 1e3)
                    remaining = deadline - time.perf_counter()
                    if (rows >= self.scfg.max_batch_slices or
                            remaining <= 0 or self._stop):
                        batch, total = [], 0
                        while self._queue and (
                                total < self.scfg.max_batch_slices or
                                not batch):
                            req = self._queue.popleft()
                            batch.append(req)
                            total += req.rows
                        if not self._stop:
                            self._note_flush(
                                total >= self.scfg.max_batch_slices or
                                bool(self._queue))
                        return batch
                    self._cond.wait(timeout=remaining)
                elif self._stop:
                    return None
                else:
                    self._cond.wait()

    def _note_flush(self, loaded: bool) -> None:
        """Adapt the micro-batch window to the flush that just happened:
        a saturated flush halves the window toward ``min_wait_ms``
        (companions are already queued -- waiting only adds latency); an
        idle deadline flush grows it back toward the ``max_wait_ms``
        ceiling.  Called under ``self._cond``."""
        if not self.scfg.adapt_window:
            return
        if loaded:
            self._window_ms = max(float(self.scfg.min_wait_ms),
                                  self._window_ms * 0.5)
            self._window_shrinks += 1
        else:
            if self._window_ms < self.scfg.max_wait_ms:
                self._window_grows += 1
            self._window_ms = min(float(self.scfg.max_wait_ms),
                                  max(self._window_ms * 2.0,
                                      self.scfg.max_wait_ms / 16.0))

    # ------------------------------------------------------------------
    # worker: coalesced launch + scatter-back + request completion
    # ------------------------------------------------------------------

    def _sig(self, launcher: Launcher, k_pad: int, shape: Tuple[int, ...],
             e_pad: int, cfg) -> tuple:
        # device ids distinguish a survivor submesh from the original
        # mesh of the same shape, so recovery invalidates by construction
        mesh_key = (None if self.mesh is None
                    else (self.mesh.axis_names, self.mesh.devices.shape,
                          tuple(d.id for d in self.mesh.devices.flat)))
        return (mesh_key, launcher.name, k_pad, shape, e_pad, cfg)

    def _k_pad(self, methods, k: int) -> int:
        """Padded row count for a launch whose items came from
        ``methods``: the smallest covering bucket of the methods' merged
        sorted ladders, the power-of-two ladder when any method declares
        none (the default), and the power-of-two fallback past the
        largest declared bucket (bucket-cap overflow)."""
        ladders = [m.batch_buckets for m in methods]
        if not ladders or any(lad is None for lad in ladders):
            return _row_bucket(k)
        for b in sorted({b for lad in ladders for b in lad}):
            if b >= k:
                return b
        return _row_bucket(k)

    # ------------------------------------------------------------------
    # collective launch fabric (leader/follower)
    # ------------------------------------------------------------------

    def _bcast(self, x):
        """One gloo payload broadcast (fault-injection site ``bcast``)."""
        from jax.experimental import multihost_utils as MH
        FI.fire("bcast")
        return MH.broadcast_one_to_all(x)

    def _collective_sweep(self, launcher: Launcher, stack: np.ndarray,
                          epss: np.ndarray, cfg, k_pad: int):
        """One padded launcher launch, surviving follower loss.

        Single-process: returns the (possibly still device-sharded)
        padded result.  Process-spanning mesh: broadcasts the launch
        descriptor + payload so followers enter the same collective
        (``multihost_utils.broadcast_one_to_all`` on the gloo epoch, the
        KV launch transport after recovery) and returns the gathered
        host (k_pad, e, R) array.  A retriable fabric fault shrinks the
        mesh (:meth:`_recover`) and relaunches -- the returned rows are
        bit-equal regardless of which fabric generation computed them.
        """
        if not self._multiproc:
            return launcher.launch(stack, epss, cfg, k_pad, self.mesh)
        with self._launch_lock:
            err: Optional[F.FabricError] = None
            for _ in range(len(self._procs0) + 1):
                try:
                    return self._collective_sweep_once(
                        launcher, stack, epss, cfg, k_pad)
                except F.FabricError as exc:
                    if not exc.retriable:
                        raise
                    err = exc
                    self._recover(exc)
            raise F.FabricError(
                "collective launch kept failing across mesh shrinks",
                kind="failed") from err

    def _collective_sweep_once(self, launcher: Launcher, stack: np.ndarray,
                               epss: np.ndarray, cfg, k_pad: int):
        if not self._multiproc:      # degraded to leader-local serving
            return launcher.launch(stack, epss, cfg, k_pad, self.mesh)
        FI.fire("leader_launch")
        stack = np.ascontiguousarray(stack, np.float32)
        epss = np.ascontiguousarray(epss, np.float32)
        gid = self.registry.launcher_id(launcher)
        if self._transport == "gloo":
            trailing = stack.shape[1:]
            hdr = np.zeros(self.HDR_LEN, np.int64)
            hdr[0], hdr[1], hdr[2], hdr[3] = (
                self.OP_LAUNCH, stack.shape[0], k_pad, stack.ndim)
            hdr[4 + (3 - len(trailing)):7] = trailing
            hdr[7] = len(epss)
            hdr[8] = gid

            def launch():
                self._bcast(hdr)
                # both sides consume the broadcast copies, so leader and
                # followers feed byte-identical inputs to the collective
                st = np.asarray(self._bcast(stack))
                ep = np.asarray(self._bcast(epss))
                out = launcher.launch(st, ep, cfg, k_pad, self.mesh)
                return launcher.gather(out)

            return self._bounded_collective(launch)
        # post-recovery transport: launch descriptor + payload + result
        # blocks through the coordination-service KV store.  A faulted
        # gloo collective leaves stale pair connections that poison any
        # later cross-process device collective in this cohort, so each
        # survivor runs its contiguous row block's launcher computation
        # on its own LOCAL mesh (rows are mesh-independent, hence still
        # bit-equal) and no cross-process collective ever runs on a
        # recovered fabric.
        seq = self._seq + 1
        base = f"{self._kvp}/l/{self._epoch}/{seq}"
        e = int(epss.shape[0])
        R = launcher.row_width
        parts = self._partition(stack.shape[0])
        F.kv_put_bytes(self._kv, f"{base}/stack", stack.tobytes())
        F.kv_put_bytes(self._kv, f"{base}/eps", epss.tobytes())
        F.kv_set(self._kv, f"{base}/hdr", json.dumps(
            {"shape": list(stack.shape), "e": e, "g": gid,
             "parts": {str(p): list(lohi) for p, lohi in parts.items()}}))
        lo, hi = parts[self._me]
        blocks = {self._me: self._local_rows(launcher, stack[lo:hi],
                                             epss, cfg, e)}
        deadline = time.monotonic() + self.scfg.launch_timeout_s
        lost = []
        for pid in self._procs:
            if pid == self._me:
                continue
            plo, phi = parts[pid]
            if phi <= plo:
                blocks[pid] = np.zeros((0, e, R), np.float32)
                continue
            data = self._collect_block(f"{base}/out/{pid}", pid, deadline)
            if data is None or len(data) != (phi - plo) * e * R * 4:
                lost.append(pid)
            else:
                blocks[pid] = np.frombuffer(
                    data, np.float32).reshape(phi - plo, e, R)
        if lost:
            raise F.FabricError(
                "survivor(s) never returned their row blocks",
                kind="follower_lost", lost=lost, retriable=True)
        self._seq = seq
        return np.concatenate([blocks[p] for p in self._procs], axis=0)

    def _collect_block(self, key: str, pid: int,
                       deadline: float) -> Optional[bytes]:
        """Wait for ``pid``'s row block under the launch deadline,
        polling in short slices so a peer that DIES mid-launch is
        detected in one heartbeat-staleness window instead of burning
        the whole deadline (a slow-but-alive peer still gets all of
        it)."""
        while True:
            rem_ms = int((deadline - time.monotonic()) * 1000)
            if rem_ms <= 0:
                return None
            data = F.kv_get_bytes(self._kv, key, min(500, rem_ms))
            if data is not None:
                return data
            if self._monitor is not None:
                self._monitor.poll()
                if self._monitor.age(pid) > self._stale_after:
                    return None

    def _partition(self, k: int) -> dict:
        """Contiguous row blocks {pid: (lo, hi)} over the current procs,
        proportional to each survivor's device share."""
        counts = [max(1, self._proc_devs.get(p, 1)) for p in self._procs]
        total = sum(counts)
        parts, lo, cum = {}, 0, 0
        for p, c in zip(self._procs, counts):
            cum += c
            hi = (k * cum) // total
            parts[p] = (lo, hi)
            lo = hi
        return parts

    def _local_rows(self, launcher: Launcher, stack: np.ndarray,
                    epss: np.ndarray, cfg, e: int) -> np.ndarray:
        """Run ``stack``'s launcher computation on this process's local
        mesh, rows to host."""
        k = stack.shape[0]
        if k == 0:
            return np.zeros((0, e, launcher.row_width), np.float32)
        out = launcher.launch(stack, epss, cfg, _row_bucket(k),
                              self._local_mesh)
        return launcher.gather(out)[:k]

    def _bounded_collective(self, fn):
        """Run one collective on a sacrificial thread under the launch
        deadline; translate peer faults into a retriable FabricError."""
        box = _Boxed(fn, "svc-collective")
        if box.wait(self.scfg.launch_timeout_s):
            if box.error is None:
                return box.value
            lost = self._observe_lost()
            if (not lost and isinstance(box.error, Exception)
                    and not isinstance(box.error, _XLA_ERRORS)):
                # every follower kept heartbeating and the failure is a
                # plain Python error: a genuine compute/shape problem,
                # scoped to this batch -- not a fabric fault.  A runtime
                # (gloo/dispatch) error with fresh heartbeats still
                # recovers: lost=() keeps every survivor and just moves
                # the fabric off the poisoned gloo transport.
                raise box.error
            raise F.FabricError(
                f"collective launch failed: "
                f"{type(box.error).__name__}: {box.error}",
                kind="follower_lost", lost=lost, retriable=True) \
                from box.error
        lost = self._observe_lost()
        if not lost:
            # deadline expired with fresh heartbeats everywhere: a
            # wedged-but-alive peer is indistinguishable from inside the
            # collective, so evict ALL followers and serve leader-local
            # (always correct, never wedged)
            lost = [p for p in self._procs if p != self._me]
        raise F.FabricError(
            f"collective launch exceeded launch_timeout_s="
            f"{self.scfg.launch_timeout_s}",
            kind="follower_lost", lost=lost, retriable=True)

    def _observe_lost(self) -> list:
        """Attribute a launch fault: watch follower heartbeats for one
        staleness window, return the pids that never advanced."""
        followers = [p for p in self._procs if p != self._me]
        if self._monitor is None or not followers:
            return followers
        return self._monitor.observe_stale(followers, self._stale_after)

    def _recover(self, err: F.FabricError) -> None:
        """Shrink the fabric to the survivors of ``err`` and rendezvous
        them at a new epoch (leader side)."""
        t0 = time.perf_counter()
        if self._kv is None:
            raise F.FabricError(
                "cannot recover: no coordination-service KV store "
                "(fabric built without jax.distributed?)",
                kind="failed") from err
        dead = set(err.lost)
        alive = [p for p in self._procs if p == self._me or p not in dead]
        for _ in range(len(self._procs0) + 2):
            self._epoch += 1
            F.kv_set(self._kv, f"{self._kvp}/epoch", json.dumps(
                {"epoch": self._epoch, "procs": alive}))
            if len(alive) <= 1:
                break
            if F.fabric_barrier(self._kv, f"{self._kvp}-rec-{self._epoch}",
                                self._barrier_timeout, alive):
                break
            # a survivor missed the rendezvous: attribute and shed (all
            # followers when unattributable), then re-publish
            stale = self._monitor.observe_stale(
                [p for p in alive if p != self._me], self._stale_after)
            shed = set(stale) or {p for p in alive if p != self._me}
            alive = [p for p in alive if p == self._me or p not in shed]
        self._procs = alive
        self._adopt_kv_fabric(alive)
        if len(alive) <= 1:
            # last one standing: degrade to the single-process path
            self._multiproc = False
        self._recoveries += 1
        self._last_recovery_s = time.perf_counter() - t0

    def _adopt_kv_fabric(self, alive: Sequence[int]) -> None:
        """Switch this process onto the recovered (KV-transport,
        local-compute) fabric: row shares from the survivor submesh,
        compute on the process-local mesh, old-mesh executables out."""
        sub = F.surviving_submesh(self._mesh0, alive)
        self._proc_devs = {
            p: sum(1 for d in sub.devices.flat if d.process_index == p)
            for p in alive}
        # a faulted gloo collective poisons even process-LOCAL
        # multi-device collectives (they dispatch through the same gloo
        # state), so recovered compute runs unsharded per process
        self._local_mesh = None
        self.mesh = self._local_mesh
        self._transport = "kv"
        self._seq = 0
        DS.invalidate_mesh_caches()
        self._executables.clear()

    def _wait_byes(self) -> None:
        """Bounded wait for follower goodbye markers at shutdown, so the
        leader's embedded coordination service stays up for their last
        KV reads (a dead follower is excused by heartbeat staleness)."""
        others = [p for p in self._procs0 if p != self._me]
        if not others or self._kv is None:
            return
        deadline = time.monotonic() + max(2.0, 2 * self._stale_after)
        while time.monotonic() < deadline:
            byes = set()
            for key in F.kv_dir(self._kv, f"{self._kvp}/bye/"):
                try:
                    byes.add(int(key.rsplit("/", 1)[-1]))
                except ValueError:
                    continue
            pending = [p for p in others if p not in byes]
            if pending and self._monitor is not None:
                self._monitor.poll()
                pending = [p for p in pending
                           if self._monitor.age(p) <= self._stale_after]
            if not pending:
                return
            time.sleep(0.05)

    # ------------------------------------------------------------------
    # follower: launch mirror + epoch recovery
    # ------------------------------------------------------------------

    def _shutdown_set(self) -> bool:
        return (self._kv is not None and
                F.kv_get(self._kv, f"{self._kvp}/shutdown", 30) is not None)

    def _read_epoch(self) -> Optional[dict]:
        raw = None if self._kv is None else \
            F.kv_get(self._kv, f"{self._kvp}/epoch", 30)
        if raw is None:
            return None
        try:
            desc = json.loads(raw)
            return {"epoch": int(desc["epoch"]),
                    "procs": [int(p) for p in desc["procs"]]}
        except Exception:
            return None

    def _epoch_advanced(self) -> bool:
        desc = self._read_epoch()
        return desc is not None and desc["epoch"] > self._epoch

    def _leader_stale(self) -> bool:
        if self._monitor is None:
            return False
        self._monitor.poll()
        if not self._monitor.seen(self._leader_pid):
            # never beat: give the leader one full startup allowance
            return time.monotonic() > self._first_beat_deadline
        return self._monitor.age(self._leader_pid) > 2 * self._stale_after

    def _follower_loop(self) -> None:
        """Mirror the leader's launch stream -- joining every collective
        with the broadcast payload until shutdown -- and mirror its
        epoch state machine across faults."""
        import traceback
        try:
            while True:
                step = (self._follower_gloo_step
                        if self._transport == "gloo"
                        else self._follower_kv_step)
                res = step()
                if res == "shutdown":
                    return
                if res == "fault":
                    self._follower_recover()
        except BaseException as exc:     # noqa: BLE001 -- must not die
            # surface the error loudly so serve() re-raises instead of
            # returning as if shutdown completed cleanly
            self._fabric_error = exc
            if not isinstance(exc, F.FabricError):
                traceback.print_exc()
        finally:
            # goodbye marker: tells the leader this process is done
            # reading the KV store, so it may tear the coordinator down
            if self._kv is not None:
                F.kv_set(self._kv, f"{self._kvp}/bye/{self._me}", "1")
            if self._hb is not None:
                self._hb.stop()

    def _follower_gloo_step(self) -> Optional[str]:
        # phase 1: park on the header broadcast, watching for shutdown,
        # an epoch advance (the leader recovered without this op), and
        # leader death -- a follower must never block forever
        box = _Boxed(lambda: self._bcast(np.zeros(self.HDR_LEN, np.int64)),
                     "svc-follower-hdr")
        while not box.wait(0.2):
            if self._shutdown_set():
                return "shutdown"
            if self._epoch_advanced():
                return "fault"
            if self._leader_stale():
                raise F.FabricError("leader stopped heartbeating",
                                    kind="leader_lost",
                                    lost=(self._leader_pid,))
        if box.error is not None:
            return "fault"           # peer died mid-broadcast
        hdr = np.asarray(box.value)
        if int(hdr[0]) == self.OP_SHUTDOWN:
            return "shutdown"
        k, k_pad, rank = int(hdr[1]), int(hdr[2]), int(hdr[3])
        trailing = tuple(int(d) for d in hdr[4 + (3 - (rank - 1)):7])
        e = int(hdr[7])
        launcher = self.registry.launcher(int(hdr[8]))
        cfg = launcher.follower_cfg(self.scfg)

        def join():
            FI.fire("follower_launch")
            stack = np.asarray(self._bcast(
                np.zeros((k,) + trailing, np.float32)))
            epss = np.asarray(self._bcast(np.zeros(e, np.float32)))
            out = launcher.launch(stack, epss, cfg, k_pad, self.mesh)
            launcher.gather(out)

        if self._bounded_join(join) == "fault":
            return "fault"
        self._count_follower_launch(launcher, k, k_pad, trailing, e, cfg)
        return None

    def _follower_kv_step(self) -> Optional[str]:
        base = f"{self._kvp}/l/{self._epoch}/{self._seq + 1}"
        raw = F.kv_get(self._kv, f"{base}/hdr", 500)
        if raw is None:
            if self._shutdown_set():
                return "shutdown"
            if self._epoch_advanced():
                return "fault"
            if self._leader_stale():
                raise F.FabricError("leader stopped heartbeating",
                                    kind="leader_lost",
                                    lost=(self._leader_pid,))
            return None              # keep polling
        hdr = json.loads(raw)
        launcher = self.registry.launcher(int(hdr.get("g", 0)))
        cfg = launcher.follower_cfg(self.scfg)
        lo, hi = hdr["parts"].get(str(self._me), (0, 0))
        timeout_ms = int(self.scfg.launch_timeout_s * 1000)

        def join():
            FI.fire("kv_launch")
            if hi <= lo:
                return
            st = F.kv_get_bytes(self._kv, f"{base}/stack", timeout_ms)
            ep = F.kv_get_bytes(self._kv, f"{base}/eps", timeout_ms)
            if st is None or ep is None:
                raise F.FabricError("KV launch payload never arrived",
                                    kind="timeout")
            stack = np.frombuffer(st, np.float32).reshape(
                hdr["shape"])[lo:hi].copy()
            epss = np.frombuffer(ep, np.float32).copy()
            rows = self._local_rows(launcher, stack, epss, cfg,
                                    int(hdr["e"]))
            F.kv_put_bytes(self._kv, f"{base}/out/{self._me}",
                           np.ascontiguousarray(rows, np.float32).tobytes())

        if self._bounded_join(join) == "fault":
            return "fault"
        self._seq += 1
        shape = tuple(hdr["shape"])
        self._count_follower_launch(
            launcher, hi - lo, _row_bucket(hi - lo) if hi > lo else 0,
            shape[1:], int(hdr["e"]), cfg)
        return None

    def _bounded_join(self, join) -> Optional[str]:
        """Phase 2 of a follower step: run the collective join on a
        sacrificial thread, abandoning it the moment the leader
        publishes a new epoch (this op will never complete) or stops
        heartbeating.  There is deliberately NO own-time deadline here:
        a follower never evicts anyone, so every fault it must react to
        is attributable -- eviction/shrink arrives as an epoch advance,
        leader death as heartbeat staleness, fabric poisoning as the
        shutdown marker -- while a bare deadline can only misfire on a
        SLOW join (e.g. first-launch compile), abandoning work the
        leader is still waiting for."""
        jb = _Boxed(join, "svc-follower-join")
        while not jb.wait(0.2):
            if self._epoch_advanced():
                return "fault"
            if self._shutdown_set():
                return "fault"       # recover observes the marker
            if self._leader_stale():
                raise F.FabricError("leader died mid-launch",
                                    kind="leader_lost",
                                    lost=(self._leader_pid,))
        return "fault" if jb.error is not None else None

    def _count_follower_launch(self, launcher: Launcher, k: int, k_pad: int,
                               trailing: tuple, e: int, cfg) -> None:
        self._launches += 1
        self._rows_launched += k
        self._pad_rows += k_pad - k
        self._executables.add(
            self._sig(launcher, k_pad, tuple(trailing), e, cfg))

    def _follower_recover(self) -> None:
        """Rejoin the fabric at the epoch the leader published (or learn
        this process was evicted / the leader is gone).  Bounded."""
        if self._kv is None:
            raise F.FabricError(
                "no coordination-service KV store to recover through",
                kind="failed")
        deadline = time.monotonic() + max(self.scfg.launch_timeout_s,
                                          4 * self._stale_after)
        while True:
            desc = self._read_epoch()
            if desc is not None and desc["epoch"] > self._epoch:
                if self._me not in desc["procs"]:
                    raise F.FabricError(
                        "this process was dropped from the recovered "
                        "fabric", kind="evicted", lost=(self._me,))
                if F.fabric_barrier(
                        self._kv, f"{self._kvp}-rec-{desc['epoch']}",
                        self._barrier_timeout, desc["procs"]):
                    self._epoch = desc["epoch"]
                    self._procs = desc["procs"]
                    self._adopt_kv_fabric(desc["procs"])
                    return
                # missed this rendezvous window: the leader may publish
                # a further-shrunk epoch (possibly without us) -- loop
            if self._shutdown_set():
                return               # next step observes the marker
            if self._leader_stale():
                raise F.FabricError("leader lost during recovery",
                                    kind="leader_lost",
                                    lost=(self._leader_pid,))
            if time.monotonic() > deadline:
                if desc is None or desc["epoch"] <= self._epoch:
                    # the epoch never moved and the leader is still
                    # heartbeating: there is no fabric fault to recover
                    # FROM (a join was abandoned spuriously, or an
                    # asymmetric gloo error the leader hasn't hit yet).
                    # Rejoin the current epoch; a real fault will
                    # re-announce itself as an epoch advance.
                    return
                raise F.FabricError(
                    "recovery window expired mid-rendezvous",
                    kind="timeout")
            time.sleep(0.1)

    # ------------------------------------------------------------------
    # batch resolution (generic over methods/launchers)
    # ------------------------------------------------------------------

    def _process(self, batch: List[MethodRequest]) -> None:
        self._batches += 1
        # 1. resolve the cross-request cache; group the misses by
        #    (launcher, trailing shape, launch config) and dedup
        #    identical rows, unioning the eps keys each digest needs
        local: Dict[Tuple[tuple, float], np.ndarray] = {}
        need: Dict[tuple, dict] = {}
        for req in batch:
            # one sighting per REQUEST touching the digest (duplicates
            # within one request's stack don't count): the admission
            # policy caches a field only once >= admit_after requests
            # wanted it (concurrent in-batch requests count)
            for key in {it.key for it in req.items}:
                self.cache.record_sighting(key)
        for req in batch:
            for it in req.items:
                for ek in it.eps_keys:
                    if (it.key, ek) in local:
                        continue
                    row = self.cache.get(it.key, ek)
                    if row is not None:
                        local[(it.key, ek)] = row
                    else:
                        group = need.setdefault(
                            (req.method.launcher, it.x.shape, it.key[1]),
                            {"items": {}, "methods": set()})
                        group["methods"].add(req.method)
                        entry = group["items"].setdefault(
                            it.key, (it.x, set()))
                        entry[1].add(ek)
        # 2. ONE launch per (launcher, shape, config) group (eps unions
        #    wider than max_eps_per_launch are chunked)
        for (launcher, shape, cfg), group in need.items():
            union = sorted({e for _, es in group["items"].values()
                            for e in es})
            step = self.scfg.max_eps_per_launch
            for lo in range(0, len(union), step):
                self._launch(launcher, group, union[lo:lo + step], cfg,
                             local)
        # 3. complete every request from the batch-local rows -- on the
        #    post-processing pool, so the device thread moves straight
        #    to the next batch (``max_live_batches`` bounds the overlap)

        def rows_for(item: Item, _local=local) -> np.ndarray:
            return np.stack([_local[(item.key, ek)]
                             for ek in item.eps_keys])

        def complete():
            try:
                for req in batch:
                    try:
                        req.future.set_result(
                            req.method.post_process(req, rows_for))
                        self._note_done(req, ok=True)
                    except Exception as exc:
                        if not req.future.done():
                            req.future.set_exception(exc)
                        self._note_done(req, ok=False)
            finally:
                self._release_live()

        self._post.submit(complete)

    def _launch(self, launcher: Launcher, group: dict,
                eps_chunk: List[float], cfg,
                local: Dict[Tuple[tuple, float], np.ndarray]) -> None:
        digests = group["items"]
        order = list(digests)
        k = len(order)
        k_pad = self._k_pad(group["methods"], k)
        # pack the batch into the pinned staging buffer for its padded
        # shape (allocated once per warm bucket, then re-used: _launch
        # runs on the single worker thread and scatter_requests below
        # blocks until the device has consumed the upload, so the next
        # batch can safely refill it).  Pad rows repeat the last real row
        # -- byte-identical to the pad sweep_padded would synthesize.
        trailing = digests[order[0]][0].shape
        buf = self._staging.get((k_pad,) + trailing)
        if buf is None:
            buf = np.empty((k_pad,) + trailing, np.float32)
            self._staging[(k_pad,) + trailing] = buf
        for i, key in enumerate(order):
            buf[i] = digests[key][0]
        buf[k:] = buf[k - 1]
        # the collective fabric broadcasts the true-k rows (the follower
        # protocol allocates from the real row count); the local path
        # hands the launcher the whole pre-padded buffer so no per-batch
        # device-side pad concat happens either
        stack = buf[:k] if self._multiproc else buf
        e_pad = launcher.eps_bucket(len(eps_chunk))
        epss = np.asarray(
            eps_chunk + [eps_chunk[-1]] * (e_pad - len(eps_chunk)),
            np.float32)
        out = self._collective_sweep(launcher, stack, epss, cfg, k_pad)
        # scatter-back: ONE host transfer for the whole coalesced batch,
        # split into per-digest row blocks (pad rows dropped)
        blocks = DS.scatter_requests(out, [1] * k)
        for key, block in zip(order, blocks):
            for j, ek in enumerate(eps_chunk):
                # owned copy: a view would pin the whole (k_pad, e_pad, R)
                # batch result in memory for the row's cache lifetime
                row = np.array(block[0, j])
                local[(key, ek)] = row
                self.cache.put(key, ek, row)
        self._launches += 1
        self._rows_launched += k
        self._pad_rows += k_pad - k
        self._executables.add(
            self._sig(launcher, k_pad, stack.shape[1:], e_pad, cfg))
