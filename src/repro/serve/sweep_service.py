"""Request-coalescing sweep service: micro-batching + cross-request cache.

The serving gap this closes: ``find_error_bound_for_cr`` (UC1) and
``best_compressor`` (UC2) each pay one full featurization dispatch per
request, and under a mesh each request triggers its own ``shard_map``
launch.  The paper's speedups assume featurization cost is *amortized*
across queries, so the service batches the amortization in three layers:

1. **Micro-batching queue** -- concurrent ``submit_*`` calls enqueue; a
   single worker thread flushes when the pending row count reaches
   ``max_batch_slices`` or the oldest request has waited ``max_wait_ms``.
   Every flushed batch becomes ONE ``dist.sweep.sweep_padded`` launch per
   (slice shape, engine config) group -- shapes are arbitrary trailing
   shapes, so (d, m, n) volume requests coalesce alongside (m, n) slice
   requests -- ``gather=False`` on the persistent mesh, so devices keep
   their shards until the single scatter-back transfer -- and the
   (k, e, 2) rows are scattered to the per-request futures.

2. **Cross-request feature cache** -- content hash of the f32 slice bytes
   + engine config -> per-error-bound feature rows, LRU with a byte
   budget.  A repeated UC1 bisection or UC2 ranking over a hot field is
   served from the cache with ZERO sweep launches.  Within one batch,
   requests for the same slice are deduplicated before launch and their
   error-bound grids are unioned into one eps vector (per-eps results are
   independent, so the union launch is bit-equal to separate ones).

3. **Persistent bucketed executables** -- batches are padded to
   power-of-two row buckets and a small set of eps-vector lengths, so the
   jitted sweep executables (keyed by mesh + padded batch shape) are
   compiled once per bucket and reused for every traffic mix.

Results are bit-identical to per-request serial dispatch: the sweep body
is row-independent and per-eps-independent, UC1 bisection runs the exact
``usecases`` code on a seeded ``SliceCache``, and UC2 ranking feeds the
shared rows through the exact ``best_compressor`` model evaluation.

Cache admission: one-shot cold fields are NOT cached.  A slice's rows are
admitted only once its content hash has been sighted by
``cache_admit_after`` distinct requests (default 2) -- concurrent
requests for the same slice inside one batch count individually, so a
hot field entering with simultaneous UC1+UC2 traffic is admitted on its
very first launch, while a scan over thousands of distinct cold slices
never evicts the working set.

Multi-process leader/follower mode
----------------------------------
Constructed on a PROCESS-SPANNING mesh (``repro.launch.mesh.dist_init``
+ ``make_sweep_mesh``), the service splits roles: the mesh's first
process is the **leader** -- it owns the micro-batching queue, the
cache, and the public ``submit_*`` API -- and every other process is a
**follower** that blocks in :meth:`serve` joining each collective
launch.  Per launch the leader broadcasts a fixed-size header (batch
rows, trailing shape, eps length, ``k_pad``) and then the slice stack +
eps union (``multihost_utils.broadcast_one_to_all``); both sides enter
the same ``dist.sweep.sweep_padded`` collective, and the scatter-back
all-gather is the single synchronization point.  ``close()`` on the
leader drains the queue and broadcasts a shutdown header that releases
the followers.  All processes must construct the service with the same
``ServiceConfig`` (the engine config is not re-broadcast per launch).

Usage::

    from repro.serve.sweep_service import SweepService, ServiceConfig
    with SweepService(mesh=my_mesh) as svc:        # or under use_mesh(...)
        f1 = svc.submit_find_eb(grid_model, slice_a, target_cr=8.0)
        f2 = svc.submit_best_compressor(models, slice_b, eps)
        f3 = svc.submit_featurize(stack, ebs)
        eps, cr = f1.result()

    # multi-process: leader (process 0) runs the block above; followers:
    svc = SweepService(mesh=my_mesh)
    svc.serve()                                    # until leader close()
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import predictors as P
from repro.core import usecases as UC
from repro.dist import sweep as DS


_EPS_BUCKETS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)


def _row_bucket(k: int) -> int:
    """Smallest power-of-two >= k: row buckets are pow2 so any pow2 mesh
    extent divides every bucket at or above it (the sharded path never
    needs a second pad)."""
    b = 1
    while b < k:
        b *= 2
    return b


def _eps_bucket(e: int) -> int:
    for b in _EPS_BUCKETS:
        if e <= b:
            return b
    return -(-e // 16) * 16


def _f32(eps) -> float:
    """Canonical f32 error-bound key (features are computed in f32)."""
    return float(np.float32(eps))


def slice_digest(x) -> str:
    """Content hash of a slice's f32 bytes (featurization casts to f32,
    so a float64 array and its f32 round-trip share cache entries)."""
    arr = np.ascontiguousarray(np.asarray(x, np.float32))
    h = hashlib.sha1(arr.tobytes())
    h.update(str(arr.shape).encode())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    max_batch_slices: int = 64       # flush when this many rows are pending
    max_wait_ms: float = 2.0         # ... or the oldest request waited this
    cache_bytes: int = 4 << 20       # cross-request feature-cache budget
    max_eps_per_launch: int = 32     # chunk wider eps unions across launches
    cache_admit_after: int = 2       # sightings before a digest is cached
    pcfg: P.PredictorConfig = dataclasses.field(
        default_factory=P.PredictorConfig)


class FeatureCache:
    """Cross-request feature cache: (slice digest, engine config) ->
    {f32 eb -> (2,) feature row}, LRU over slices with a byte budget.

    Admission policy: a digest's rows are stored only once it has been
    *sighted* (``record_sighting``, one count per request touching the
    digest) at least ``admit_after`` times, so one-shot cold fields pass
    through without polluting the LRU ring.  ``admit_after=1`` (the
    class default, kept for direct users) admits on first touch; the
    sweep service passes ``ServiceConfig.cache_admit_after`` (default
    2).  The sighting ring is a bounded FIFO of bare digests -- a few
    bytes per cold field, never row data.
    """

    ROW_BYTES = 2 * 4
    ENTRY_OVERHEAD = 128             # digest + dict bookkeeping estimate

    def __init__(self, max_bytes: int, admit_after: int = 1,
                 seen_capacity: int = 65536):
        self.max_bytes = int(max_bytes)
        self.admit_after = max(1, int(admit_after))
        self.seen_capacity = int(seen_capacity)
        self._entries: "collections.OrderedDict[tuple, dict]" = \
            collections.OrderedDict()
        self._seen: "collections.OrderedDict[tuple, int]" = \
            collections.OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.admissions_denied = 0
        self._lock = threading.Lock()

    def record_sighting(self, key: tuple, n: int = 1) -> int:
        """Count a request touching ``key``; returns the running total.
        Admitted digests stop counting (their entry is the signal)."""
        with self._lock:
            if key in self._entries:
                return self.admit_after
            seen = self._seen.get(key, 0) + n
            self._seen[key] = seen
            self._seen.move_to_end(key)
            while len(self._seen) > self.seen_capacity:
                self._seen.popitem(last=False)
            return seen

    def get(self, key: tuple, eps_key: float) -> Optional[np.ndarray]:
        with self._lock:
            ent = self._entries.get(key)
            if ent is None or eps_key not in ent:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return ent[eps_key]

    def put(self, key: tuple, eps_key: float, row: np.ndarray) -> bool:
        """Store one (digest, eb) row; returns False when the admission
        policy rejects the (cold, under-sighted) digest."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                if self.admit_after > 1 and \
                        self._seen.get(key, 0) < self.admit_after:
                    self.admissions_denied += 1
                    return False
                self._seen.pop(key, None)
                ent = self._entries[key] = {}
                self._bytes += self.ENTRY_OVERHEAD
            if eps_key not in ent:
                self._bytes += self.ROW_BYTES
            ent[eps_key] = row
            self._entries.move_to_end(key)
            # never evict the slice just written: it may still be needed
            # to complete the in-flight batch
            while self._bytes > self.max_bytes and len(self._entries) > 1:
                _, old = self._entries.popitem(last=False)
                self._bytes -= self.ENTRY_OVERHEAD + self.ROW_BYTES * len(old)
                self.evictions += 1
            return True

    @property
    def nbytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "entries": len(self),
                    "bytes": self._bytes,
                    "admissions_denied": self.admissions_denied,
                    "pending_sightings": len(self._seen)}


@dataclasses.dataclass
class _Item:
    """One slice's launch needs within a request."""
    key: tuple                       # (digest, engine config)
    x: np.ndarray                    # (m, n) / (d, m, n) f32 launch copy
    eps_keys: Tuple[float, ...]      # f32 ebs this request reads


@dataclasses.dataclass
class _Request:
    kind: str                        # featurize | find_eb | best_compressor
    items: List[_Item]
    future: Future
    payload: dict
    t_submit: float

    @property
    def rows(self) -> int:
        return len(self.items)


class SweepService:
    """Coalesces concurrent featurize/UC1/UC2 requests into single batched
    launches on a persistent mesh (module docstring has the full story).

    The mesh is captured at construction (explicit ``mesh=`` argument or
    the thread's active ``dist.sharding.use_mesh``) and reused for every
    launch -- the worker thread never depends on the caller's thread-local
    mesh context.
    """

    HDR_LEN = 8                      # [op, k, k_pad, rank, t0, t1, t2, e_pad]
    OP_SHUTDOWN, OP_LAUNCH = 0, 1

    def __init__(self, scfg: Optional[ServiceConfig] = None, *, mesh=None):
        self.scfg = scfg if scfg is not None else ServiceConfig()
        self.mesh = DS.active_sweep_mesh(mesh)
        self.cache = FeatureCache(self.scfg.cache_bytes,
                                  admit_after=self.scfg.cache_admit_after)
        self._queue: "collections.deque[_Request]" = collections.deque()
        self._cond = threading.Condition()
        self._stop = False
        self._launches = 0
        self._rows_launched = 0
        self._pad_rows = 0
        self._batches = 0
        self._requests = collections.Counter()
        self._executables: set = set()   # (mesh shape, k_pad, m, n, e_pad, cfg)
        # leader/follower roles on a process-spanning mesh: the mesh's
        # first process owns the queue, everyone else joins collectives
        self._multiproc = DS.mesh_spans_processes(self.mesh)
        if self._multiproc:
            import jax
            self.role = ("leader" if jax.process_index() ==
                         DS.mesh_processes(self.mesh)[0] else "follower")
        else:
            self.role = "leader"
        # serializes collective launches on the leader (worker batches vs
        # main-thread warmup/close): followers see one header stream
        self._launch_lock = threading.Lock()
        target = self._loop if self.role == "leader" else self._follower_loop
        self._worker = threading.Thread(
            target=target, name=f"sweep-service-{self.role}", daemon=True)
        self._worker.start()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def _check_cfg(self, cfg: P.PredictorConfig) -> P.PredictorConfig:
        """Leader/follower launches carry no per-request engine config
        (the header is fixed-size and followers compiled against the
        service config), so multi-process services accept only it."""
        if self._multiproc and cfg != self.scfg.pcfg:
            raise ValueError(
                "multi-process SweepService serves only its configured "
                "engine config (ServiceConfig.pcfg); per-request configs "
                "are a single-process feature")
        return cfg

    def submit_featurize(self, slices, epss,
                         cfg: Optional[P.PredictorConfig] = None) -> Future:
        """(k, m, n) slice stack or (k, d, m, n) volume stack x (e,) ebs
        -> Future[(k, e, 2) np.ndarray], bit-equal to
        ``features_sweep(slices, epss)``.  Batching/digests are keyed by
        the trailing shape, so volume requests coalesce with each other
        exactly like slice requests do."""
        cfg = self._check_cfg(cfg if cfg is not None else self.scfg.pcfg)
        arr = np.asarray(slices, np.float32)
        if arr.ndim not in (3, 4):
            raise ValueError(
                f"submit_featurize expects (k, m, n) or (k, d, m, n), "
                f"got {arr.shape}")
        eps_keys = tuple(_f32(e) for e in np.asarray(epss).reshape(-1))
        if not eps_keys:
            raise ValueError("submit_featurize needs at least one eb")
        items = [_Item((slice_digest(s), cfg), s, eps_keys) for s in arr]
        return self._submit(_Request(
            "featurize", items, Future(),
            {"eps_keys": eps_keys}, time.perf_counter()))

    def submit_find_eb(self, grid_model, data, target_cr: float,
                       tol: float = 0.02, max_iters: int = 32) -> Future:
        """UC1 through the service: Future[(eps, predicted_cr)], bit-equal
        to ``usecases.find_error_bound_for_cr``.  The grid featurization
        comes from the shared launch / cross-request cache."""
        cfg = self._check_cfg(grid_model.cfg)
        x = np.asarray(data, np.float32)
        if x.ndim != grid_model.ndim:
            # validate at submit time: a worker-side failure would poison
            # the whole coalesced batch, not just this request
            raise ValueError(
                f"submit_find_eb: grid model '{grid_model.name}' was "
                f"trained on {grid_model.ndim}-D data, got {x.shape}")
        eps_keys = tuple(_f32(e) for e in np.asarray(grid_model.ebs))
        item = _Item((slice_digest(x), cfg), x, eps_keys)
        return self._submit(_Request(
            "find_eb", [item], Future(),
            {"grid_model": grid_model, "data": data, "target_cr": target_cr,
             "tol": tol, "max_iters": max_iters}, time.perf_counter()))

    def submit_best_compressor(self, models: Dict[str, object], data,
                               eps: float) -> Future:
        """UC2 through the service: Future[(best_name, preds)], bit-equal
        to ``usecases.best_compressor``."""
        if not models:
            raise ValueError("submit_best_compressor needs trained models")
        cfg = self._check_cfg(next(iter(models.values())).cfg)
        ndims = {m.ndim for m in models.values()}
        x = np.asarray(data, np.float32)
        if len(ndims) > 1 or x.ndim != next(iter(ndims)):
            raise ValueError(
                f"submit_best_compressor: models trained on "
                f"{sorted(ndims)}-D data must all match the request rank, "
                f"got {x.shape}")
        item = _Item((slice_digest(x), cfg), x, (_f32(eps),))
        return self._submit(_Request(
            "best_compressor", [item], Future(),
            {"models": models, "data": data, "eps": eps},
            time.perf_counter()))

    # sync conveniences ------------------------------------------------

    def featurize(self, slices, epss, cfg=None) -> np.ndarray:
        return self.submit_featurize(slices, epss, cfg).result()

    def find_eb(self, grid_model, data, target_cr, **kw) -> tuple:
        return self.submit_find_eb(grid_model, data, target_cr, **kw).result()

    def best_compressor(self, models, data, eps) -> tuple:
        return self.submit_best_compressor(models, data, eps).result()

    def stats(self) -> dict:
        return {"role": self.role,
                "launches": self._launches,
                "rows_launched": self._rows_launched,
                "pad_rows": self._pad_rows,
                "batches": self._batches,
                "executables": len(self._executables),
                "requests": dict(self._requests),
                "cache": self.cache.stats()}

    @property
    def launches(self) -> int:
        return self._launches

    def warmup(self, shapes: Sequence[Tuple[int, ...]],
               grid_sizes: Sequence[int] = (1,),
               row_buckets: Sequence[int] = (1,),
               cfg: Optional[P.PredictorConfig] = None) -> None:
        """Pre-compile the bucketed executables for the expected traffic
        (slice (m, n) / volume (d, m, n) shapes x eps-grid sizes x row
        buckets) so first requests don't pay compile latency.  On a
        process-spanning mesh the leader's warmup launches ride the
        collective fabric, so followers precompile the same executables
        (followers themselves call :meth:`serve`, not ``warmup``)."""
        if self.role == "follower":
            raise RuntimeError(
                "warmup runs on the leader; followers precompile by "
                "joining its collective warmup launches via serve()")
        cfg = self._check_cfg(cfg if cfg is not None else self.scfg.pcfg)
        for shape in shapes:
            shape = tuple(shape)
            x = np.zeros((1,) + shape, np.float32)
            for e in grid_sizes:
                for k in row_buckets:
                    k_pad, e_pad = _row_bucket(k), _eps_bucket(e)
                    out = self._collective_sweep(
                        x, np.full((e_pad,), 1.0, np.float32), cfg, k_pad)
                    np.asarray(DS.gather_rows(out))
                    self._executables.add(self._sig(k_pad, shape, e_pad, cfg))

    def serve(self) -> None:
        """Block until the service stops.

        The follower's main loop: joins collective launches until the
        leader's ``close()`` broadcasts shutdown.  On a leader this just
        waits for ``close()`` from another thread.  Raises if the worker
        died on an error instead of a clean shutdown (a silently-exited
        follower would wedge the leader's next collective).
        """
        self._worker.join()
        err = getattr(self, "_fabric_error", None)
        if err is not None:
            raise RuntimeError(
                f"sweep-service {self.role} worker died; the fabric is "
                "wedged (restart every process)") from err

    def close(self) -> None:
        """Flush pending requests and stop the worker thread.

        Leader of a multi-process service: after the queue drains, a
        shutdown header releases every follower out of :meth:`serve`.
        Follower: blocks until the leader shuts the fabric down.
        """
        if self.role == "follower":
            self._worker.join()
            return
        with self._cond:
            if self._stop:
                return
            self._stop = True
            self._cond.notify_all()
        self._worker.join()
        if self._multiproc:
            from jax.experimental import multihost_utils as MH
            with self._launch_lock:
                MH.broadcast_one_to_all(
                    np.zeros(self.HDR_LEN, np.int64))     # OP_SHUTDOWN

    def __enter__(self) -> "SweepService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # worker: micro-batching loop
    # ------------------------------------------------------------------

    def _submit(self, req: _Request) -> Future:
        if self.role == "follower":
            raise RuntimeError(
                "follower processes don't accept requests; submit to the "
                "leader (the mesh's first process) and call serve() here")
        with self._cond:
            if self._stop:
                raise RuntimeError("SweepService is closed")
            self._queue.append(req)
            self._requests[req.kind] += 1
            self._cond.notify_all()
        return req.future

    def _loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._process(batch)
            except Exception as exc:  # fail the whole batch, not the server
                for req in batch:
                    if not req.future.done():
                        req.future.set_exception(exc)

    def _next_batch(self) -> Optional[List[_Request]]:
        """Block until a batch is ready: pending rows reach
        ``max_batch_slices``, or the OLDEST pending request has waited
        ``max_wait_ms`` (a single request flushes alone at the deadline),
        or the service is closing (drains what is left)."""
        with self._cond:
            while True:
                if self._queue:
                    rows = sum(r.rows for r in self._queue)
                    deadline = (self._queue[0].t_submit +
                                self.scfg.max_wait_ms / 1e3)
                    remaining = deadline - time.perf_counter()
                    if (rows >= self.scfg.max_batch_slices or
                            remaining <= 0 or self._stop):
                        batch, total = [], 0
                        while self._queue and (
                                total < self.scfg.max_batch_slices or
                                not batch):
                            req = self._queue.popleft()
                            batch.append(req)
                            total += req.rows
                        return batch
                    self._cond.wait(timeout=remaining)
                elif self._stop:
                    return None
                else:
                    self._cond.wait()

    # ------------------------------------------------------------------
    # worker: coalesced launch + scatter-back + request completion
    # ------------------------------------------------------------------

    def _sig(self, k_pad: int, shape: Tuple[int, ...], e_pad: int,
             cfg: P.PredictorConfig) -> tuple:
        mesh_key = (None if self.mesh is None
                    else (self.mesh.axis_names, self.mesh.devices.shape))
        return (mesh_key, k_pad, shape, e_pad, cfg)

    # ------------------------------------------------------------------
    # collective launch fabric (leader/follower)
    # ------------------------------------------------------------------

    def _collective_sweep(self, stack: np.ndarray, epss: np.ndarray,
                          cfg: P.PredictorConfig, k_pad: int):
        """One ``sweep_padded`` launch.  Single-process: returns the
        (possibly still device-sharded) padded result.  Process-spanning
        mesh: broadcasts the launch descriptor + payload so followers
        enter the same collective, and returns the all-gathered host
        (k_pad, e, 2) array."""
        if not self._multiproc:
            return DS.sweep_padded(stack, epss, cfg, k_pad=k_pad,
                                   mesh=self.mesh)
        from jax.experimental import multihost_utils as MH
        trailing = stack.shape[1:]
        hdr = np.zeros(self.HDR_LEN, np.int64)
        hdr[0], hdr[1], hdr[2], hdr[3] = (
            self.OP_LAUNCH, stack.shape[0], k_pad, stack.ndim)
        hdr[4 + (3 - len(trailing)):7] = trailing
        hdr[7] = len(epss)
        with self._launch_lock:
            MH.broadcast_one_to_all(hdr)
            # both sides consume the broadcast copies, so leader and
            # followers feed byte-identical inputs to the collective
            stack = np.asarray(MH.broadcast_one_to_all(
                np.ascontiguousarray(stack, np.float32)))
            epss = np.asarray(MH.broadcast_one_to_all(
                np.ascontiguousarray(epss, np.float32)))
            out = DS.sweep_padded(stack, epss, cfg, k_pad=k_pad,
                                  mesh=self.mesh)
            return DS.gather_rows(out)

    def _follower_loop(self) -> None:
        """Mirror the leader's header stream: join every collective
        launch with the broadcast payload until shutdown."""
        import traceback
        from jax.experimental import multihost_utils as MH
        try:
            while True:
                hdr = np.asarray(MH.broadcast_one_to_all(
                    np.zeros(self.HDR_LEN, np.int64)))
                if int(hdr[0]) == self.OP_SHUTDOWN:
                    return
                k, k_pad, rank = int(hdr[1]), int(hdr[2]), int(hdr[3])
                trailing = tuple(int(d) for d in hdr[4 + (3 - (rank - 1)):7])
                stack = np.asarray(MH.broadcast_one_to_all(
                    np.zeros((k,) + trailing, np.float32)))
                epss = np.asarray(MH.broadcast_one_to_all(
                    np.zeros(int(hdr[7]), np.float32)))
                out = DS.sweep_padded(stack, epss, self.scfg.pcfg,
                                      k_pad=k_pad, mesh=self.mesh)
                DS.gather_rows(out)
                self._launches += 1
                self._rows_launched += k
                self._pad_rows += k_pad - k
                self._executables.add(self._sig(k_pad, trailing,
                                                len(epss), self.scfg.pcfg))
        except BaseException as exc:     # noqa: BLE001 -- must not die
            # a dead follower would wedge the leader's next collective;
            # record + surface the error loudly so serve() re-raises
            # instead of returning as if shutdown completed cleanly
            self._fabric_error = exc
            traceback.print_exc()
            raise

    def _process(self, batch: List[_Request]) -> None:
        self._batches += 1
        # 1. resolve the cross-request cache; group the misses by
        #    (slice shape, engine config) and dedup identical slices,
        #    unioning the error bounds each digest needs
        local: Dict[Tuple[tuple, float], np.ndarray] = {}
        need: Dict[tuple, dict] = {}
        for req in batch:
            # one sighting per REQUEST touching the digest (duplicates
            # within one request's stack don't count): the admission
            # policy caches a field only once >= admit_after requests
            # wanted it (concurrent in-batch requests count)
            for key in {it.key for it in req.items}:
                self.cache.record_sighting(key)
        for req in batch:
            for it in req.items:
                for ek in it.eps_keys:
                    if (it.key, ek) in local:
                        continue
                    row = self.cache.get(it.key, ek)
                    if row is not None:
                        local[(it.key, ek)] = row
                    else:
                        group = need.setdefault((it.x.shape, it.key[1]), {})
                        entry = group.setdefault(it.key, (it.x, set()))
                        entry[1].add(ek)
        # 2. ONE launch per (shape, config) group (eps unions wider than
        #    max_eps_per_launch are chunked)
        for (shape, cfg), digests in need.items():
            union = sorted({e for _, es in digests.values() for e in es})
            step = self.scfg.max_eps_per_launch
            for lo in range(0, len(union), step):
                self._launch(digests, union[lo:lo + step], cfg, local)
        # 3. complete every request from the batch-local rows
        for req in batch:
            try:
                req.future.set_result(self._finish(req, local))
            except Exception as exc:
                req.future.set_exception(exc)

    def _launch(self, digests: dict, eps_chunk: List[float],
                cfg: P.PredictorConfig,
                local: Dict[Tuple[tuple, float], np.ndarray]) -> None:
        order = list(digests)
        stack = np.stack([digests[key][0] for key in order])
        k = len(order)
        k_pad = _row_bucket(k)
        e_pad = _eps_bucket(len(eps_chunk))
        epss = np.asarray(
            eps_chunk + [eps_chunk[-1]] * (e_pad - len(eps_chunk)),
            np.float32)
        out = self._collective_sweep(stack, epss, cfg, k_pad)
        # scatter-back: ONE host transfer for the whole coalesced batch,
        # split into per-digest row blocks (pad rows dropped)
        blocks = DS.scatter_requests(out, [1] * k)
        for key, block in zip(order, blocks):
            for j, ek in enumerate(eps_chunk):
                # owned copy: a view would pin the whole (k_pad, e_pad, 2)
                # batch result in memory for the row's cache lifetime
                row = np.array(block[0, j])
                local[(key, ek)] = row
                self.cache.put(key, ek, row)
        self._launches += 1
        self._rows_launched += k
        self._pad_rows += k_pad - k
        self._executables.add(self._sig(k_pad, stack.shape[1:], e_pad, cfg))

    def _finish(self, req: _Request,
                local: Dict[Tuple[tuple, float], np.ndarray]):
        def rows_for(item: _Item) -> np.ndarray:
            return np.stack([local[(item.key, ek)] for ek in item.eps_keys])

        if req.kind == "featurize":
            return np.stack([rows_for(it) for it in req.items])
        if req.kind == "find_eb":
            gm = req.payload["grid_model"]
            feats = rows_for(req.items[0])                      # (e, 2)
            feat_cache = P.get_engine(gm.cfg).cached(
                req.payload["data"], features=feats, epss=gm.ebs)
            return UC.find_error_bound_for_cr(
                gm, req.payload["data"], req.payload["target_cr"],
                tol=req.payload["tol"], max_iters=req.payload["max_iters"],
                feat_cache=feat_cache)
        if req.kind == "best_compressor":
            feats = rows_for(req.items[0])                      # (1, 2)
            return UC.best_compressor(
                req.payload["models"], req.payload["data"],
                req.payload["eps"], feats=feats)
        raise ValueError(f"unknown request kind {req.kind!r}")
