"""Method registry: names -> servable methods, launchers -> wire ids.

The registry is the only place the serving platform learns what it can
serve.  ``SweepService`` takes one at construction (defaulting to
:func:`default_registry`) and routes every ``submit(name, ...)`` through
it; the queue/launch core itself contains zero method-specific branches.

Launcher wire ids
-----------------
Each distinct :class:`~repro.serve.method.Launcher` instance gets a
small integer id in REGISTRATION ORDER.  The id travels in the
leader/follower launch header (and the recovered fabric's KV launch
descriptors), so every process of a multi-process service must build
its registry with the same methods in the same order -- the same
lockstep-construction rule the collective fabric already imposes on
``ServiceConfig``.  The default registry satisfies it by construction.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.serve.method import (AdviseMethod, BestCompressorMethod,
                                FeaturizeMethod, FindEbMethod,
                                FindSettingMethod, KVGateMethod, Launcher,
                                QualityMethod, ServableMethod, SweepLauncher)


class MethodRegistry:
    """Name -> :class:`ServableMethod` map plus the launcher id space."""

    def __init__(self):
        self._methods: "Dict[str, ServableMethod]" = {}
        self._launchers: List[Launcher] = []

    def register(self, method: ServableMethod) -> ServableMethod:
        if not method.name:
            raise ValueError("servable method needs a non-empty name")
        if method.name in self._methods:
            raise ValueError(
                f"method {method.name!r} is already registered")
        if method.launcher not in self._launchers:
            self._launchers.append(method.launcher)
        self._methods[method.name] = method
        return method

    def get(self, name: str) -> ServableMethod:
        try:
            return self._methods[name]
        except KeyError:
            raise ValueError(
                f"unknown servable method {name!r}; registered: "
                f"{sorted(self._methods)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._methods

    def methods(self) -> Tuple[ServableMethod, ...]:
        return tuple(self._methods.values())

    def names(self) -> Tuple[str, ...]:
        return tuple(self._methods)

    def launcher_id(self, launcher: Launcher) -> int:
        return self._launchers.index(launcher)

    def launcher(self, gid: int) -> Launcher:
        return self._launchers[int(gid)]


def default_registry() -> MethodRegistry:
    """The built-in platform: the paper's three request kinds plus the
    streaming compression advisor over one shared sweep launcher, plus
    the serving engine's KV-cache gate, plus the ratio-quality frontier
    pair (UC3 ``find_setting`` riding the sweep launcher; the fused
    quality sweep on its own launcher).  A fresh instance per call --
    services never share mutable registry state.  Registration is
    APPEND-ONLY so launcher wire ids (sweep=0, int8cr=1, quality=2) are
    stable across platform growth: ``advise`` and ``find_setting`` reuse
    the sweep launcher; ``quality`` registers last."""
    reg = MethodRegistry()
    sweep = SweepLauncher()
    reg.register(FeaturizeMethod(sweep))
    reg.register(FindEbMethod(sweep))
    reg.register(BestCompressorMethod(sweep))
    reg.register(KVGateMethod())
    reg.register(AdviseMethod(sweep))
    reg.register(FindSettingMethod(sweep))
    reg.register(QualityMethod())
    return reg
