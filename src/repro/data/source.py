"""Out-of-core dataset sources: named variables -> fixed-budget chunks.

Every sweep entry point in this repo used to require the caller to hand
over a fully materialized in-memory ``(k, ...)`` array stack; real
scientific archives are multi-variable files far larger than device
memory.  This module is the ingestion half of the streaming refactor:
a :class:`DatasetSource` names its variables and serves any contiguous
row range of each one on demand, and :meth:`DatasetSource.chunks` turns
a variable into an iterator of fixed-budget row/slab chunks sized so no
chunk ever exceeds a caller-chosen byte budget.  The incremental sweep
driver (``repro.core.stream``) consumes exactly this contract.

Three backings:

* :class:`MemmapSource` -- the out-of-core path: a directory holding one
  raw binary per variable plus a ``manifest.json`` (shape/dtype/order).
  ``read_rows`` slices a ``np.memmap``, so only the requested rows are
  ever resident (the f32 launch copy of one chunk is the peak footprint
  even when the variable is 100x device memory).
* :class:`NpzSource` -- ``.npz`` convenience for datasets that fit in
  host memory (``np.load`` materializes a variable per access; the most
  recently touched variable is cached so chunk iteration doesn't re-read
  the archive per chunk).
* :class:`GeneratorSource` -- the existing ``data.scientific`` field
  generators as a virtual dataset: 2-D slice-stack variables are
  BIT-EQUAL to ``scientific.field_slices`` row for row (same key split,
  same z schedule) but generated per chunk, so a variable larger than
  host memory can be produced -- and written to disk by
  :func:`write_dataset` / ``tools/make_dataset.py`` -- without ever
  materializing it.

Rows are served as C-contiguous float32 (featurization casts to f32
anyway, and contiguous row bytes make the incremental content digest --
``serve.method.StreamingDigest`` -- equal to the resident-array
``slice_digest``).  On-disk dtype may be float64: converting the chunk
on read is exactly the host-side ingest work a real archive costs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import zlib
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

MANIFEST = "manifest.json"
_FORMAT_VERSION = 1


class StreamingDigest:
    """Incremental content digest of a variable fed as row chunks.

    The serving layer keys its cross-request :class:`~repro.serve.
    sweep_service.FeatureCache` on ``serve.method.slice_digest`` -- a
    sha1 of the array's C-order f32 bytes plus its shape -- which
    requires the full f32 buffer resident.  This class computes the
    IDENTICAL digest from chunked reads: row chunks are C-contiguous
    along axis 0, so hashing each chunk's f32 bytes in order reproduces
    the full buffer's byte stream, and the shape suffix is reconstructed
    from the accumulated row count.  ``slice_digest(x)`` delegates here
    (one implementation, zero drift), so an out-of-core variable's cache
    key can be computed without ever materializing the variable.
    """

    def __init__(self):
        self._h = hashlib.sha1()
        self._rows = 0
        self._tail: Optional[Tuple[int, ...]] = None

    def update(self, chunk) -> "StreamingDigest":
        """Absorb the next row chunk (cast/copied to C-order f32 exactly
        like ``slice_digest``); chunks must share a trailing shape."""
        arr = np.ascontiguousarray(np.asarray(chunk, np.float32))
        if arr.ndim == 0:
            raise ValueError("StreamingDigest needs rows, got a scalar")
        if self._tail is None:
            self._tail = arr.shape[1:]
        elif arr.shape[1:] != self._tail:
            raise ValueError(
                f"chunk trailing shape {arr.shape[1:]} != first chunk's "
                f"{self._tail}")
        self._h.update(arr.tobytes())
        self._rows += arr.shape[0]
        return self

    @property
    def rows(self) -> int:
        return self._rows

    def digest(self) -> str:
        """The hex digest so far: equal to ``slice_digest`` of the
        concatenation of every chunk absorbed.  Non-destructive -- more
        chunks may follow."""
        if self._tail is None:
            raise ValueError("StreamingDigest.digest() before any update()")
        h = self._h.copy()
        h.update(str((self._rows,) + self._tail).encode())
        return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class VariableMeta:
    """Shape/dtype of one named variable; ``shape[0]`` is the row axis
    the sweep layer chunks and shards over."""
    name: str
    shape: Tuple[int, ...]
    dtype: str                         # on-disk dtype ("float32"/"float64")

    @property
    def rows(self) -> int:
        return int(self.shape[0])

    @property
    def row_shape(self) -> Tuple[int, ...]:
        return tuple(self.shape[1:])

    @property
    def row_nbytes_f32(self) -> int:
        """f32 bytes of ONE row -- the unit chunk budgets are charged in
        (chunks are staged/launched as f32 regardless of disk dtype)."""
        return 4 * int(np.prod(self.row_shape, dtype=np.int64))

    @property
    def nbytes_f32(self) -> int:
        return self.rows * self.row_nbytes_f32


def rows_per_chunk(meta: VariableMeta, budget_bytes: int) -> int:
    """Rows of ``meta`` fitting a ``budget_bytes`` f32 chunk (>= 1: a
    single row is the indivisible unit even when it alone exceeds the
    budget -- the caller's device must hold at least one row)."""
    if budget_bytes <= 0:
        raise ValueError(f"chunk budget must be positive, got {budget_bytes}")
    return max(1, min(meta.rows, budget_bytes // max(meta.row_nbytes_f32, 1)))


class DatasetSource:
    """Named variables -> on-demand contiguous row ranges.

    Subclasses implement :meth:`variables`, :meth:`meta`, and
    :meth:`read_rows`; chunk iteration, budget math, and whole-variable
    reads are shared here.
    """

    def variables(self) -> Tuple[str, ...]:
        raise NotImplementedError

    def meta(self, name: str) -> VariableMeta:
        raise NotImplementedError

    def read_rows(self, name: str, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of variable ``name`` as a C-contiguous float32
        ``(hi - lo,) + row_shape`` array (a fresh chunk copy the caller
        may donate to a device launch)."""
        raise NotImplementedError

    # -- shared conveniences -------------------------------------------

    def read(self, name: str) -> np.ndarray:
        """The whole variable (in-memory reference path; tests/benches)."""
        return self.read_rows(name, 0, self.meta(name).rows)

    def chunk_rows(self, name: str, budget_bytes: int) -> int:
        return rows_per_chunk(self.meta(name), budget_bytes)

    def chunks(self, name: str, *, budget_bytes: Optional[int] = None,
               rows: Optional[int] = None,
               ) -> Iterator[Tuple[int, np.ndarray]]:
        """Yield ``(lo, chunk)`` pairs covering variable ``name`` in
        order: every chunk has ``rows`` rows (from ``budget_bytes`` when
        not given explicitly) except a possibly-ragged final one.
        Chunk boundaries depend only on (k, rows), so every process of a
        multi-process stream iterates the same chunk schedule."""
        meta = self.meta(name)
        if rows is None:
            if budget_bytes is None:
                raise ValueError("chunks() needs rows= or budget_bytes=")
            rows = rows_per_chunk(meta, budget_bytes)
        if rows < 1:
            raise ValueError(f"chunk rows must be >= 1, got {rows}")
        for lo in range(0, meta.rows, rows):
            hi = min(lo + rows, meta.rows)
            yield lo, self.read_rows(name, lo, hi)

    def _check_range(self, meta: VariableMeta, lo: int, hi: int) -> None:
        if not (0 <= lo <= hi <= meta.rows):
            raise ValueError(
                f"rows [{lo}, {hi}) out of range for variable "
                f"{meta.name!r} with {meta.rows} rows")


def _as_f32_rows(block: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(block, np.float32))


# ---------------------------------------------------------------------------
# File-backed sources
# ---------------------------------------------------------------------------


class MemmapSource(DatasetSource):
    """Raw-binary dataset directory (the out-of-core backing).

    Layout: ``<dir>/manifest.json`` mapping variable names to
    ``{"shape", "dtype", "file"}`` plus one C-order raw binary per
    variable.  ``read_rows`` opens the file as ``np.memmap`` once and
    slices it per call, so a chunk read touches only that chunk's bytes.
    """

    def __init__(self, path: str):
        self.path = str(path)
        mf = os.path.join(self.path, MANIFEST)
        if not os.path.exists(mf):
            raise FileNotFoundError(
                f"{self.path!r} is not a memmap dataset (no {MANIFEST}); "
                "write one with tools/make_dataset.py or data.source."
                "write_dataset")
        with open(mf) as f:
            manifest = json.load(f)
        if manifest.get("format_version") != _FORMAT_VERSION:
            raise ValueError(
                f"unsupported dataset format_version "
                f"{manifest.get('format_version')!r} in {mf}")
        self._vars: Dict[str, dict] = dict(manifest["variables"])
        self._maps: Dict[str, np.memmap] = {}

    def variables(self) -> Tuple[str, ...]:
        return tuple(self._vars)

    def meta(self, name: str) -> VariableMeta:
        spec = self._vars[name]
        return VariableMeta(name, tuple(int(s) for s in spec["shape"]),
                            str(spec["dtype"]))

    def _map(self, name: str) -> np.memmap:
        mm = self._maps.get(name)
        if mm is None:
            spec = self._vars[name]
            mm = self._maps[name] = np.memmap(
                os.path.join(self.path, spec["file"]), mode="r",
                dtype=np.dtype(spec["dtype"]),
                shape=tuple(int(s) for s in spec["shape"]))
        return mm

    def read_rows(self, name: str, lo: int, hi: int) -> np.ndarray:
        self._check_range(self.meta(name), lo, hi)
        return _as_f32_rows(self._map(name)[lo:hi])


class NpzSource(DatasetSource):
    """``.npz`` dataset (host-memory convenience backing).

    ``np.load`` materializes a whole variable per archive access; the
    most recently read variable is cached so per-chunk iteration costs
    one decode, not one per chunk.  For datasets that do not fit host
    memory use :class:`MemmapSource`.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._npz = np.load(self.path)
        self._cached: Tuple[Optional[str], Optional[np.ndarray]] = (None, None)

    def variables(self) -> Tuple[str, ...]:
        return tuple(self._npz.files)

    def meta(self, name: str) -> VariableMeta:
        if name != self._cached[0]:
            self._cached = (name, self._npz[name])
        arr = self._cached[1]
        return VariableMeta(name, tuple(arr.shape), str(arr.dtype))

    def read_rows(self, name: str, lo: int, hi: int) -> np.ndarray:
        meta = self.meta(name)               # fills the cache
        self._check_range(meta, lo, hi)
        return _as_f32_rows(self._cached[1][lo:hi])


def open_dataset(path: str) -> DatasetSource:
    """Open a dataset written by :func:`write_dataset`: a ``.npz`` file
    or a memmap manifest directory."""
    if os.path.isdir(path):
        return MemmapSource(path)
    if path.endswith(".npz"):
        return NpzSource(path)
    raise ValueError(
        f"{path!r} is neither a dataset directory nor a .npz archive")


# ---------------------------------------------------------------------------
# Generator-backed source (data.scientific as a virtual dataset)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FieldVariable:
    """One synthetic variable: ``count`` rows of a named
    ``data.scientific`` field.  ``shape=(n,)`` (or an int) makes rows
    (n, n) 2-D slices bit-equal to ``scientific.field_slices``;
    ``shape=(d, m, n)`` makes rows independent (d, m, n) volumes (a
    rank-4 variable) via ``scientific.volume`` with a per-row seed."""
    field: str
    count: int
    shape: Tuple[int, ...]
    seed: int = 0

    def __post_init__(self):
        shape = self.shape
        if isinstance(shape, int):
            shape = (int(shape),)
        object.__setattr__(self, "shape", tuple(int(s) for s in shape))
        if len(self.shape) not in (1, 3):
            raise ValueError(
                f"FieldVariable shape must be (n,) for 2-D slices or "
                f"(d, m, n) for volumes, got {self.shape}")

    @property
    def row_shape(self) -> Tuple[int, ...]:
        n = self.shape[0]
        return (n, n) if len(self.shape) == 1 else self.shape


class GeneratorSource(DatasetSource):
    """``data.scientific`` generators as a chunk-addressable dataset.

    2-D slice variables reproduce ``scientific.field_slices(field,
    count, seed, n)`` EXACTLY (same ``PRNGKey`` split over the full
    count, same ``linspace(0, pi, count)`` z schedule) but generate only
    the requested row range -- so a multi-gigabyte variable can be
    streamed or written to disk chunk by chunk with a bounded footprint.
    """

    def __init__(self, variables: Sequence[FieldVariable]):
        self._vars: Dict[str, FieldVariable] = {}
        for v in variables:
            key = self.variable_name(v)
            if key in self._vars:
                raise ValueError(f"duplicate generated variable {key!r}")
            self._vars[key] = v

    @staticmethod
    def variable_name(v: FieldVariable) -> str:
        return v.field if len(v.shape) == 1 else v.field + "-vol"

    def variables(self) -> Tuple[str, ...]:
        return tuple(self._vars)

    def meta(self, name: str) -> VariableMeta:
        v = self._vars[name]
        return VariableMeta(name, (v.count,) + v.row_shape, "float32")

    def read_rows(self, name: str, lo: int, hi: int) -> np.ndarray:
        self._check_range(self.meta(name), lo, hi)
        v = self._vars[name]
        if lo == hi:
            return np.zeros((0,) + v.row_shape, np.float32)
        if len(v.shape) == 1:
            return _as_f32_rows(generate_field_rows(
                v.field, v.count, lo, hi, n=v.shape[0], seed=v.seed))
        from repro.data import scientific
        return _as_f32_rows(np.stack(
            [np.asarray(scientific.volume(v.field, v.shape, seed=v.seed + i))
             for i in range(lo, hi)]))


def generate_field_rows(field: str, count: int, lo: int, hi: int, *,
                        n: Optional[int] = None, seed: int = 0) -> np.ndarray:
    """Rows [lo, hi) of ``scientific.field_slices(field, count, seed,
    n)``, bit-equal to slicing the full stack, without generating the
    other rows: the PRNG keys are split for the FULL count and only the
    requested indices are evaluated."""
    import jax
    import jax.numpy as jnp
    from repro.data import scientific

    spec = scientific.FIELDS[field]
    n = n or spec.n
    keys = jax.random.split(
        jax.random.PRNGKey(zlib.crc32(field.encode()) % (2**31) + seed),
        count)
    zs = jnp.linspace(0.0, jnp.pi, count)
    if lo == hi:
        return np.zeros((0, n, n), np.float32)
    return np.stack([np.asarray(spec.generator(keys[i], n, float(zs[i])))
                     for i in range(lo, hi)])


# ---------------------------------------------------------------------------
# Dataset writer (tools/make_dataset.py is the CLI wrapper)
# ---------------------------------------------------------------------------


def write_dataset(path: str, source: DatasetSource, *,
                  fmt: str = "memmap", dtype: str = "float32",
                  budget_bytes: int = 64 << 20,
                  seed: Optional[int] = None) -> str:
    """Copy every variable of ``source`` to a file-backed dataset.

    ``fmt="memmap"`` writes ``<path>/manifest.json`` + one raw C-order
    binary per variable, chunk by chunk -- peak memory is one chunk even
    for variables far larger than host memory.  ``fmt="npz"`` writes a
    single (uncompressed) archive and is the small-dataset convenience.
    ``dtype="float64"`` upcasts on write so streaming reads pay the
    realistic f64->f32 ingest conversion of real archives.  Returns the
    dataset path (``fmt="npz"`` appends ``.npz`` when missing).
    """
    np_dtype = np.dtype(dtype)
    if np_dtype not in (np.dtype(np.float32), np.dtype(np.float64)):
        raise ValueError(f"dtype must be float32/float64, got {dtype}")
    if fmt == "npz":
        if not path.endswith(".npz"):
            path = path + ".npz"
        arrs = {name: source.read(name).astype(np_dtype)
                for name in source.variables()}
        np.savez(path, **arrs)
        return path
    if fmt != "memmap":
        raise ValueError(f"fmt must be 'memmap' or 'npz', got {fmt!r}")
    os.makedirs(path, exist_ok=True)
    manifest = {"format_version": _FORMAT_VERSION, "seed": seed,
                "variables": {}}
    for name in source.variables():
        meta = source.meta(name)
        fn = name.replace("/", "_") + ".bin"
        mm = np.memmap(os.path.join(path, fn), mode="w+", dtype=np_dtype,
                       shape=meta.shape)
        for lo, chunk in source.chunks(name, budget_bytes=budget_bytes):
            mm[lo:lo + chunk.shape[0]] = chunk.astype(np_dtype)
        mm.flush()
        del mm
        manifest["variables"][name] = {
            "shape": list(meta.shape), "dtype": str(np_dtype), "file": fn}
    with open(os.path.join(path, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    return path
