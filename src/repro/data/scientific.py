"""Synthetic stand-ins for the paper's six scientific datasets.

The container is offline, so SDRBench itself is unavailable; each generator
mimics the qualitative structure the paper relies on (spatial correlation
profile, heterogeneity, value range) so that every table/figure has a
corresponding bench row.  Slices vary smoothly along the slicing axis, so a
*field* yields a stack of correlated-but-distinct 2-D slices -- exactly the
training population the paper's per-field regressions use.

Dimensions follow Table 1 (reduced by default for CI speed; full sizes via
``full_size=True``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp

import zlib

from repro.data import gaussian


def _fbm_spectrum_field(key, n: int, slope: float, seed_phase: float = 0.0):
    """Power-law (turbulence-like) random field: |k|^-slope spectrum."""
    freq = jnp.fft.fftfreq(n) * n
    k2 = freq[:, None] ** 2 + freq[None, :] ** 2
    spec = jnp.where(k2 > 0, k2 ** (-slope / 2.0), 0.0)
    kr, ki = jax.random.split(key)
    noise = jax.random.normal(kr, (n, n)) + 1j * jax.random.normal(ki, (n, n))
    f = jnp.fft.ifft2(noise * jnp.sqrt(spec)).real
    return f / jnp.maximum(jnp.std(f), 1e-9)


def miranda_like(key, n: int = 384, z: float = 0.0) -> jnp.ndarray:
    """Multicomponent-flow density: smooth turbulence + sharp material
    interface (tanh front) whose position drifts with slice index z."""
    k1, k2 = jax.random.split(key)
    # complexity sweeps along the slicing axis: smooth laminar slices at one
    # end, fine-grained turbulent mixing at the other (wide CR range, as the
    # real Miranda z-stack exhibits).
    mix = 0.5 - 0.5 * jnp.cos(z)            # 0 .. 1
    slope = 4.0 - 1.8 * mix                  # smooth -> rough spectrum
    turb = _fbm_spectrum_field(k1, n, slope=slope)
    ii = jnp.linspace(-1, 1, n)
    front = jnp.tanh((ii[:, None] - 0.3 * jnp.sin(3 * z) +
                      (0.05 + 0.4 * mix) * turb) * (2.0 + 12.0 * mix))
    return (1.5 + 0.5 * front + (0.05 + 0.45 * mix) * turb).astype(jnp.float32)


def cesm_cloud_like(key, n: int = 512, z: float = 0.0) -> jnp.ndarray:
    """Cloud fraction: intermittent [0,1] field with large clear patches."""
    k1, _ = jax.random.split(key)
    mix = 0.5 - 0.5 * jnp.cos(z)
    base = _fbm_spectrum_field(k1, n, slope=3.4 - 1.6 * mix)
    sharp = 2.0 + 10.0 * mix
    cloud = jax.nn.sigmoid((base - 0.4 + 0.3 * jnp.cos(2 * z)) * sharp)
    return jnp.clip(cloud, 0.0, 1.0).astype(jnp.float32)


def hurricane_like(key, n: int = 500, z: float = 0.0) -> jnp.ndarray:
    """East-west wind with a vortex: solid-body core + 1/r tail + noise."""
    k1, _ = jax.random.split(key)
    ii = jnp.linspace(-1, 1, n)
    x, y = jnp.meshgrid(ii, ii, indexing="ij")
    cx, cy = 0.25 * jnp.sin(z), 0.25 * jnp.cos(z)
    r = jnp.sqrt((x - cx) ** 2 + (y - cy) ** 2) + 1e-3
    vtheta = jnp.where(r < 0.2, r / 0.2, 0.2 / r) * 40.0
    u = -vtheta * (y - cy) / r
    mix = 0.5 - 0.5 * jnp.cos(z)
    noise = (0.5 + 6.0 * mix) * _fbm_spectrum_field(k1, n, slope=3.6 - 1.4 * mix)
    return (u + noise).astype(jnp.float32)


def scale_letkf_like(key, n: int = 600, z: float = 0.0) -> jnp.ndarray:
    """Rainfall-simulation wind: strong multiscale heterogeneity (the
    paper's hardest 2-D case) -- mixed small/large-scale features."""
    k1, k2, k3 = jax.random.split(key, 3)
    mix = 0.5 - 0.5 * jnp.cos(z)
    large = gaussian.grf_sample(k1, n, 96.0)
    small = gaussian.grf_sample(k2, n, 4.0 + 12.0 * (1 - mix))
    w = gaussian._spatial_weight(k3, n)
    return (10.0 * large + (1.0 + 7.0 * mix) * w * small
            + 3.0 * mix * small * large).astype(jnp.float32)


def nyx_like(key, n: int = 512, z: float = 0.0) -> jnp.ndarray:
    """Cosmology baryon velocity: filamentary, heavy-tailed."""
    k1, k2 = jax.random.split(key)
    mix = 0.5 - 0.5 * jnp.cos(z)
    base = _fbm_spectrum_field(k1, n, slope=3.2 - 1.2 * mix)
    fil = _fbm_spectrum_field(k2, n, slope=3.5)
    return (1e6 * jnp.tanh(base) * (1.0 + (0.1 + mix) * jnp.abs(fil))).astype(jnp.float32)


def qmcpack_like(key, n: int = 96, z: float = 0.0) -> jnp.ndarray:
    """Electronic orbital: smooth oscillatory standing waves + envelope."""
    k1, _ = jax.random.split(key)
    ii = jnp.linspace(0, 1, n)
    x, y = jnp.meshgrid(ii, ii, indexing="ij")
    mix = 0.5 - 0.5 * jnp.cos(z)
    kx, ky = 4 + 14 * mix, 5 + 11 * mix
    wave = jnp.sin(2 * jnp.pi * kx * x) * jnp.sin(2 * jnp.pi * ky * y)
    env = jnp.exp(-((x - 0.5) ** 2 + (y - 0.5) ** 2) * 6.0)
    noise = (0.01 + 0.15 * mix) * _fbm_spectrum_field(k1, n, slope=3.0)
    return (wave * env + noise).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    name: str
    generator: Callable
    n: int                 # slice edge (reduced-size default)
    full_n: int            # paper's slice edge
    slices: int            # number of 2-D slices available
    eps: float             # the paper's error bound for this field


FIELDS: Dict[str, FieldSpec] = {
    "miranda-vx":   FieldSpec("miranda-vx", miranda_like, 384, 384, 64, 1e-5),
    "miranda-de":   FieldSpec("miranda-de", miranda_like, 384, 384, 64, 1e-5),
    "cesm-cloud":   FieldSpec("cesm-cloud", cesm_cloud_like, 512, 1800, 48, 1e-5),
    "hurricane-u":  FieldSpec("hurricane-u", hurricane_like, 500, 500, 48, 1e-2),
    "scale-u":      FieldSpec("scale-u", scale_letkf_like, 600, 1200, 48, 1e-3),
    "scale-pressure": FieldSpec("scale-pressure", scale_letkf_like, 600, 1200, 48, 1e-3),
    "nyx-vx":       FieldSpec("nyx-vx", nyx_like, 512, 512, 48, 1e-2),
    "qmcpack":      FieldSpec("qmcpack", qmcpack_like, 96, 96, 64, 1e-2),
}


def field_slices(name: str, count: int | None = None, seed: int = 0,
                 n: int | None = None) -> jnp.ndarray:
    """(count, n, n) stack of 2-D slices for a named field."""
    spec = FIELDS[name]
    count = count or spec.slices
    n = n or spec.n
    keys = jax.random.split(
        jax.random.PRNGKey(zlib.crc32(name.encode()) % (2**31) + seed), count)
    zs = jnp.linspace(0.0, jnp.pi, count)
    # vary per-slice structure parameter z; different key per slice
    return jnp.stack([spec.generator(keys[i], n, float(zs[i]))
                      for i in range(count)])


def volume(name: str, shape=(64, 96, 96), seed: int = 0) -> jnp.ndarray:
    """A 3-D volume assembled from smoothly varying slices (for HOSVD/
    TTHRESH experiments, paper section 4.5).

    Returns exactly ``shape``: slabs are generated at ``max(shape[1:])``
    and cropped, so non-square requests like (4, 32, 64) no longer come
    back silently truncated to (4, 32, 32).
    """
    spec = FIELDS[name]
    d, n = shape[0], max(shape[1:])
    keys = jax.random.split(
        jax.random.PRNGKey(zlib.crc32(name.encode()) % (2**31) + 7 + seed), 1)
    zs = jnp.linspace(0.0, jnp.pi, d)
    slabs = [spec.generator(keys[0], n, float(z)) for z in zs]
    vol = jnp.stack(slabs)[:, : shape[1], : shape[2]]
    return vol
