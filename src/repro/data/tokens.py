"""Deterministic synthetic token streams for LM training.

Markov-chain token generator with per-step seeding: step N's batch is a
pure function of (seed, N), which is what makes checkpoint-restart
deterministic (the restarted loop regenerates the exact same stream).
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@partial(jax.jit, static_argnames=("batch", "seq", "vocab"))
def _batch(key, batch: int, seq: int, vocab: int) -> jnp.ndarray:
    """Structured (learnable) token stream: noisy arithmetic progressions."""
    k1, k2, k3 = jax.random.split(key, 3)
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    stride = jax.random.randint(k2, (batch, 1), 1, 17)
    base = (start + stride * jnp.arange(seq)[None, :]) % vocab
    noise = jax.random.bernoulli(k3, 0.1, (batch, seq))
    rand = jax.random.randint(jax.random.fold_in(k3, 1), (batch, seq), 0, vocab)
    return jnp.where(noise, rand, base).astype(jnp.int32)


def make_data_iter(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    """step -> batch dict (tokens/labels [+frames/mrope]) -- deterministic."""
    def it(step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        toks = _batch(key, batch, seq + 1, cfg.vocab_size)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "encdec":
            out["frames"] = jax.random.normal(
                jax.random.fold_in(key, 7),
                (batch, cfg.encoder_frames, cfg.d_model), jnp.bfloat16) \
                .astype(jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            out["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(seq, dtype=jnp.int32), (3, batch, seq))
        return out
    return it
