"""Gaussian random-field sample generation (paper section 2.3.2).

2-D stationary Gaussian samples with squared-exponential correlation
Sigma(xi, xj) = sigma^2 exp(-|xi-xj|^2 / a^2), synthesized spectrally:
white noise is shaped in the Fourier domain by the square root of the
power spectrum of the SE kernel (circulant embedding on the periodic
torus -- exact for ranges << domain).

Four sample types, from simplest to most complex (X = sum_l w_l U_l):
  1. single correlation range (L=1)
  2. L=3, scalar weights, fixed ranges
  3. L=3, spatial Gaussian-bump weights, fixed ranges
  4. L=3, spatial weights, random ranges
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

DEFAULT_SIZE = 1028  # paper uses 1028 x 1028


def _se_spectrum(n: int, a: float) -> jnp.ndarray:
    """Power spectrum of the squared-exponential kernel on an n x n torus.

    SE kernel k(r) = exp(-r^2/a^2) has (continuous) spectrum
    S(w) ~ exp(-a^2 w^2 / 4); we evaluate on the discrete frequency grid.
    """
    freq = jnp.fft.fftfreq(n) * n          # integer frequencies
    w2 = freq[:, None] ** 2 + freq[None, :] ** 2
    spec = jnp.exp(-(jnp.pi * a / n) ** 2 * w2)
    return spec


@partial(jax.jit, static_argnames=("n",))
def grf_sample(key: jax.Array, n: int, a: float | jnp.ndarray) -> jnp.ndarray:
    """One n x n sample with SE correlation range ``a`` (unit variance)."""
    spec = _se_spectrum(n, a)
    kr, ki = jax.random.split(key)
    noise = (jax.random.normal(kr, (n, n)) + 1j * jax.random.normal(ki, (n, n)))
    field = jnp.fft.ifft2(noise * jnp.sqrt(spec)).real
    field = field * (n / jnp.sqrt(jnp.maximum(jnp.sum(spec), 1e-30)))
    return field


def _spatial_weight(key: jax.Array, n: int) -> jnp.ndarray:
    """2-D Gaussian-bump weight in [0, 1] with random mean, fixed spread."""
    mu = jax.random.uniform(key, (2,), minval=0.2 * n, maxval=0.8 * n)
    omega = (0.15 * n) ** 2
    ii = jnp.arange(n, dtype=jnp.float32)
    g = jnp.exp(-((ii[:, None] - mu[0]) ** 2 + (ii[None, :] - mu[1]) ** 2)
                / (2 * omega))
    return g


def sample_type1(key, n: int = DEFAULT_SIZE, a: float = 32.0) -> jnp.ndarray:
    return grf_sample(key, n, a)


def sample_type2(key, n: int = DEFAULT_SIZE,
                 ranges: Sequence[float] = (8.0, 32.0, 128.0),
                 weights: Sequence[float] = (0.6, 0.9, 1.2)) -> jnp.ndarray:
    keys = jax.random.split(key, len(ranges))
    parts = [w * grf_sample(k, n, a) for k, a, w in zip(keys, ranges, weights)]
    return sum(parts)


def sample_type3(key, n: int = DEFAULT_SIZE,
                 ranges: Sequence[float] = (8.0, 32.0, 128.0)) -> jnp.ndarray:
    keys = jax.random.split(key, 2 * len(ranges))
    out = jnp.zeros((n, n))
    for i, a in enumerate(ranges):
        u = grf_sample(keys[2 * i], n, a)
        w = _spatial_weight(keys[2 * i + 1], n)
        out = out + w * u
    return out


def sample_type4(key, n: int = DEFAULT_SIZE) -> jnp.ndarray:
    k0, key = jax.random.split(key)
    # mixture of short / medium / long ranges, drawn randomly
    los = jnp.array([4.0, 16.0, 64.0])
    his = jnp.array([16.0, 64.0, 256.0])
    u = jax.random.uniform(k0, (3,))
    ranges = los + u * (his - los)
    keys = jax.random.split(key, 6)
    out = jnp.zeros((n, n))
    for i in range(3):
        f = grf_sample(keys[2 * i], n, ranges[i])
        w = _spatial_weight(keys[2 * i + 1], n)
        out = out + w * f
    return out


SAMPLERS = {1: sample_type1, 2: sample_type2, 3: sample_type3, 4: sample_type4}


def sample_batch(sample_type: int, count: int, n: int = DEFAULT_SIZE,
                 seed: int = 0, **kw) -> jnp.ndarray:
    """(count, n, n) stack of independent samples of the given type.

    For type 1 the correlation range is swept across samples (the paper's
    type-1 set varies ``a`` -- that is what creates the wide CR range that
    section 4.1 notes makes SZ's type-1 errors larger).
    """
    keys = jax.random.split(jax.random.PRNGKey(seed), count)
    outs = []
    for i in range(count):
        if sample_type == 1 and "a" not in kw:
            a = 4.0 * (2.0 ** (5.0 * i / max(count - 1, 1)))  # 4 .. 128
            outs.append(sample_type1(keys[i], n, a))
        else:
            outs.append(SAMPLERS[sample_type](keys[i], n, **kw))
    return jnp.stack(outs)
