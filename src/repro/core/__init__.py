"""Paper core: compressor-agnostic CR predictors + statistical models.

Public API:
    predictors.features_2d / features_3d / svd_trunc / quantized_entropy
    regression.LinearCRModel / SplineCRModel / lasso_importance
    pipeline.CRPredictor / kfold_evaluate
    usecases.EbGridModel / find_error_bound_for_cr / best_compressor
"""
from repro.core import predictors, regression, pipeline, usecases  # noqa: F401
