"""Two-step CR-prediction pipeline + evaluation (paper sections 3.2-3.3).

Step (1): compressor-agnostic predictors per slice (repro.core.predictors).
Step (2): per-(compressor, field) regression trained on observed CRs.

Evaluation follows Algorithm 1: k-fold cross-validation, out-of-sample
median absolute percentage error (MedAPE) with 10%/90% quantiles, and the
linear correlation between true and predicted CRs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import predictors as P
from repro.core import regression as R


@dataclasses.dataclass
class EvalResult:
    medape: float            # median over folds of per-fold median APE (%)
    medape_q10: float
    medape_q90: float
    correlation: float       # pooled over all out-of-sample predictions
    true_cr: np.ndarray
    pred_cr: np.ndarray

    def __repr__(self) -> str:  # pragma: no cover
        return (f"EvalResult(medape={self.medape:.2f}% "
                f"[{self.medape_q10:.2f},{self.medape_q90:.2f}], "
                f"corr={self.correlation:.3f}, n={len(self.true_cr)})")


def ape(true: np.ndarray, pred: np.ndarray) -> np.ndarray:
    return 100.0 * np.abs(true - pred) / np.abs(true)


def featurize_slices(
    slices: jnp.ndarray,
    eps: float,
    cfg: P.PredictorConfig = P.PredictorConfig(),
    *,
    sharded: bool | None = None,
    mesh=None,
) -> jnp.ndarray:
    """(k, m, n) stack of 2-D slices -- or (k, d, m, n) stack of volumes
    -- -> (k, 2) predictor matrix.

    Routed through the batched sweep engine (single-eb column): one
    batched Gram + eigvalsh for all k slices instead of k separate solves
    (volumes: one batched Gram + eigvalsh per HOSVD mode).  Under an
    active mesh (or explicit ``mesh``) the slice axis is sharded across
    devices; ``sharded=False`` pins the single-device path.
    """
    return P.get_engine(cfg).features(slices, eps, sharded=sharded, mesh=mesh)


def featurize_sweep(
    slices: jnp.ndarray,
    epss,
    cfg: P.PredictorConfig = P.PredictorConfig(),
    *,
    sharded: bool | None = None,
    mesh=None,
    gather: bool = True,
) -> jnp.ndarray:
    """(k, m, n) slice stack or (k, d, m, n) volume stack x (e,) error
    bounds -> (k, e, 2) predictor tensor in one pass over the data (see
    ``predictors.FeaturizationEngine``).

    Shards the slice axis over an active (or passed) mesh; ``gather=False``
    keeps the padded result sharded for distributed downstream stages.
    """
    return P.get_engine(cfg).sweep(slices, epss, sharded=sharded, mesh=mesh,
                                   gather=gather)


def kfold_evaluate(
    features: np.ndarray,
    cr: np.ndarray,
    model: str = "spline",
    k: int = 8,
    seed: int = 0,
) -> EvalResult:
    """Algorithm 1: k-fold CV of the CR regression; returns MedAPE stats."""
    features = np.asarray(features, np.float64)
    cr = np.asarray(cr, np.float64)
    n = len(cr)
    k = min(k, n)  # never more folds than points
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    fit = R.MODEL_REGISTRY[model]

    fold_medape, all_true, all_pred = [], [], []
    for f in folds:
        test_mask = np.zeros(n, bool)
        test_mask[f] = True
        x_tr, y_tr = features[~test_mask], cr[~test_mask]
        x_te, y_te = features[test_mask], cr[test_mask]
        m = fit(jnp.asarray(x_tr), jnp.asarray(y_tr))
        pred = np.asarray(m.predict(jnp.asarray(x_te)))
        fold_medape.append(float(np.median(ape(y_te, pred))))
        all_true.append(y_te)
        all_pred.append(pred)

    true = np.concatenate(all_true)
    pred = np.concatenate(all_pred)
    corr = float(np.corrcoef(true, pred)[0, 1]) if len(true) > 1 else 1.0
    med = np.asarray(fold_medape)
    return EvalResult(
        medape=float(np.quantile(med, 0.5)),
        medape_q10=float(np.quantile(med, 0.1)),
        medape_q90=float(np.quantile(med, 0.9)),
        correlation=corr,
        true_cr=true,
        pred_cr=pred,
    )


@dataclasses.dataclass
class CRPredictor:
    """A trained (compressor, field, error-bound) CR predictor.

    This is the deployable object used by the framework services
    (checkpointing, gradient compression, KV-cache gating).
    """
    model: object
    eps: float
    cfg: P.PredictorConfig = dataclasses.field(default_factory=P.PredictorConfig)
    ndim: int = 2

    @staticmethod
    def train(
        slices: jnp.ndarray,
        cr: jnp.ndarray,
        eps: float,
        model: str = "spline",
        cfg: P.PredictorConfig = P.PredictorConfig(),
        ndim: int = 2,
    ) -> "CRPredictor":
        if slices.ndim != ndim + 1:
            raise ValueError(
                f"CRPredictor.train(ndim={ndim}) expects a rank-{ndim + 1} "
                f"stack, got {slices.shape}")
        # both ranks route through the batched sweep engine (the 3-D path
        # dispatches to hosvd_trunc_batch -- no per-volume Python loop)
        feats = featurize_slices(slices, eps, cfg)
        return CRPredictor.train_from_features(feats, cr, eps, model, cfg, ndim)

    @staticmethod
    def train_from_features(
        feats: jnp.ndarray,
        cr: jnp.ndarray,
        eps: float,
        model: str = "spline",
        cfg: P.PredictorConfig = P.PredictorConfig(),
        ndim: int = 2,
    ) -> "CRPredictor":
        """Fit from a precomputed (k, 2) feature matrix -- the sweep-native
        training path (featurize the whole eb grid once, fit per eb)."""
        m = R.MODEL_REGISTRY[model](feats, jnp.asarray(cr))
        return CRPredictor(m, eps, cfg, ndim)

    def predict_from_features(self, feats: jnp.ndarray) -> jnp.ndarray:
        return self.model.predict(feats)

    def predict(self, slices: jnp.ndarray) -> jnp.ndarray:
        if slices.ndim != self.ndim + 1:
            raise ValueError(
                f"CRPredictor(ndim={self.ndim}).predict expects a "
                f"rank-{self.ndim + 1} stack, got {slices.shape}")
        feats = featurize_slices(slices, self.eps, self.cfg)
        return self.model.predict(feats)
