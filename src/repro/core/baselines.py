"""Prior CR-estimation methods the paper compares against (Table 5).

* ``block_sampling``  -- Tao et al. 2019b / Liang et al. 2019b: compress a
  small sample of blocks and extrapolate the ratio to the full field.
  Systematically *underestimates* CR (block boundaries break the
  decorrelation context and per-block coder overhead is amortized worse).
* ``lu_model``        -- Lu et al. 2018-style white-box SZ model: runs the
  prediction+quantization stage, then estimates the Huffman-coded size from
  a Gaussian fit to the quantization-code distribution (their key modelling
  assumption).  Systematically *overestimates* CR when codes are heavy-
  tailed, exactly the failure mode the paper reports.
* ``optzconfig_probe`` -- Underwood et al. 2022-style black-box surrogate:
  a piecewise-linear model of log CR(log eb) fitted from 2 warm-start probe
  compressions at neighbouring error bounds, evaluated at the target eb.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import compressors as C
from repro.compressors import lossless
from repro.compressors.sz import lorenzo_encode


def block_sampling(data: jnp.ndarray, eps: float, compressor: str = "sz2",
                   block: int = 32, frac: float = 0.05,
                   seed: int = 0) -> float:
    """Estimate CR by compressing ``frac`` of ``block x block`` tiles."""
    comp = C.get(compressor)
    m, n = data.shape
    bi, bj = m // block, n // block
    total = bi * bj
    k = max(1, int(total * frac))
    rng = np.random.default_rng(seed)
    idx = rng.choice(total, size=k, replace=False)
    sizes = 0
    raw = 0
    for t in idx:
        i, j = divmod(int(t), bj)
        tile = data[i * block:(i + 1) * block, j * block:(j + 1) * block]
        codes, aux = comp.encode(tile, eps)
        sizes += comp.size_bytes(codes, aux, eps)
        raw += tile.size * 4
    return raw / max(sizes, 1)


def lu_model(data: jnp.ndarray, eps: float) -> float:
    """White-box SZ CR model with the Gaussian-codes assumption."""
    codes = np.asarray(lorenzo_encode(data, eps)).reshape(-1)
    # Gaussian fit to the code distribution (Lu et al.'s assumption)
    mu, sigma = codes.mean(), max(codes.std(), 1e-6)
    # entropy of a *discretized gaussian* with that sigma
    h = 0.5 * np.log2(2 * np.pi * np.e * sigma * sigma) if sigma > 0.3 else 1.0
    h = max(h, 0.05)
    est_bytes = codes.size * h / 8.0 + 1024
    return data.size * 4 / est_bytes


def optzconfig_probe(train_slice: jnp.ndarray, eps: float,
                     compressor: str = "sz2",
                     probe_ratio: float = 4.0) -> float:
    """Warm-start piecewise-linear surrogate (Underwood et al. 2022).

    The surrogate is built from probe compressions of *previously seen*
    data of the same field (warm start) -- CR(log eb) on the training
    slice, log-interpolated at the target eb -- then applied to the new
    slice without running the compressor on it.  Its error therefore
    reflects slice-to-slice CR variation, the paper's Table 5 regime."""
    comp = C.get(compressor)
    lo, hi = eps / probe_ratio, eps * probe_ratio
    cr_lo = comp.cr(train_slice, lo)
    cr_hi = comp.cr(train_slice, hi)
    t = (np.log(eps) - np.log(lo)) / (np.log(hi) - np.log(lo))
    return float(np.exp((1 - t) * np.log(cr_lo) + t * np.log(cr_hi)))
