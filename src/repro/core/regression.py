"""Regression models for CR prediction (paper section 3.2), in pure JAX.

* ``LinearCRModel``  -- Eq. (1): log(CR) = a + b*log(qent) + c*log(svd/sigma)
                        + d * interaction, least squares.
* ``SplineCRModel``  -- Eq. (2): GAM with natural cubic splines (3 knots) per
                        predictor + tensor-product interaction, penalized LS.
* ``lasso_path``     -- LASSO (FISTA) for predictor-importance analysis
                        (Table 3 / Fig 8 analogues).

All models operate on standardized predictors and log(CR) targets, mirroring
the paper ("statistical predictors are standardized ... we consider the
logarithm of CRs").  R's lm/mgcv/glmnet are replaced by closed-form /
iterative JAX solvers (validated against scipy in tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Standardizer(NamedTuple):
    mean: jnp.ndarray
    std: jnp.ndarray

    @staticmethod
    def fit(x: jnp.ndarray) -> "Standardizer":
        return Standardizer(jnp.mean(x, axis=0), jnp.maximum(jnp.std(x, axis=0), 1e-8))

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return (x - self.mean) / self.std


# ---------------------------------------------------------------------------
# Linear model (Eq. 1)
# ---------------------------------------------------------------------------

def _linear_design(z: jnp.ndarray) -> jnp.ndarray:
    """[1, z1, z2, z1*z2] design from standardized predictors (n, 2)."""
    one = jnp.ones((z.shape[0], 1), z.dtype)
    inter = (z[:, 0] * z[:, 1])[:, None]
    return jnp.concatenate([one, z, inter], axis=1)


class LinearCRModel(NamedTuple):
    """log(CR) ~ a + b z1 + c z2 + d z1 z2 with standardized predictors."""
    std: Standardizer
    coef: jnp.ndarray          # (4,)

    @staticmethod
    def fit(features: jnp.ndarray, cr: jnp.ndarray, ridge: float = 1e-8) -> "LinearCRModel":
        std = Standardizer.fit(features)
        x = _linear_design(std(features))
        y = jnp.log(cr)
        xtx = x.T @ x + ridge * jnp.eye(x.shape[1])
        coef = jnp.linalg.solve(xtx, x.T @ y)
        return LinearCRModel(std, coef)

    def predict(self, features: jnp.ndarray) -> jnp.ndarray:
        x = _linear_design(self.std(features))
        return jnp.exp(x @ self.coef)

    def predict_log(self, features: jnp.ndarray) -> jnp.ndarray:
        return _linear_design(self.std(features)) @ self.coef


# ---------------------------------------------------------------------------
# Natural cubic spline basis (ESL section 5.2.1), K knots -> K basis funcs
# ---------------------------------------------------------------------------

def ncs_basis(x: jnp.ndarray, knots: jnp.ndarray) -> jnp.ndarray:
    """Natural cubic spline basis N(x): (n,) -> (n, K).

    N1 = 1, N2 = x, N_{k+2} = d_k - d_{K-1} with
    d_k(x) = ((x - xi_k)^3_+ - (x - xi_K)^3_+) / (xi_K - xi_k).
    """
    k = knots.shape[0]

    def d(j):
        num = jnp.maximum(x - knots[j], 0.0) ** 3 - jnp.maximum(x - knots[k - 1], 0.0) ** 3
        return num / (knots[k - 1] - knots[j])

    cols = [jnp.ones_like(x), x]
    d_last = d(k - 2)
    for j in range(k - 2):
        cols.append(d(j) - d_last)
    return jnp.stack(cols, axis=1)


def _quantile_knots(z: jnp.ndarray, num_knots: int) -> jnp.ndarray:
    qs = jnp.linspace(0.05, 0.95, num_knots)
    knots = jnp.quantile(z, qs)
    # Degenerate guard: strictly increasing knots.
    return knots + jnp.arange(num_knots) * 1e-6


def _spline_design(z: jnp.ndarray, knots1: jnp.ndarray, knots2: jnp.ndarray) -> jnp.ndarray:
    """GAM design: s(z1) + s(z2) + ti(z1, z2).

    Columns: [1, N1_nonconst(z1), N2_nonconst(z2), outer(ti-parts)].
    """
    b1 = ncs_basis(z[:, 0], knots1)          # (n, K)
    b2 = ncs_basis(z[:, 1], knots2)          # (n, K)
    smooth1 = b1[:, 1:]                       # drop shared intercept
    smooth2 = b2[:, 1:]
    # tensor-product interaction of the non-constant parts
    ti = (smooth1[:, :, None] * smooth2[:, None, :]).reshape(z.shape[0], -1)
    one = jnp.ones((z.shape[0], 1), z.dtype)
    return jnp.concatenate([one, smooth1, smooth2, ti], axis=1)


class SplineCRModel(NamedTuple):
    """GAM (Eq. 2): cubic splines + tensor-product interaction, 3 knots."""
    std: Standardizer
    knots1: jnp.ndarray
    knots2: jnp.ndarray
    coef: jnp.ndarray

    @staticmethod
    def fit(
        features: jnp.ndarray,
        cr: jnp.ndarray,
        num_knots: int = 3,
        ridge: float = 1e-4,
    ) -> "SplineCRModel":
        std = Standardizer.fit(features)
        z = std(features)
        knots1 = _quantile_knots(z[:, 0], num_knots)
        knots2 = _quantile_knots(z[:, 1], num_knots)
        x = _spline_design(z, knots1, knots2)
        y = jnp.log(cr)
        # Penalized LS; don't penalize intercept.
        pen = ridge * jnp.eye(x.shape[1]).at[0, 0].set(0.0)
        coef = jnp.linalg.solve(x.T @ x + pen, x.T @ y)
        return SplineCRModel(std, knots1, knots2, coef)

    def predict(self, features: jnp.ndarray) -> jnp.ndarray:
        x = _spline_design(self.std(features), self.knots1, self.knots2)
        return jnp.exp(x @ self.coef)

    def predict_log(self, features: jnp.ndarray) -> jnp.ndarray:
        x = _spline_design(self.std(features), self.knots1, self.knots2)
        return x @ self.coef


# ---------------------------------------------------------------------------
# LASSO via FISTA (predictor importance, Table 3)
# ---------------------------------------------------------------------------

def _soft_threshold(x: jnp.ndarray, t: float) -> jnp.ndarray:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


@partial(jax.jit, static_argnames=("num_iters",))
def lasso_fit(x: jnp.ndarray, y: jnp.ndarray, lam: jnp.ndarray, num_iters: int = 500) -> jnp.ndarray:
    """min_b 1/(2n) ||y - X b||^2 + lam ||b_{1:}||_1 (intercept unpenalized).

    FISTA with fixed step 1/L, L = largest eigenvalue of X^T X / n.
    Returns coefficient vector (p,).
    """
    n = x.shape[0]
    xtx = x.T @ x / n
    xty = x.T @ y / n
    lipschitz = jnp.linalg.eigvalsh(xtx)[-1] + 1e-8
    step = 1.0 / lipschitz
    mask = jnp.ones(x.shape[1]).at[0].set(0.0)  # don't penalize intercept

    def body(_, carry):
        b, v, t = carry
        grad = xtx @ v - xty
        b_new = _soft_threshold(v - step * grad, step * lam * 1.0) * mask + \
            (v - step * grad) * (1 - mask)
        t_new = (1 + jnp.sqrt(1 + 4 * t * t)) / 2
        v_new = b_new + ((t - 1) / t_new) * (b_new - b)
        return b_new, v_new, t_new

    b0 = jnp.zeros(x.shape[1])
    b, _, _ = jax.lax.fori_loop(0, num_iters, body, (b0, b0, jnp.array(1.0)))
    return b


def lasso_importance(
    features: jnp.ndarray,
    cr: jnp.ndarray,
    lam_grid: jnp.ndarray | None = None,
    k: int = 8,
    seed: int = 0,
) -> jnp.ndarray:
    """Cross-validated LASSO on the Eq.-(1) design; returns |coef| for
    [qent, svd/sigma, interaction] -- the paper's Table 3 numbers.
    """
    std = Standardizer.fit(features)
    x = _linear_design(std(features))
    y = jnp.log(cr)
    y_mean, y_std = jnp.mean(y), jnp.maximum(jnp.std(y), 1e-8)
    yz = (y - y_mean) / y_std
    if lam_grid is None:
        lam_grid = jnp.logspace(-4, 0, 20)

    n = x.shape[0]
    perm = jax.random.permutation(jax.random.PRNGKey(seed), n)
    folds = jnp.array_split(perm, k)

    def cv_err(lam):
        errs = []
        for f in folds:
            test_mask = jnp.zeros(n, bool).at[f].set(True)
            w = (~test_mask).astype(x.dtype)
            # weighted LS via FISTA on weighted matrices
            xw = x * w[:, None]
            b = lasso_fit(xw, yz * w, lam)
            resid = (x @ b - yz) * test_mask
            errs.append(jnp.sum(resid**2) / jnp.maximum(jnp.sum(test_mask), 1))
        return jnp.mean(jnp.stack(errs))

    errs = jnp.stack([cv_err(l) for l in lam_grid])
    best = lam_grid[jnp.argmin(errs)]
    coef = lasso_fit(x, yz, best)
    return jnp.abs(coef[1:])  # drop intercept: [qent, svd/sigma, interaction]


# jitted whole-model evaluation: models are NamedTuple pytrees, so one
# compile serves every instance with the same knot count
@jax.jit
def predict_fast(model, feats: jnp.ndarray) -> jnp.ndarray:
    return model.predict(feats)


MODEL_REGISTRY: dict[str, Callable] = {
    "linear": LinearCRModel.fit,
    "spline": SplineCRModel.fit,
}
