"""Compressor-agnostic statistical predictors of lossy compressibility.

Implements the paper's Section 3.1:
  * ``svd_trunc``      -- fraction of singular values needed to recover 99%
                          of the variance of a mean-corrected 2-D slice
                          (proxy for spatial correlation range).
  * ``hosvd_trunc``    -- 3-D extension: Tucker/HOSVD unfolding truncation at
                          90% of squared singular mass per mode.
  * ``std``            -- slice standard deviation.
  * ``entropy``        -- Shannon entropy of the raw symbol distribution.
  * ``quantized_entropy`` -- entropy of ``Q(d, eps) = floor(d/eps)*eps``:
                          the paper's lossyness-aware entropy.

TPU adaptation (DESIGN.md section 4): singular values are obtained from the
eigenvalues of the Gram matrix ``X^T X`` (MXU-friendly matmul + small
symmetric eigensolve) instead of a LAPACK bidiagonalisation; the Gram matmul
has a Pallas kernel in ``repro.kernels.gram``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp


DEFAULT_VARIANCE_FRACTION_2D = 0.99
DEFAULT_VARIANCE_FRACTION_3D = 0.90


# ---------------------------------------------------------------------------
# SVD truncation level (2-D)
# ---------------------------------------------------------------------------

def _gram_singular_values_sq(x: jnp.ndarray, use_kernel: bool = False) -> jnp.ndarray:
    """Squared singular values of ``x`` via the Gram matrix of the smaller side.

    For an (m, n) matrix the nonzero singular values of X equal the square
    roots of the eigenvalues of X^T X (n x n) or X X^T (m x m); we pick the
    smaller Gram matrix.  eigvalsh is ascending; we return descending.
    """
    m, n = x.shape
    if use_kernel:  # Pallas tiled Gram (TPU path); imported lazily.
        from repro.kernels.gram import ops as gram_ops
        g = gram_ops.gram(x, transpose=m >= n)
    else:
        g = x.T @ x if m >= n else x @ x.T
    ev = jnp.linalg.eigvalsh(g)
    ev = jnp.maximum(ev, 0.0)
    return ev[::-1]


def svd_trunc(
    x: jnp.ndarray,
    variance_fraction: float = DEFAULT_VARIANCE_FRACTION_2D,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Fraction of singular values needed to capture ``variance_fraction``
    of the total variance of the mean-corrected 2-D slice ``x``.

    Returns a scalar in (0, 1].  Low values => strong spatial correlation.
    """
    if x.ndim != 2:
        raise ValueError(f"svd_trunc expects a 2-D slice, got shape {x.shape}")
    x = x.astype(jnp.float32)
    x = x - jnp.mean(x, axis=0, keepdims=True)  # mean-corrected columns
    s2 = _gram_singular_values_sq(x, use_kernel=use_kernel)
    total = jnp.sum(s2)
    # Guard: constant slice -> total == 0 -> define trunc = 1/k (maximally
    # compressible).
    k = s2.shape[0]
    cum = jnp.cumsum(s2)
    frac = jnp.where(total > 0, cum / jnp.maximum(total, 1e-30), 1.0)
    # number of singular values needed = first index where frac >= fraction
    needed = 1 + jnp.sum(frac < variance_fraction)
    return needed.astype(jnp.float32) / k


# ---------------------------------------------------------------------------
# HOSVD truncation level (3-D)
# ---------------------------------------------------------------------------

def _unfold(x: jnp.ndarray, mode: int) -> jnp.ndarray:
    """Mode-``mode`` unfolding: fibers of dimension ``mode`` become columns."""
    return jnp.moveaxis(x, mode, 0).reshape(x.shape[mode], -1)


def hosvd_trunc(
    x: jnp.ndarray,
    variance_fraction: float = DEFAULT_VARIANCE_FRACTION_3D,
) -> jnp.ndarray:
    """HOSVD-based truncation statistic for an N-D tensor (paper section 3.1.2).

    For each mode, unfold and compute the fraction of singular values whose
    squared mass reaches ``variance_fraction``; returns the mean fraction
    across modes (scalar in (0, 1]).
    """
    if x.ndim < 3:
        raise ValueError(f"hosvd_trunc expects >=3-D tensor, got {x.shape}")
    x = x.astype(jnp.float32)
    x = x - jnp.mean(x)
    fracs = []
    for mode in range(x.ndim):
        u = _unfold(x, mode)
        s2 = _gram_singular_values_sq(u)
        total = jnp.maximum(jnp.sum(s2), 1e-30)
        cum = jnp.cumsum(s2)
        needed = 1 + jnp.sum(cum / total < variance_fraction)
        fracs.append(needed.astype(jnp.float32) / s2.shape[0])
    return jnp.mean(jnp.stack(fracs))


# ---------------------------------------------------------------------------
# Entropy / quantized entropy
# ---------------------------------------------------------------------------

def _entropy_from_counts(counts: jnp.ndarray) -> jnp.ndarray:
    n = jnp.maximum(jnp.sum(counts), 1)
    p = counts / n
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))


def quantized_codes(x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Linear quantization codes ``floor(d/eps)`` as int32 (paper section 3.1.5)."""
    return jnp.floor(x / eps).astype(jnp.int32)


def quantized_entropy(
    x: jnp.ndarray,
    eps: float,
    num_bins: int = 65536,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Shannon entropy (bits/symbol) of the linearly quantized data.

    The code domain is data-dependent and unbounded, so for a jittable
    implementation we histogram the *shifted* codes into ``num_bins`` bins;
    codes beyond the range are hashed (mod) into the table.  For all datasets
    in the study the code range at the studied error bounds fits well within
    2^16 bins, making this exact (tests verify against a bincount oracle).
    """
    x = x.astype(jnp.float32).reshape(-1)
    codes = quantized_codes(x, eps)
    if use_kernel:
        from repro.kernels.qent import ops as qent_ops
        return qent_ops.quantized_entropy(x, eps, num_bins=num_bins)
    lo = jnp.min(codes)
    shifted = (codes - lo) % num_bins
    counts = jnp.zeros((num_bins,), jnp.int32).at[shifted].add(1)
    return _entropy_from_counts(counts)


def entropy(x: jnp.ndarray, num_bins: int = 65536) -> jnp.ndarray:
    """Entropy of raw float bit patterns, binned (lossless-style entropy)."""
    x = x.reshape(-1)
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    idx = (bits % jnp.uint32(num_bins)).astype(jnp.int32)
    counts = jnp.zeros((num_bins,), jnp.int32).at[idx].add(1)
    return _entropy_from_counts(counts)


# ---------------------------------------------------------------------------
# Feature bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    variance_fraction_2d: float = DEFAULT_VARIANCE_FRACTION_2D
    variance_fraction_3d: float = DEFAULT_VARIANCE_FRACTION_3D
    qent_bins: int = 65536
    use_kernels: bool = False  # route hot spots through Pallas kernels


def features_2d(x: jnp.ndarray, eps: float, cfg: PredictorConfig = PredictorConfig()) -> jnp.ndarray:
    """The paper's predictor vector for one 2-D slice at error bound ``eps``:
    ``[log(q_ent), log(svd_trunc / sigma)]`` (both standardized downstream).
    """
    sigma = jnp.std(x.astype(jnp.float32))
    sv = svd_trunc(x, cfg.variance_fraction_2d, use_kernel=cfg.use_kernels)
    qe = quantized_entropy(x, eps, cfg.qent_bins, use_kernel=cfg.use_kernels)
    # Guard logs: q-ent can be 0 (all values in one bin) and sigma can be 0.
    log_qe = jnp.log(jnp.maximum(qe, 1e-3))
    log_ratio = jnp.log(jnp.maximum(sv, 1e-6) / jnp.maximum(sigma, 1e-12))
    return jnp.stack([log_qe, log_ratio])


def features_3d(x: jnp.ndarray, eps: float, cfg: PredictorConfig = PredictorConfig()) -> jnp.ndarray:
    sigma = jnp.std(x.astype(jnp.float32))
    sv = hosvd_trunc(x, cfg.variance_fraction_3d)
    qe = quantized_entropy(x, eps, cfg.qent_bins, use_kernel=cfg.use_kernels)
    log_qe = jnp.log(jnp.maximum(qe, 1e-3))
    log_ratio = jnp.log(jnp.maximum(sv, 1e-6) / jnp.maximum(sigma, 1e-12))
    return jnp.stack([log_qe, log_ratio])


def features_batch(slices: jnp.ndarray, eps: float, cfg: PredictorConfig = PredictorConfig()) -> jnp.ndarray:
    """vmapped featurizer over a stack of 2-D slices: (k, m, n) -> (k, 2)."""
    fn = functools.partial(features_2d, eps=eps, cfg=cfg)
    return jax.vmap(fn)(slices)


# ---------------------------------------------------------------------------
# eps-cached featurization (UC1: "the SVD is independent of the error bound,
# we execute this code only once; q-ent and inference run per error bound")
# ---------------------------------------------------------------------------

@jax.jit
def _qent_traced(x: jnp.ndarray, eps: jnp.ndarray) -> jnp.ndarray:
    """Quantized entropy with eps as a traced argument: one compile for the
    whole error-bound sweep."""
    return quantized_entropy(x, eps)


@jax.jit
def _svd_sigma_traced(x: jnp.ndarray):
    return svd_trunc(x), jnp.std(x.astype(jnp.float32))


def features_2d_cached(x: jnp.ndarray):
    """Precompute the eps-independent predictor parts once; returns a
    closure evaluating the full feature vector at any error bound."""
    sv, sigma = _svd_sigma_traced(x)
    log_ratio = jnp.log(jnp.maximum(sv, 1e-6) / jnp.maximum(sigma, 1e-12))

    def at_eps(eps) -> jnp.ndarray:
        qe = _qent_traced(x, jnp.asarray(eps, jnp.float32))
        return jnp.stack([jnp.log(jnp.maximum(qe, 1e-3)), log_ratio])

    return at_eps
