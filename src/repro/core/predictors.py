"""Compressor-agnostic statistical predictors of lossy compressibility.

Implements the paper's Section 3.1:
  * ``svd_trunc``      -- fraction of singular values needed to recover 99%
                          of the variance of a mean-corrected 2-D slice
                          (proxy for spatial correlation range).
  * ``hosvd_trunc``    -- 3-D extension: Tucker/HOSVD unfolding truncation at
                          90% of squared singular mass per mode.
  * ``std``            -- slice standard deviation.
  * ``entropy``        -- Shannon entropy of the raw symbol distribution.
  * ``quantized_entropy`` -- entropy of ``Q(d, eps) = floor(d/eps)*eps``:
                          the paper's lossyness-aware entropy.

TPU adaptation (DESIGN.md section 4): singular values are obtained from the
eigenvalues of the Gram matrix ``X^T X`` (MXU-friendly matmul + small
symmetric eigensolve) instead of a LAPACK bidiagonalisation; the Gram matmul
has a Pallas kernel in ``repro.kernels.gram``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp


from repro.kernels import tune as KT
from repro.quant import (INT32_CODE_MIN, INT32_CODE_MAX,
                         validate_eps_positive as _validate_eps_positive)

DEFAULT_VARIANCE_FRACTION_2D = 0.99
DEFAULT_VARIANCE_FRACTION_3D = 0.90


# ---------------------------------------------------------------------------
# SVD truncation level (2-D)
# ---------------------------------------------------------------------------

def svd_trunc(
    x: jnp.ndarray,
    variance_fraction: float = DEFAULT_VARIANCE_FRACTION_2D,
    use_kernel: bool = False,
    tune: KT.TuneConfig | None = None,
) -> jnp.ndarray:
    """Fraction of singular values needed to capture ``variance_fraction``
    of the total variance of the mean-corrected 2-D slice ``x``.

    Returns a scalar in (0, 1].  Low values => strong spatial correlation.
    The k=1 case of ``svd_trunc_batch`` (single implementation).
    """
    if x.ndim != 2:
        raise ValueError(f"svd_trunc expects a 2-D slice, got shape {x.shape}")
    return svd_trunc_batch(x[None], variance_fraction, use_kernel=use_kernel,
                           tune=tune)[0]


# ---------------------------------------------------------------------------
# HOSVD truncation level (3-D)
# ---------------------------------------------------------------------------

def _unfold_batch(x: jnp.ndarray, mode: int) -> jnp.ndarray:
    """Mode-``mode`` unfolding of every tensor in a (k, ...) stack: fibers
    of (per-tensor) dimension ``mode`` become columns -> (k, dims[mode], -1).
    """
    return jnp.moveaxis(x, 1 + mode, 1).reshape(x.shape[0], x.shape[1 + mode], -1)


def hosvd_trunc_batch(
    vols: jnp.ndarray,
    variance_fraction: float = DEFAULT_VARIANCE_FRACTION_3D,
    use_kernel: bool = False,
    tune: KT.TuneConfig | None = None,
) -> jnp.ndarray:
    """``hosvd_trunc`` for a (k, d, m, n) stack of volumes (any rank >= 4):
    per-mode unfoldings computed as ONE batched Gram + batched ``eigvalsh``
    per mode, instead of the per-mode/per-volume Python loops.

    Each volume is mean-corrected by its own global mean (the same
    correction the scalar path applies), and a zero-variance mode (constant
    volume) yields fraction 1/p -- the ``jnp.where(total > 0, ..., 1.0)``
    guard ``svd_trunc_batch`` uses -- so the result stays in (0, 1].
    Returns a (k,) vector: the mean fraction across modes per volume.
    """
    if vols.ndim < 4:
        raise ValueError(
            f"hosvd_trunc_batch expects a (k, d, m, n) volume stack "
            f"(rank >= 4), got {vols.shape}; wrap one volume as x[None]")
    x = vols.astype(jnp.float32)
    x = x - jnp.mean(x, axis=tuple(range(1, x.ndim)), keepdims=True)
    fracs = []
    for mode in range(x.ndim - 1):
        u = _unfold_batch(x, mode)
        _, p, q = u.shape
        if use_kernel:
            from repro.kernels.gram import ops as gram_ops
            g = gram_ops.gram_batched(u, transpose=p >= q, tune=tune)
        else:
            g = (jnp.einsum("kai,kaj->kij", u, u) if p >= q
                 else jnp.einsum("kia,kja->kij", u, u))
        ev = jnp.maximum(jnp.linalg.eigvalsh(g), 0.0)[:, ::-1]   # descending
        total = jnp.sum(ev, axis=1, keepdims=True)
        cum = jnp.cumsum(ev, axis=1)
        frac = jnp.where(total > 0, cum / jnp.maximum(total, 1e-30), 1.0)
        needed = 1 + jnp.sum(frac < variance_fraction, axis=1)
        fracs.append(needed.astype(jnp.float32) / ev.shape[1])
    return jnp.mean(jnp.stack(fracs), axis=0)


def hosvd_trunc(
    x: jnp.ndarray,
    variance_fraction: float = DEFAULT_VARIANCE_FRACTION_3D,
    use_kernel: bool = False,
    tune: KT.TuneConfig | None = None,
) -> jnp.ndarray:
    """HOSVD-based truncation statistic for an N-D tensor (paper section 3.1.2).

    For each mode, unfold and compute the fraction of singular values whose
    squared mass reaches ``variance_fraction``; returns the mean fraction
    across modes (scalar in (0, 1] -- a constant tensor yields the mean of
    1/p over modes, not (1+p)/p).  The k=1 case of ``hosvd_trunc_batch``
    (single implementation, bit-exact with the batch path)."""
    if x.ndim < 3:
        raise ValueError(f"hosvd_trunc expects >=3-D tensor, got {x.shape}")
    return hosvd_trunc_batch(x[None], variance_fraction,
                             use_kernel=use_kernel, tune=tune)[0]


# ---------------------------------------------------------------------------
# Entropy / quantized entropy
# ---------------------------------------------------------------------------

def _entropy_from_counts(counts: jnp.ndarray) -> jnp.ndarray:
    n = jnp.maximum(jnp.sum(counts), 1)
    p = counts / n
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))


def quantized_codes(x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Linear quantization codes ``floor(d/eps)`` as int32 (paper section 3.1.5).

    Raises ``ValueError`` for ``eps <= 0`` (concrete values), and clamps
    the float codes to the int32 range before the cast so extreme
    (value, eps) pairs saturate instead of silently wrapping.
    """
    _validate_eps_positive(eps)
    scaled = jnp.floor(x / eps)
    return jnp.clip(scaled, INT32_CODE_MIN, INT32_CODE_MAX).astype(jnp.int32)


def quantized_entropy(
    x: jnp.ndarray,
    eps: float,
    num_bins: int = 65536,
    use_kernel: bool = False,
    tune: KT.TuneConfig | None = None,
) -> jnp.ndarray:
    """Shannon entropy (bits/symbol) of the linearly quantized data.

    The code domain is data-dependent and unbounded, so for a jittable
    implementation we histogram the *shifted* codes into ``num_bins`` bins;
    codes beyond the range are hashed (mod) into the table.  For all datasets
    in the study the code range at the studied error bounds fits well within
    2^16 bins, making this exact (tests verify against a bincount oracle).
    """
    x = x.astype(jnp.float32).reshape(-1)
    codes = quantized_codes(x, eps)
    if use_kernel:
        from repro.kernels.qent import ops as qent_ops
        return qent_ops.quantized_entropy(x, eps, num_bins=num_bins, tune=tune)
    lo = jnp.min(codes)
    shifted = (codes - lo) % num_bins
    counts = jnp.zeros((num_bins,), jnp.int32).at[shifted].add(1)
    return _entropy_from_counts(counts)


def entropy(x: jnp.ndarray, num_bins: int = 65536) -> jnp.ndarray:
    """Entropy of raw float bit patterns, binned (lossless-style entropy)."""
    x = x.reshape(-1)
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    idx = (bits % jnp.uint32(num_bins)).astype(jnp.int32)
    counts = jnp.zeros((num_bins,), jnp.int32).at[idx].add(1)
    return _entropy_from_counts(counts)


# ---------------------------------------------------------------------------
# Feature bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    variance_fraction_2d: float = DEFAULT_VARIANCE_FRACTION_2D
    variance_fraction_3d: float = DEFAULT_VARIANCE_FRACTION_3D
    qent_bins: int = 65536
    use_kernels: bool = False  # route hot spots through Pallas kernels
    # kernel tile policy: defaults consult the backend's tuned table
    # (kernels/tuned/<backend>.json); frozen+hashable so it rides jit
    # static args and the serving layer's executable signatures
    tune: KT.TuneConfig = KT.TuneConfig()


def features_2d(x: jnp.ndarray, eps: float, cfg: PredictorConfig = PredictorConfig()) -> jnp.ndarray:
    """The paper's predictor vector for one 2-D slice at error bound ``eps``:
    ``[log(q_ent), log(svd_trunc / sigma)]`` (both standardized downstream).
    """
    sigma = jnp.std(x.astype(jnp.float32))
    sv = svd_trunc(x, cfg.variance_fraction_2d, use_kernel=cfg.use_kernels,
                   tune=cfg.tune)
    qe = quantized_entropy(x, eps, cfg.qent_bins, use_kernel=cfg.use_kernels,
                           tune=cfg.tune)
    # Guard logs: q-ent can be 0 (all values in one bin) and sigma can be 0.
    log_qe = jnp.log(jnp.maximum(qe, 1e-3))
    log_ratio = jnp.log(jnp.maximum(sv, 1e-6) / jnp.maximum(sigma, 1e-12))
    return jnp.stack([log_qe, log_ratio])


def features_3d(x: jnp.ndarray, eps: float, cfg: PredictorConfig = PredictorConfig()) -> jnp.ndarray:
    sigma = jnp.std(x.astype(jnp.float32))
    sv = hosvd_trunc(x, cfg.variance_fraction_3d, use_kernel=cfg.use_kernels,
                     tune=cfg.tune)
    qe = quantized_entropy(x, eps, cfg.qent_bins, use_kernel=cfg.use_kernels,
                           tune=cfg.tune)
    log_qe = jnp.log(jnp.maximum(qe, 1e-3))
    log_ratio = jnp.log(jnp.maximum(sv, 1e-6) / jnp.maximum(sigma, 1e-12))
    return jnp.stack([log_qe, log_ratio])


def features_batch(slices: jnp.ndarray, eps: float, cfg: PredictorConfig = PredictorConfig()) -> jnp.ndarray:
    """vmapped featurizer over a stack of 2-D slices: (k, m, n) -> (k, 2)."""
    fn = functools.partial(features_2d, eps=eps, cfg=cfg)
    return jax.vmap(fn)(slices)


# ---------------------------------------------------------------------------
# Sweep-native batched featurization engine
#
# The production workload is a *sweep*: k slices x e error bounds (UC1
# probes a grid of ebs; UC2 shares features across compressors; training
# fits one model per grid eb).  The SVD predictor is eb-independent, so the
# engine computes it ONCE per slice via a single batched Gram + batched
# eigvalsh, and the q-ent predictor reads each slice once while quantizing
# at every error bound (fused multi-eps histogram) -- O(1) data reads per
# slice instead of the looped path's O(e).
# ---------------------------------------------------------------------------

def svd_trunc_batch(
    slices: jnp.ndarray,
    variance_fraction: float = DEFAULT_VARIANCE_FRACTION_2D,
    use_kernel: bool = False,
    tune: KT.TuneConfig | None = None,
) -> jnp.ndarray:
    """svd_trunc for a (k, m, n) stack in one batched Gram + eigvalsh."""
    if slices.ndim != 3:
        raise ValueError(f"svd_trunc_batch expects (k, m, n), got {slices.shape}")
    x = slices.astype(jnp.float32)
    x = x - jnp.mean(x, axis=1, keepdims=True)   # mean-corrected columns
    _, m, n = x.shape
    if use_kernel:
        from repro.kernels.gram import ops as gram_ops
        g = gram_ops.gram_batched(x, transpose=m >= n, tune=tune)
    else:
        g = (jnp.einsum("kai,kaj->kij", x, x) if m >= n
             else jnp.einsum("kia,kja->kij", x, x))
    ev = jnp.maximum(jnp.linalg.eigvalsh(g), 0.0)[:, ::-1]   # descending
    p = ev.shape[1]
    total = jnp.sum(ev, axis=1, keepdims=True)
    cum = jnp.cumsum(ev, axis=1)
    frac = jnp.where(total > 0, cum / jnp.maximum(total, 1e-30), 1.0)
    needed = 1 + jnp.sum(frac < variance_fraction, axis=1)
    return needed.astype(jnp.float32) / p


def _sort_f32_fast(x: jnp.ndarray) -> jnp.ndarray:
    """Ascending last-axis sort of f32 data via an order-preserving
    uint32 key (~4x faster than XLA's CPU float comparator sort).

    The key map is bijective -- negatives flip all bits, positives set
    the sign bit -- and inverted after the sort, so the output carries
    the EXACT input bit patterns and equals ``jnp.sort`` on non-NaN data
    (including -0.0 < +0.0; ties need no stability, there is no payload).
    """
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    key = jnp.where(u >> 31 == 1, ~u, u | jnp.uint32(0x80000000))
    sk = jax.lax.sort(key, dimension=-1, is_stable=False)
    v = jnp.where(sk >> 31 == 1, sk & jnp.uint32(0x7FFFFFFF), ~sk)
    return jax.lax.bitcast_convert_type(v, jnp.float32)


def quantized_entropy_sweep(
    slices: jnp.ndarray,
    epss: jnp.ndarray,
    num_bins: int = 65536,
    use_kernel: bool = False,
    tune: KT.TuneConfig | None = None,
) -> jnp.ndarray:
    """q-ent of a (k, ...) stack at an (e,) eb vector -> (k, e), reading
    the data once.

    Kernel route: the fused multi-eps Pallas histogram (``num_bins``
    hashed bins, one launch).  jnp route: sort each slice ONCE (shared by
    every error bound -- floor(x/eps) is monotone in x), then per-eps
    run-length counts from pure cumulative ops: no scatter, no histogram
    table.  The sort route is *exact*; it equals the hashed-histogram
    paths whenever the code range fits the bins (the study's validated
    regime, where those paths are exact too).
    """
    _validate_eps_positive(epss)
    k = slices.shape[0]
    flat = slices.astype(jnp.float32).reshape(k, -1)
    epss = jnp.asarray(epss, jnp.float32).reshape(-1)
    if use_kernel:
        from repro.kernels.qent import ops as qent_ops
        return qent_ops.quantized_entropy_sweep(flat, epss, num_bins=num_bins,
                                                tune=tune)
    n = flat.shape[1]
    xs = _sort_f32_fast(flat)                         # once, shared by all ebs
    iota = jnp.arange(n)
    ones = jnp.ones((k, 1), bool)

    def one_eps(eps):
        # lax.map over ebs keeps the peak working set at (k, n) -- the
        # same order as one step of the looped baseline -- instead of
        # materializing (k, e, n) temporaries for the whole sweep.
        codes = jnp.clip(jnp.floor(xs / eps),         # saturate, don't wrap
                         INT32_CODE_MIN, INT32_CODE_MAX).astype(jnp.int32)
        start = jnp.concatenate(                      # run starts, (k, n)
            [ones, codes[:, 1:] != codes[:, :-1]], axis=1)
        run_start = jax.lax.cummax(jnp.where(start, iota, 0), axis=1)
        # H = log2(n) - (1/n) sum_runs L*log2(L).  Telescoping over the
        # rank j = 1..L inside each run, L*log2(L) = sum_j g(j) with
        # g(j) = j*log2(j) - (j-1)*log2(j-1), so one forward cummax (the
        # rank) replaces any backward pass or per-run reduction.
        j = (iota - run_start + 1).astype(jnp.float32)
        g = j * jnp.log2(j) - (j - 1) * jnp.log2(jnp.maximum(j - 1, 1))
        return jnp.log2(float(n)) - jnp.sum(g, axis=1) / n

    return jax.lax.map(one_eps, epss).T               # (e, k) -> (k, e)


def variance_fraction_for(cfg: PredictorConfig, stack_ndim: int) -> float:
    """The truncation variance fraction a (k, ...) stack featurizes with:
    2-D slices (rank-3 stacks) use ``variance_fraction_2d``, volumes
    (rank >= 4) the HOSVD ``variance_fraction_3d``."""
    return (cfg.variance_fraction_2d if stack_ndim == 3
            else cfg.variance_fraction_3d)


# Trailing-axis width of the sweep tensor per mode: "features" emits the
# (log q-ent, log trunc-ratio) predictor pair, "quality" the (PSNR,
# NRMSE) pair of the quantization proxy, "both" their concatenation
# [log_qe, log_ratio, psnr, nrmse] from ONE read of the data.
SWEEP_MODE_WIDTHS = {"features": 2, "quality": 2, "both": 4}


def _features_sweep_impl(slices, epss, *, vf, bins, use_kernels, tune=None,
                         mode="features"):
    """Pure sweep body: (k, m, n) | (k, d, m, n) x (e,) -> (k, e, w).

    Rank-dispatching: rank-3 stacks run the batched 2-D SVD predictor,
    rank-4+ stacks the batched HOSVD predictor (``hosvd_trunc_batch``);
    the q-ent sweep flattens each element and is shared as-is.

    ``mode`` selects the trailing axis (``SWEEP_MODE_WIDTHS``):
    "features" is the paper's predictor pair, "quality" the fused
    PSNR/NRMSE pair (``kernels/quality``), "both" their concatenation --
    the one-pass ratio-quality frontier (a single tensor keeps the
    shard_map out_specs/masking width-agnostic).

    Kept jit-free so the distributed layer (``repro.dist.sweep``) can call
    it inside a ``shard_map`` body on each device's local slice shard.
    """
    if mode not in SWEEP_MODE_WIDTHS:
        raise ValueError(f"unknown sweep mode {mode!r}; expected one of "
                         f"{sorted(SWEEP_MODE_WIDTHS)}")
    x = slices.astype(jnp.float32)
    outs = []
    if mode in ("features", "both"):
        sigma = jnp.std(x, axis=tuple(range(1, x.ndim)))
        if x.ndim == 3:
            sv = svd_trunc_batch(x, vf, use_kernel=use_kernels, tune=tune)
        else:
            sv = hosvd_trunc_batch(x, vf, use_kernel=use_kernels, tune=tune)
        log_ratio = jnp.log(jnp.maximum(sv, 1e-6) / jnp.maximum(sigma, 1e-12))
        qe = quantized_entropy_sweep(x, epss, bins, use_kernel=use_kernels,
                                     tune=tune)
        log_qe = jnp.log(jnp.maximum(qe, 1e-3))             # (k, e)
        outs.append(jnp.stack(
            [log_qe, jnp.broadcast_to(log_ratio[:, None], log_qe.shape)],
            axis=-1))
    if mode in ("quality", "both"):
        from repro.kernels.quality import ops as quality_ops
        outs.append(quality_ops.quality_sweep(
            x, epss, use_kernel=use_kernels))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)


_features_sweep_traced = jax.jit(
    _features_sweep_impl,
    static_argnames=("vf", "bins", "use_kernels", "tune", "mode"))

# zero-copy variant for the serving hot path: the caller hands over the
# (padded) input stack and XLA may reuse its buffer for intermediates.
# Identical computation -- donation changes buffer lifetime, not math --
# so it shares _features_sweep_impl and tests assert bit-equality.
_features_sweep_donated = jax.jit(
    _features_sweep_impl,
    static_argnames=("vf", "bins", "use_kernels", "tune", "mode"),
    donate_argnums=(0,))


def features_sweep(
    slices: jnp.ndarray,
    epss,
    cfg: PredictorConfig = PredictorConfig(),
    *,
    sharded: bool | None = None,
    mesh=None,
    gather: bool = True,
    quality: bool = False,
) -> jnp.ndarray:
    """The full predictor tensor in one pass: (k, m, n) x (e,) -> (k, e, 2).

    Volumes are first-class: a rank-4 (k, d, m, n) stack routes the
    eb-independent column through the batched HOSVD predictor
    (``hosvd_trunc_batch``) instead of the 2-D SVD, same output shape.

    Column [..., 0] is log(q-ent) (eb-dependent, fused multi-eps
    histogram); column [..., 1] is log(svd_trunc / sigma) (for volumes
    log(hosvd_trunc / sigma); eb-independent, computed once and
    broadcast).  Matches looped ``features_2d`` / ``features_3d`` to f32
    tolerance (regression-tested).

    Distribution: with ``sharded=None`` (default) the sweep automatically
    runs as a ``shard_map`` over the slice axis whenever a mesh whose
    "slices"-mapped axis has extent > 1 is active (``dist.sharding.use_mesh``)
    or passed as ``mesh``; ``sharded=False`` forces the single-device path
    and ``sharded=True`` requires a mesh (raising if none is usable).
    ``gather=False`` returns the padded per-device result still sharded
    over the mesh (see ``repro.dist.sweep.features_sweep_sharded``).

    ``quality=True`` makes the same single pass also emit the fused
    PSNR/NRMSE tensor of the quantization proxy (``kernels/quality``)
    and returns the pair ``(features (k, e, 2), quality (k, e, 2))`` --
    both halves of the ratio-quality frontier from one read of the data.
    """
    out = _sweep_dispatch(slices, epss, cfg, sharded=sharded, mesh=mesh,
                          gather=gather,
                          mode="both" if quality else "features")
    if quality:
        return out[..., :2], out[..., 2:]
    return out


def quality_sweep(
    slices: jnp.ndarray,
    epss,
    cfg: PredictorConfig = PredictorConfig(),
    *,
    sharded: bool | None = None,
    mesh=None,
    gather: bool = True,
) -> jnp.ndarray:
    """The quality half of the frontier: (k, ...) x (e,) -> (k, e, 2).

    Column [..., 0] is the PSNR (dB) and [..., 1] the NRMSE of the
    quantization proxy (quantize-dequantize at each error bound, same
    saturating quantizer as the q-ent predictor), computed by the fused
    ``kernels/quality`` sweep in one read of the data.  Sharding routes
    exactly like :func:`features_sweep` (same auto-mesh rules), and the
    tensor is bitwise identical across the jnp reference, the Pallas
    kernel, sharded, streamed, and served paths.
    """
    return _sweep_dispatch(slices, epss, cfg, sharded=sharded, mesh=mesh,
                           gather=gather, mode="quality")


def _sweep_dispatch(slices, epss, cfg, *, sharded, mesh, gather, mode):
    """Shared routing for the mode-selected sweeps (validation, auto-
    sharding, single-device fallthrough)."""
    if slices.ndim not in (3, 4):
        raise ValueError(
            f"features_sweep expects a (k, m, n) slice stack or a "
            f"(k, d, m, n) volume stack, got {slices.shape}; wrap a single "
            f"slice/volume as x[None]")
    _validate_eps_positive(epss)
    epss = jnp.asarray(epss, jnp.float32).reshape(-1)
    # Auto-routing skips k=1: a single slice has no parallelism to split,
    # so sharding would only broadcast redundant copies of the same work
    # (UC1/UC2 featurize one query slice at a time under a serving mesh).
    if sharded or (sharded is None and slices.shape[0] > 1):
        from repro.dist import sweep as dsweep
        use_mesh = dsweep.active_sweep_mesh(mesh)
        if sharded and use_mesh is None:
            raise ValueError(
                "features_sweep(sharded=True) needs a mesh with a "
                "'slices'-mapped axis of extent > 1 (pass mesh= or "
                "activate one via dist.sharding.use_mesh)")
        if use_mesh is not None:
            return dsweep.features_sweep_sharded(
                slices, epss, cfg, mesh=use_mesh, gather=gather, mode=mode)
    return _features_sweep_traced(
        slices, epss, vf=variance_fraction_for(cfg, slices.ndim),
        bins=cfg.qent_bins, use_kernels=cfg.use_kernels, tune=cfg.tune,
        mode=mode)


@functools.partial(jax.jit, static_argnames=("bins", "use_kernels", "tune"))
def _qent_sweep_traced(x, epss, *, bins, use_kernels, tune=None):
    return quantized_entropy_sweep(x[None], epss, bins, use_kernel=use_kernels,
                                   tune=tune)[0]


@functools.partial(jax.jit, static_argnames=("vf", "use_kernels", "tune"))
def _svd_sigma_traced(x, *, vf, use_kernels, tune=None):
    if x.ndim == 2:
        sv = svd_trunc_batch(x[None], vf, use_kernel=use_kernels, tune=tune)[0]
    else:
        sv = hosvd_trunc_batch(x[None], vf, use_kernel=use_kernels,
                               tune=tune)[0]
    return sv, jnp.std(x.astype(jnp.float32))


class SliceCache:
    """Featurization cache for ONE slice or volume (UC1/UC2 cost
    structure): the eps-independent SVD-or-HOSVD/sigma part is computed at
    most once; q-ent is memoized per error bound; ``prefetch`` fills the
    memo for a whole eb grid with a single fused sweep (truncation
    predictor once + e histograms, one read)."""

    def __init__(self, x: jnp.ndarray, cfg: PredictorConfig):
        self._x = x
        self._cfg = cfg
        self._memo: dict = {}
        self._log_ratio = None

    @staticmethod
    def _key(eps) -> float:
        # features are computed in f32, so memoize at f32 resolution --
        # a float64 grid eb and its f32 round-trip must hit the same entry
        return float(jnp.float32(eps))

    def _ratio(self) -> jnp.ndarray:
        if self._log_ratio is None:
            sv, sigma = _svd_sigma_traced(
                self._x,
                vf=variance_fraction_for(self._cfg, self._x.ndim + 1),
                use_kernels=self._cfg.use_kernels, tune=self._cfg.tune)
            self._log_ratio = jnp.log(
                jnp.maximum(sv, 1e-6) / jnp.maximum(sigma, 1e-12))
        return self._log_ratio

    def prefetch(self, epss) -> jnp.ndarray:
        """Featurize the whole eb grid in one sweep; returns (e, 2)."""
        feats = features_sweep(self._x[None], epss, self._cfg)[0]
        return self.seed(epss, feats)

    def seed(self, epss, feats) -> jnp.ndarray:
        """Preload externally computed features: ``feats[i]`` is the (2,)
        feature vector of this slice at ``epss[i]``.

        The hook the coalescing sweep service uses to hand a request rows
        from a shared batched launch (or the cross-request feature cache)
        instead of featurizing again; the seeded cache is bit-identical to
        one filled by :meth:`prefetch` because coalesced sweep rows are
        row-independent.
        """
        epss = jnp.asarray(epss).reshape(-1)
        if len(epss) != len(feats):
            raise ValueError(
                f"seed needs one feature row per eb: {len(epss)} ebs vs "
                f"{len(feats)} rows")
        for i, eps in enumerate(epss):
            self._memo[self._key(eps)] = feats[i]
        if len(feats):
            self._log_ratio = feats[0][1]
        return feats

    def __call__(self, eps) -> jnp.ndarray:
        _validate_eps_positive(eps)
        key = self._key(eps)
        if key not in self._memo:
            qe = _qent_sweep_traced(
                self._x, jnp.asarray([key], jnp.float32),
                bins=self._cfg.qent_bins,
                use_kernels=self._cfg.use_kernels, tune=self._cfg.tune)[0]
            self._memo[key] = jnp.stack(
                [jnp.log(jnp.maximum(qe, 1e-3)), self._ratio()])
        return self._memo[key]


class FeaturizationEngine:
    """Batched, sweep-native featurizer -- the single entry point the
    pipeline, use cases, and benchmarks route through.

    * ``sweep(slices, epss)``  -- (k, m, n) x (e,) -> (k, e, 2), one pass.
    * ``features(slices, eps)`` -- (k, 2): the e=1 column of the sweep.
    * ``cached(x)``            -- per-slice :class:`SliceCache`.

    Volumes are first-class: every entry point also accepts a
    (k, d, m, n) volume stack (``cached``: a single (d, m, n) volume) and
    routes the eb-independent column through ``hosvd_trunc_batch`` --
    per-mode unfoldings as batched Grams + batched ``eigvalsh`` -- with
    ``variance_fraction_3d``; shapes, sharding, and caching behave
    identically to the 2-D path.

    Distributed sweeps
    ------------------
    ``sweep``/``features`` shard the slice axis across every device of an
    active mesh (logical axis "slices" -> physical "data"; see
    ``repro.dist.sweep``).  Nothing changes at the call site beyond
    activating a mesh -- on a multi-device host (or a CPU dev box with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` exported before
    jax is imported)::

        from repro.dist import sharding as S
        from repro.launch import mesh as M
        engine = get_engine()
        with S.use_mesh(M.make_sweep_mesh()):
            feats = engine.sweep(slices, ebs)    # shard_map over slices

    Slice counts that don't divide the mesh are padded (and the pad
    dropped from the gathered result); ``gather=False`` keeps the padded
    result sharded for downstream stages that stay distributed.  The
    sharded sweep matches the single-device engine to f32 tolerance
    (asserted by tests/test_dist_sweep.py and bench_sweep_sharded).
    """

    def __init__(self, cfg: PredictorConfig = PredictorConfig()):
        self.cfg = cfg

    def sweep(self, slices: jnp.ndarray, epss, *, sharded: bool | None = None,
              mesh=None, gather: bool = True,
              quality: bool = False) -> jnp.ndarray:
        """One-pass predictor tensor; ``quality=True`` returns the
        ``(features, quality)`` pair from the same single read (the
        fused ratio-quality frontier, see :func:`features_sweep`)."""
        return features_sweep(slices, epss, self.cfg, sharded=sharded,
                              mesh=mesh, gather=gather, quality=quality)

    def quality(self, slices: jnp.ndarray, epss, *,
                sharded: bool | None = None, mesh=None,
                gather: bool = True) -> jnp.ndarray:
        """The (k, e, 2) PSNR/NRMSE tensor alone (:func:`quality_sweep`)."""
        return quality_sweep(slices, epss, self.cfg, sharded=sharded,
                             mesh=mesh, gather=gather)

    def features(self, slices: jnp.ndarray, eps: float, *,
                 sharded: bool | None = None, mesh=None) -> jnp.ndarray:
        return self.sweep(slices, [eps], sharded=sharded, mesh=mesh)[:, 0, :]

    def stream(self, source, name: str, epss, *, stream=None, mesh=None,
               digest=None, quality: bool = False):
        """Out-of-core sweep of one :class:`repro.data.source.
        DatasetSource` variable: chunked, double-buffered, bit-equal to
        ``sweep(source.read(name), epss)`` with at most one budgeted
        chunk resident (see ``repro.core.stream.stream_features``).
        ``quality=True`` returns the streamed ``(features, quality)``
        pair from the same chunk launches."""
        from repro.core import stream as ST
        return ST.stream_features(source, name, epss, self.cfg,
                                  stream=stream, mesh=mesh, digest=digest,
                                  quality=quality)

    def cached(self, x: jnp.ndarray, *, features=None, epss=None) -> SliceCache:
        """Per-slice cache; ``features``/``epss`` pre-seed it with
        externally supplied feature rows (see :meth:`SliceCache.seed`) so
        serving layers can reuse coalesced-launch / cross-request results."""
        c = SliceCache(x, self.cfg)
        if features is not None:
            c.seed(epss, features)
        return c


_DEFAULT_ENGINE = FeaturizationEngine()


def get_engine(cfg: PredictorConfig = None) -> FeaturizationEngine:
    """The shared default engine (or a fresh one for a custom config)."""
    if cfg is None or cfg == _DEFAULT_ENGINE.cfg:
        return _DEFAULT_ENGINE
    return FeaturizationEngine(cfg)


# ---------------------------------------------------------------------------
# eps-cached featurization (UC1: "the SVD is independent of the error bound,
# we execute this code only once; q-ent and inference run per error bound")
# ---------------------------------------------------------------------------

def features_2d_cached(x: jnp.ndarray) -> SliceCache:
    """Compat wrapper: per-slice cache from the default engine.  Returns a
    callable evaluating the full feature vector at any error bound, with
    the eps-independent parts computed once."""
    return _DEFAULT_ENGINE.cached(x)
