"""Incremental streaming sweep driver: fixed-budget chunks in, the full
per-variable feature tensor out.

``features_sweep`` (and everything stacked on it) takes a resident
``(k, ...)`` array; this module drives the SAME sweep body over a
:class:`repro.data.source.DatasetSource` variable chunk by chunk, so a
variable far larger than device (or host) memory featurizes with a
bounded footprint:

* **Chunking** -- ``rows_per_chunk`` sizes every chunk to a byte budget;
  all chunks launch padded to one fixed row bucket (the full-chunk row
  count), so the whole stream compiles ONE executable and the ragged
  final chunk reuses it.
* **Double buffering** -- a reader thread stages chunk ``n+1`` (file
  read + f64->f32 conversion + optional running content digest) behind a
  bounded queue while chunk ``n``'s launch executes; launches are
  dispatched asynchronously and drained ``max_in_flight`` behind, so
  host I/O overlaps device compute (``prefetch=0`` degrades to the
  strictly synchronous read -> launch -> block loop, which is the
  baseline ``bench_stream`` gates against).
* **Zero-copy ingestion** -- every chunk is a fresh service-owned f32
  staging copy, so its device upload is donated
  (``dist.sweep.sweep_padded(donate=True)``, PR 8's contract).
* **Incremental aggregation** -- per-chunk ``(k_chunk, e, 2)`` blocks
  concatenate into the full ``(k, e, 2)`` tensor.  The sweep body is
  row-independent (the serving layer's coalescing contract), so the
  streamed tensor is BIT-EQUAL to one in-memory ``features_sweep``
  launch; tests and ``bench_stream`` assert it.
* **Multi-process streaming** -- under a process-spanning mesh each
  process reads ONLY its ``dist.sweep.process_block`` rows of every
  chunk and the chunk launches collectively via the PR 5
  ``process_local`` ingestion contract (same chunk schedule everywhere:
  boundaries depend only on the row count and the budget).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from collections import deque
from typing import Dict, Optional

import numpy as np

from repro.core import predictors as PRED
from repro.data.source import DatasetSource, StreamingDigest, rows_per_chunk
from repro.dist import sweep as DS


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs of the incremental driver.

    ``budget_bytes`` caps one chunk's f32 bytes (the peak host staging
    AND device upload per launch -- set it at or below the device memory
    budget).  ``prefetch`` is how many chunks the reader thread stages
    ahead (0 = fully synchronous, no reader thread).  ``max_in_flight``
    bounds dispatched-but-undrained launches so device memory holds at
    most that many chunk uploads."""
    budget_bytes: int = 64 << 20
    prefetch: int = 2
    max_in_flight: int = 2

    def __post_init__(self):
        if self.budget_bytes <= 0:
            raise ValueError(
                f"budget_bytes must be positive, got {self.budget_bytes}")
        if self.prefetch < 0 or self.max_in_flight < 1:
            raise ValueError(
                f"prefetch must be >= 0 and max_in_flight >= 1, got "
                f"prefetch={self.prefetch} max_in_flight={self.max_in_flight}")


_DONE = object()


def _reader(source: DatasetSource, name: str, schedule, q: "queue.Queue",
            digest: Optional[StreamingDigest]) -> None:
    """Reader-thread body: stage chunks (read + f32 convert + digest)
    into the bounded queue; exceptions travel through the queue so the
    consumer re-raises them instead of hanging."""
    try:
        for lo, hi, rlo, rhi in schedule:
            arr = source.read_rows(name, rlo, rhi)
            if digest is not None:
                digest.update(arr)
            q.put((lo, hi, arr))
        q.put(_DONE)
    except BaseException as exc:             # noqa: BLE001 -- re-raised
        q.put(exc)


def _staged_chunks(source, name, schedule, prefetch: int,
                   digest: Optional[StreamingDigest]):
    """Iterate ``(lo, hi, rows)`` chunks: behind a ``prefetch``-bounded
    reader thread, or inline when ``prefetch == 0`` (synchronous)."""
    if prefetch <= 0:
        for lo, hi, rlo, rhi in schedule:
            arr = source.read_rows(name, rlo, rhi)
            if digest is not None:
                digest.update(arr)
            yield lo, hi, arr
        return
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    t = threading.Thread(target=_reader,
                         args=(source, name, schedule, q, digest),
                         daemon=True)
    t.start()
    try:
        while True:
            item = q.get()
            if item is _DONE:
                break
            if isinstance(item, BaseException):
                raise item
            yield item
    finally:
        t.join(timeout=5.0)


def chunk_schedule(k: int, chunk: int, mesh=None) -> list:
    """The deterministic chunk plan: ``(lo, hi, read_lo, read_hi)`` per
    chunk.  ``read_*`` is the sub-range THIS process ingests -- the full
    chunk on a single process, the chunk's :func:`dist.sweep.
    process_block` block under a process-spanning mesh.  Boundaries
    depend only on ``(k, chunk)``, so every process of a cohort computes
    the identical schedule."""
    multiproc = DS.mesh_spans_processes(mesh)
    sched = []
    for lo in range(0, k, chunk):
        hi = min(lo + chunk, k)
        if multiproc:
            blo, bhi = DS.process_block(hi - lo, mesh)
            sched.append((lo, hi, lo + blo, lo + bhi))
        else:
            sched.append((lo, hi, lo, hi))
    return sched


def stream_features(
    source: DatasetSource,
    name: str,
    epss,
    cfg: Optional[PRED.PredictorConfig] = None,
    *,
    stream: Optional[StreamConfig] = None,
    mesh=None,
    digest: Optional[StreamingDigest] = None,
    quality: bool = False,
) -> np.ndarray:
    """Featurize one variable of ``source`` chunk by chunk: the full
    ``(k, e, 2)`` tensor, bit-equal to ``features_sweep(source.read(
    name), epss, cfg)``, with at most one ``budget_bytes`` chunk of the
    variable resident at a time.

    ``quality=True`` streams the fused "both" sweep -- each chunk launch
    emits the concatenated (k_chunk, e, 4) features+quality tensor from
    one read -- and returns the pair ``(features (k, e, 2), quality
    (k, e, 2))``, each half bit-equal to its in-memory counterpart
    (``features_sweep`` / ``quality_sweep``).

    ``digest``: a :class:`repro.data.source.StreamingDigest` updated
    with every chunk in row order; after the call ``digest.digest()``
    equals ``serve.method.slice_digest`` of the fully materialized
    variable (the out-of-core FeatureCache key) without the variable
    ever having been resident.  Single-process only: under a
    process-spanning mesh each process reads only its block, so no
    process sees every byte.

    Under a process-spanning mesh (``dist_init`` + a mesh over every
    process's devices) the call is COLLECTIVE: every process streams the
    same schedule, reads only its ``process_block`` rows of each chunk,
    and returns the identical full tensor.
    """
    cfg = cfg if cfg is not None else PRED.PredictorConfig()
    stream = stream if stream is not None else StreamConfig()
    PRED._validate_eps_positive(epss)
    epss_np = np.asarray(epss, np.float32).reshape(-1)
    meta = source.meta(name)
    if len(meta.shape) not in (3, 4):
        raise ValueError(
            f"stream_features expects a (k, m, n) or (k, d, m, n) "
            f"variable, got {name!r} with shape {meta.shape}")
    mode = "both" if quality else "features"
    width = PRED.SWEEP_MODE_WIDTHS[mode]
    k = meta.rows
    if k == 0:
        empty = np.zeros((0, len(epss_np), width), np.float32)
        return (empty[..., :2], empty[..., 2:]) if quality else empty
    mesh = DS.active_sweep_mesh(mesh)
    multiproc = DS.mesh_spans_processes(mesh)
    if multiproc and digest is not None:
        raise ValueError(
            "digest= is single-process only: under a process-spanning "
            "mesh each process reads only its block of every chunk, so "
            "no single process observes the variable's full byte stream")
    chunk = rows_per_chunk(meta, stream.budget_bytes)
    schedule = chunk_schedule(k, chunk, mesh)

    results: list = [None] * len(schedule)
    pending: deque = deque()                 # (index, launch, real_rows)

    def drain_one() -> None:
        idx, out, rows = pending.popleft()
        results[idx] = np.asarray(DS.gather_rows(out)[:rows], np.float32)

    chunks = _staged_chunks(source, name, schedule,
                            stream.prefetch, digest)
    for idx, (lo, hi, arr) in enumerate(chunks):
        rows = hi - lo
        if multiproc:
            # collective per-chunk launch; gather_rows inside
            # features_sweep_sharded is the synchronization point, so
            # the result is already on the host
            out = DS.features_sweep_sharded(
                arr, epss_np, cfg, mesh=mesh, gather=True,
                process_local=True, global_k=rows, donate=True, mode=mode)
            results[idx] = np.asarray(out, np.float32)
            continue
        # every chunk launches padded to the SAME bucket (the full-chunk
        # row count): one compiled executable serves the whole stream,
        # ragged final chunk included, and the fresh staging copy's
        # upload is donated (zero-copy ingestion)
        out = DS.sweep_padded(arr, epss_np, cfg, k_pad=chunk, mesh=mesh,
                              donate=True, mode=mode)
        pending.append((idx, out, rows))
        # async dispatch: block only when the in-flight window is full
        # (prefetch=0 keeps the strictly synchronous baseline semantics)
        while pending and (stream.prefetch <= 0
                           or len(pending) > stream.max_in_flight):
            drain_one()
    while pending:
        drain_one()
    full = np.concatenate(results, axis=0)
    if quality:
        return full[..., :2], full[..., 2:]
    return full


def stream_dataset(
    source: DatasetSource,
    epss,
    cfg: Optional[PRED.PredictorConfig] = None,
    *,
    stream: Optional[StreamConfig] = None,
    mesh=None,
    digests: Optional[Dict[str, str]] = None,
) -> Dict[str, np.ndarray]:
    """:func:`stream_features` over every variable of ``source``;
    returns ``{variable: (k, e, 2)}``.  ``digests`` (when given, and on
    a single process) is filled with each variable's streaming content
    digest -- the FeatureCache key of the whole variable."""
    out: Dict[str, np.ndarray] = {}
    multiproc = DS.mesh_spans_processes(DS.active_sweep_mesh(mesh))
    for name in source.variables():
        d = StreamingDigest() if digests is not None and not multiproc \
            else None
        out[name] = stream_features(source, name, epss, cfg, stream=stream,
                                    mesh=mesh, digest=d)
        if d is not None:
            digests[name] = d.digest()
    return out
