"""The paper's two production use cases (sections 1 and 5), plus UC3.

UC1 -- fixed-ratio configuration: find the error bound at which a compressor
       achieves a target CR.  OptZConfig-style iterative search, but each
       probe evaluates the *statistical model* instead of running the
       compressor (the paper's >= 8.8x speedup).
UC2 -- best-compressor selection: rank a set of compressors by predicted CR
       at a fixed error bound without running any of them (>= 7.8x speedup).
UC3 -- joint ratio-quality configuration (beyond the paper; Jin et al.,
       arXiv 2111.09815): the cheapest (compressor, eb) meeting a PSNR
       floor AND a CR floor simultaneously, by bisection over the
       monotone joint frontier (:func:`find_setting`).

Cross-error-bound modelling follows section 4.4: per-eb regressions are fit
on a small grid of error bounds and model predictions are interpolated in
log(eps) (the paper observes coefficients vary smoothly/low-order in eb).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.core import pipeline as PL
from repro.core import predictors as P
from repro.core.regression import predict_fast
from repro.kernels.quality import PSNR_CAP
from repro import compressors as C


# Model outputs pass through np.log during cross-eb interpolation and
# bisection compares the result against the target ratio, so a degenerate
# regression (extrapolation far outside the training range) must never
# yield log(<=0) = NaN: clamp predicted CRs into a positive finite band.
# +inf must clamp to the CEILING (still "far above any target"), not the
# floor, or bisection would discard the wrong half of the bracket; NaN
# carries no direction, so it lands on the floor.
_CR_FLOOR = 1e-9
_CR_CEIL = 1e9


def _clamp_cr(value) -> float:
    v = float(value)
    if np.isnan(v):
        return _CR_FLOOR
    return float(np.clip(v, _CR_FLOOR, _CR_CEIL))


@dataclasses.dataclass
class QualityTable:
    """Per-grid-eb quality models riding next to the CR models.

    For each grid eb a least-squares affine map from the 2 predictor
    features to the quantization proxy's PSNR (labels come from the
    fused ``kernels/quality`` half of the SAME training sweep -- zero
    extra passes over the data, and UC3 queries ride the same
    SliceCache / coalesced-launch features UC1 does).  The proxy PSNR is
    compressor-independent (it depends only on the data and the eb), but
    the table lives per :class:`EbGridModel` so each compressor's grid
    carries its own quality curve.
    """
    coef: np.ndarray                      # (e, 3): [w_qent, w_trunc, bias]
    mean_psnr: np.ndarray                 # (e,) training-set mean PSNR
    mean_nrmse: np.ndarray                # (e,) training-set mean NRMSE

    @staticmethod
    def fit(feats, qual) -> "QualityTable":
        """(k, e, 2) features x (k, e, 2) [psnr, nrmse] labels -> table.

        ``lstsq`` returns the min-norm solution, so degenerate designs
        (k=1, constant features) fit cleanly instead of raising."""
        feats = np.asarray(feats, np.float64)
        qual = np.asarray(qual, np.float64)
        k, e, _ = feats.shape
        coef = np.zeros((e, 3), np.float64)
        for i in range(e):
            a = np.concatenate([feats[:, i, :], np.ones((k, 1))], axis=1)
            y = np.clip(qual[:, i, 0], -PSNR_CAP, PSNR_CAP)
            sol, *_ = np.linalg.lstsq(a, y, rcond=None)
            if not np.all(np.isfinite(sol)):
                sol = np.array([0.0, 0.0, float(np.mean(y))])
            coef[i] = sol
        return QualityTable(coef, qual[:, :, 0].mean(axis=0),
                            qual[:, :, 1].mean(axis=0))

    def predict_one(self, i: int, feats) -> float:
        """Predicted proxy PSNR (dB) at grid index ``i`` from a (2,)
        feature vector, clamped to the kernel's +-PSNR_CAP band."""
        f = np.asarray(feats, np.float64).reshape(-1)
        v = self.coef[i, 0] * f[0] + self.coef[i, 1] * f[1] + self.coef[i, 2]
        if not np.isfinite(v):
            v = self.mean_psnr[i]
        return float(np.clip(v, -PSNR_CAP, PSNR_CAP))


@dataclasses.dataclass
class EbGridModel:
    """CR predictor across error bounds: one model per grid eb +
    log-linear interpolation of log(CR) between neighbouring grid points."""
    ebs: np.ndarray                       # ascending error-bound grid
    models: list                          # CRPredictor per eb
    name: str = ""
    cfg: P.PredictorConfig = dataclasses.field(default_factory=P.PredictorConfig)
    quality: Optional[QualityTable] = None

    @staticmethod
    def train(
        slices: jnp.ndarray,
        compressor: str,
        ebs: Sequence[float],
        model: str = "spline",
        cfg: P.PredictorConfig = P.PredictorConfig(),
        mesh=None,
        ndim: int = 2,
    ) -> "EbGridModel":
        """``ndim=2``: (k, m, n) slice stack; ``ndim=3``: (k, d, m, n)
        volume stack (HOSVD featurization -- UC1/UC2 over the
        ``compressors.STUDY_3D`` set run on the resulting model exactly
        like the 2-D path)."""
        if slices.ndim != ndim + 1:
            raise ValueError(
                f"EbGridModel.train(ndim={ndim}) expects a rank-{ndim + 1} "
                f"stack, got {slices.shape}")
        comp = C.get(compressor)
        # ONE fused sweep featurizes every (slice, grid-eb) pair: the
        # SVD/HOSVD runs once per slice and each slice is read once for
        # all ebs, instead of the old per-eb re-featurization.  Under a
        # mesh the sweep shards the slice axis across devices; the per-eb
        # fits are tiny, so features are all-gathered (np.asarray) while
        # the training-time compressor runs execute on local shards only
        # (partitioned over processes, all-gathered as a (k, e) table).
        from repro.dist import sweep as DS
        # quality=True: the SAME pass also emits the fused PSNR/NRMSE
        # tensor, which becomes the training labels of the quality table
        feats, qual = P.get_engine(cfg).sweep(
            slices, np.asarray(ebs, np.float64), mesh=mesh, quality=True)
        feats = np.asarray(feats)
        # the compressor-run partition reuses the SAME mesh the sweep
        # sharded over (its processes), not an ad-hoc runtime-wide split
        cr_table = DS.training_crs(comp, slices, ebs,
                                   mesh=DS.active_sweep_mesh(mesh))
        models = []
        for i, eps in enumerate(ebs):
            models.append(PL.CRPredictor.train_from_features(
                jnp.asarray(feats[:, i, :]), jnp.asarray(cr_table[:, i]),
                float(eps), model, cfg, ndim))
        return EbGridModel(np.asarray(ebs, np.float64), models, compressor,
                           cfg, QualityTable.fit(feats, np.asarray(qual)))

    @property
    def ndim(self) -> int:
        """Training data rank: 2 (slices) or 3 (volumes)."""
        return self.models[0].ndim if self.models else 2

    def _check_rank(self, data) -> None:
        if np.ndim(data) != self.ndim:
            raise ValueError(
                f"EbGridModel '{self.name}' was trained on "
                f"{self.ndim}-D data; got rank-{np.ndim(data)} input "
                f"{np.shape(data)}")

    def log_ebs(self) -> np.ndarray:
        """log of the eb grid, computed once per model (every bisection
        probe used to recompute it)."""
        lg = getattr(self, "_log_ebs", None)
        if lg is None:
            lg = self._log_ebs = np.log(self.ebs)
        return lg

    def predict(self, data: jnp.ndarray, eps: float,
                feat_cache=None) -> float:
        """Predicted CR for one slice (or (d, m, n) volume) at an
        arbitrary eb (log-interp).

        ``feat_cache``: a ``predictors.SliceCache`` (or any callable
        eps -> (2,)); reuses the eps-independent SVD/sigma across the
        whole sweep (the paper's UC1 cost structure)."""
        self._check_rank(data)
        if feat_cache is None:
            # featurize under the SAME config the models were trained with
            feat_cache = P.get_engine(self.cfg).cached(data)
        le = np.log(eps)
        lg = self.log_ebs()
        if le <= lg[0]:
            i0, i1, t = 0, 0, 0.0
        elif le >= lg[-1]:
            i0, i1, t = len(lg) - 1, len(lg) - 1, 0.0
        else:
            i1 = int(np.searchsorted(lg, le))
            if le == lg[i1]:
                # exact interior grid point: one model evaluation
                # suffices (t would come out 1.0 and cost two)
                i0, t = i1, 0.0
            else:
                i0 = i1 - 1
                t = (le - lg[i0]) / (lg[i1] - lg[i0])
        # q-ent is eb-dependent -> evaluate features at the grid ebs
        f0 = feat_cache(self.ebs[i0])[None]
        c0 = _clamp_cr(predict_fast(self.models[i0].model, f0)[0])
        if i1 == i0:
            return c0
        f1 = feat_cache(self.ebs[i1])[None]
        c1 = _clamp_cr(predict_fast(self.models[i1].model, f1)[0])
        return float(np.exp((1 - t) * np.log(c0) + t * np.log(c1)))

    def predict_psnr(self, data: jnp.ndarray, eps: float,
                     feat_cache=None) -> float:
        """Predicted proxy PSNR (dB) for one slice/volume at an
        arbitrary eb: the per-grid-eb quality models evaluated on the
        same cached features as :meth:`predict`, linear in log(eps)
        between grid points (PSNR is already a log-domain quantity)."""
        if self.quality is None:
            raise ValueError(
                f"EbGridModel '{self.name}' has no quality table; retrain "
                "with EbGridModel.train (quality models are fit from the "
                "same fused sweep that features the CR models)")
        self._check_rank(data)
        if feat_cache is None:
            feat_cache = P.get_engine(self.cfg).cached(data)
        le = np.log(eps)
        lg = self.log_ebs()
        if le <= lg[0]:
            i0, i1, t = 0, 0, 0.0
        elif le >= lg[-1]:
            i0, i1, t = len(lg) - 1, len(lg) - 1, 0.0
        else:
            i1 = int(np.searchsorted(lg, le))
            if le == lg[i1]:
                i0, t = i1, 0.0
            else:
                i0 = i1 - 1
                t = (le - lg[i0]) / (lg[i1] - lg[i0])
        p0 = self.quality.predict_one(i0, feat_cache(self.ebs[i0]))
        if i1 == i0:
            return p0
        p1 = self.quality.predict_one(i1, feat_cache(self.ebs[i1]))
        return float((1 - t) * p0 + t * p1)


def find_error_bound_for_cr(
    grid_model: EbGridModel,
    data: jnp.ndarray,
    target_cr: float,
    tol: float = 0.02,
    max_iters: int = 32,
    feat_cache=None,
) -> tuple[float, float]:
    """UC1: bisection on log(eps) using the statistical model only.

    Returns (eps, predicted_cr).  CR(eps) is monotone nondecreasing, so
    bisection converges; the model evaluation replaces compressor runs.

    ``feat_cache``: an externally supplied eps -> (2,) feature source
    (e.g. a :class:`predictors.SliceCache` seeded by the coalescing sweep
    service from a shared batched launch or its cross-request cache); it
    must already cover the model-grid ebs.  When None, ONE fused sweep up
    front covers every probe: SVD once, the slice read once, all grid
    q-ents from a single kernel launch.
    """
    # Bisection only ever evaluates features at the model-grid ebs.
    grid_model._check_rank(data)
    if feat_cache is None:
        feat_cache = P.get_engine(grid_model.cfg).cached(data)
        feat_cache.prefetch(grid_model.ebs)

    lo, hi = float(grid_model.ebs[0]), float(grid_model.ebs[-1])
    cr_lo = grid_model.predict(data, lo, feat_cache)
    cr_hi = grid_model.predict(data, hi, feat_cache)
    if target_cr <= cr_lo:
        return lo, cr_lo
    if target_cr >= cr_hi:
        return hi, cr_hi
    # max_iters=0 must still return a finite probe (mirrors
    # find_error_bound_exhaustive), not NameError on unbound loop vars
    mid, cr_mid = hi, cr_hi
    for _ in range(max_iters):
        mid = float(np.exp(0.5 * (np.log(lo) + np.log(hi))))
        cr_mid = grid_model.predict(data, mid, feat_cache)
        if abs(cr_mid - target_cr) / target_cr < tol:
            return mid, cr_mid
        if cr_mid < target_cr:
            lo = mid
        else:
            hi = mid
    return mid, cr_mid


def find_error_bound_exhaustive(
    compressor: str,
    data: jnp.ndarray,
    target_cr: float,
    lo: float,
    hi: float,
    tol: float = 0.02,
    max_iters: int = 32,
) -> tuple[float, float, int]:
    """UC1 baseline: same bisection but *running the compressor* per probe
    (what OptZConfig does).  Returns (eps, cr, num_compressor_runs)."""
    comp = C.get(compressor)
    runs = 0
    cr_lo = comp.cr(data, lo); runs += 1
    cr_hi = comp.cr(data, hi); runs += 1
    if target_cr <= cr_lo:
        return lo, cr_lo, runs
    if target_cr >= cr_hi:
        return hi, cr_hi, runs
    mid, cr_mid = hi, cr_hi
    for _ in range(max_iters):
        mid = float(np.exp(0.5 * (np.log(lo) + np.log(hi))))
        cr_mid = comp.cr(data, mid); runs += 1
        if abs(cr_mid - target_cr) / target_cr < tol:
            break
        if cr_mid < target_cr:
            lo = mid
        else:
            hi = mid
    return mid, cr_mid, runs


def best_compressor(
    models: Dict[str, object],
    data: jnp.ndarray,
    eps: float,
    feats=None,
) -> tuple[str, Dict[str, float]]:
    """UC2: rank compressors by predicted CR; no compressor executions.

    ``models``: name -> trained CRPredictor at this eps.  The expensive
    featurization (SVD + q-ent) is shared across compressors -- computed
    once by the engine, fed to every model (the paper's key UC2 cost
    structure).  ``feats``: an externally supplied (1, 2) feature matrix
    for ``data`` at ``eps`` (coalescing sweep service / cross-request
    cache); when None the engine featurizes here.
    """
    if not models:
        raise ValueError(
            "best_compressor needs at least one trained model; got an "
            "empty models dict (train CRPredictors per compressor first)")
    ndims = {m.ndim for m in models.values()}
    if len(ndims) > 1:
        raise ValueError(
            f"best_compressor models mix training ndims {sorted(ndims)}; "
            "features are shared across models, so all must be trained "
            "on the same data rank")
    model_ndim = ndims.pop()
    if np.ndim(data) != model_ndim:
        raise ValueError(
            f"best_compressor models were trained on {model_ndim}-D data; "
            f"got rank-{np.ndim(data)} input {np.shape(data)}")
    if feats is None:
        # featurize under the config the models were trained with
        cfg = next(iter(models.values())).cfg
        feats = P.get_engine(cfg).features(data[None], eps)
    preds = {name: float(predict_fast(m.model, feats)[0])
             for name, m in models.items()}
    return max(preds, key=preds.get), preds


@dataclasses.dataclass(frozen=True)
class JointSetting:
    """UC3 result: the cheapest (compressor, eb) meeting both floors.

    "Cheapest" = largest predicted CR among the settings that satisfy
    PSNR >= psnr_floor AND CR >= cr_floor.  ``feasible=False`` is the
    TYPED infeasible result: ``compressor``/``eb`` then carry the
    best-achievable diagnostic setting (highest CR inside the quality
    region, or the least-bad quality point when no compressor reaches
    the PSNR floor at all) and ``reason`` says which floor failed.
    ``candidates`` holds the per-compressor frontier diagnostics.
    """
    feasible: bool
    compressor: Optional[str]
    eb: Optional[float]
    predicted_cr: Optional[float]
    predicted_psnr: Optional[float]
    reason: str = ""
    candidates: Dict[str, dict] = dataclasses.field(default_factory=dict)


def find_setting(
    models: Dict[str, EbGridModel],
    data: jnp.ndarray,
    *,
    cr_floor: float,
    psnr_floor: float,
    tol: float = 1e-3,
    max_iters: int = 48,
    feat_cache=None,
) -> JointSetting:
    """UC3: cheapest (compressor, eb) with PSNR >= ``psnr_floor`` and
    CR >= ``cr_floor``, via bisection over the monotone joint frontier.

    Per compressor the grid PSNR curve is monotonized nonincreasing in
    eb and the grid CR curve nondecreasing (both physically monotone;
    monotonization absorbs regression noise), so the quality-feasible
    region is the eb interval [grid floor, eb_q] and the best CR inside
    it sits at eb_q -- found by bisection on log(eb) with the invariant
    ``psnr(lo) >= floor > psnr(hi)``, then SNAPPED UP to the largest
    quality-feasible grid eb.  The snap makes the search grid-complete
    regardless of ``max_iters``: whenever some grid point satisfies both
    (monotonized) floors, the returned setting is feasible, because
    eb_q never undershoots a feasible grid point and CR is
    nondecreasing toward it.

    ``feat_cache``: shared eps -> (2,) feature source covering every
    model's grid ebs (the serving layer seeds one from coalesced
    launches); when None, one engine cache per distinct grid is
    prefetched here -- featurization still happens once, not per
    compressor.  Ties prefer the lexicographically first compressor
    name (deterministic across runs).
    """
    if not models:
        raise ValueError(
            "find_setting needs at least one trained EbGridModel; got an "
            "empty models dict")
    ndims = {m.ndim for m in models.values()}
    if len(ndims) > 1:
        raise ValueError(
            f"find_setting models mix training ndims {sorted(ndims)}; "
            "features are shared across models, so all must be trained "
            "on the same data rank")
    missing = sorted(n for n, m in models.items() if m.quality is None)
    if missing:
        raise ValueError(
            f"find_setting needs a quality table on every model; missing "
            f"on {missing} (retrain with EbGridModel.train)")
    first = next(iter(models.values()))
    first._check_rank(data)
    if feat_cache is None:
        cfgs = {m.cfg for m in models.values()}
        if len(cfgs) > 1:
            raise ValueError(
                "find_setting models mix predictor configs; features are "
                "shared across models, so all must use one config")
        feat_cache = P.get_engine(first.cfg).cached(data)
        for grid in {tuple(float(e) for e in m.ebs) for m in models.values()}:
            feat_cache.prefetch(np.asarray(grid, np.float64))

    candidates: Dict[str, dict] = {}
    best: Optional[str] = None
    for name in sorted(models):
        gm = models[name]
        lg = gm.log_ebs()
        pg = np.minimum.accumulate(
            [gm.predict_psnr(data, float(e), feat_cache) for e in gm.ebs])
        cg = np.maximum.accumulate(
            [gm.predict(data, float(e), feat_cache) for e in gm.ebs])
        lcg = np.log(cg)          # cg is _clamp_cr-positive, log is finite

        if pg[0] < psnr_floor:
            # even the finest grid eb misses the quality floor
            candidates[name] = {
                "quality_ok": False, "cr_ok": False, "eb": float(gm.ebs[0]),
                "psnr": float(pg[0]), "cr": float(cg[0])}
            continue
        if pg[-1] >= psnr_floor:
            le_q = float(lg[-1])
        else:
            lo, hi = float(lg[0]), float(lg[-1])
            for _ in range(max_iters):
                if hi - lo < tol:
                    break
                mid = 0.5 * (lo + hi)
                if float(np.interp(mid, lg, pg)) >= psnr_floor:
                    lo = mid
                else:
                    hi = mid
            # grid-snap: never land below the largest quality-feasible
            # grid eb (grid-completeness must not depend on max_iters)
            j_star = int(np.nonzero(pg >= psnr_floor)[0][-1])
            le_q = max(lo, float(lg[j_star]))
        eb_q = float(np.exp(le_q))
        # exp(interp(log cr)) can round a hair BELOW the exact grid
        # value; the curve is nondecreasing, so the last grid point at
        # or under le_q is an exact lower bound -- without it a floor
        # sitting exactly on the frontier tests infeasible by one ulp
        jlo = int(np.searchsorted(lg, le_q + 1e-12, side="right") - 1)
        cr_q = float(max(np.exp(np.interp(le_q, lg, lcg)), cg[jlo]))
        psnr_q = float(np.interp(le_q, lg, pg))
        cr_ok = cr_q >= cr_floor
        candidates[name] = {
            "quality_ok": True, "cr_ok": bool(cr_ok), "eb": eb_q,
            "psnr": psnr_q, "cr": cr_q}
        if cr_ok and (best is None or cr_q > candidates[best]["cr"]):
            best = name

    if best is not None:
        c = candidates[best]
        return JointSetting(
            True, best, c["eb"], c["cr"], c["psnr"],
            reason="cheapest setting meeting both floors", candidates=candidates)
    q_ok = {n: c for n, c in candidates.items() if c["quality_ok"]}
    if q_ok:
        name = min(q_ok, key=lambda n: (-q_ok[n]["cr"], n))
        c = q_ok[name]
        return JointSetting(
            False, name, c["eb"], c["cr"], c["psnr"],
            reason=(f"no compressor reaches CR >= {cr_floor:g} inside the "
                    f"PSNR >= {psnr_floor:g} region; best achievable CR is "
                    f"{c['cr']:.3g}"),
            candidates=candidates)
    name = min(candidates, key=lambda n: (-candidates[n]["psnr"], n))
    c = candidates[name]
    return JointSetting(
        False, name, c["eb"], c["cr"], c["psnr"],
        reason=(f"PSNR floor {psnr_floor:g} is unreachable on every grid "
                f"(best {c['psnr']:.1f} dB at the finest eb)"),
        candidates=candidates)


def best_compressor_exhaustive(
    names: Sequence[str],
    data: jnp.ndarray,
    eps: float,
) -> tuple[str, Dict[str, float]]:
    """UC2 baseline: run every compressor (Tao et al. 2019b procedure)."""
    crs = {n: C.get(n).cr(data, eps) for n in names}
    return max(crs, key=crs.get), crs
