"""The paper's two production use cases (sections 1 and 5).

UC1 -- fixed-ratio configuration: find the error bound at which a compressor
       achieves a target CR.  OptZConfig-style iterative search, but each
       probe evaluates the *statistical model* instead of running the
       compressor (the paper's >= 8.8x speedup).
UC2 -- best-compressor selection: rank a set of compressors by predicted CR
       at a fixed error bound without running any of them (>= 7.8x speedup).

Cross-error-bound modelling follows section 4.4: per-eb regressions are fit
on a small grid of error bounds and model predictions are interpolated in
log(eps) (the paper observes coefficients vary smoothly/low-order in eb).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Sequence

import numpy as np
import jax.numpy as jnp

from repro.core import pipeline as PL
from repro.core import predictors as P
from repro.core.regression import predict_fast
from repro import compressors as C


# Model outputs pass through np.log during cross-eb interpolation and
# bisection compares the result against the target ratio, so a degenerate
# regression (extrapolation far outside the training range) must never
# yield log(<=0) = NaN: clamp predicted CRs into a positive finite band.
# +inf must clamp to the CEILING (still "far above any target"), not the
# floor, or bisection would discard the wrong half of the bracket; NaN
# carries no direction, so it lands on the floor.
_CR_FLOOR = 1e-9
_CR_CEIL = 1e9


def _clamp_cr(value) -> float:
    v = float(value)
    if np.isnan(v):
        return _CR_FLOOR
    return float(np.clip(v, _CR_FLOOR, _CR_CEIL))


@dataclasses.dataclass
class EbGridModel:
    """CR predictor across error bounds: one model per grid eb +
    log-linear interpolation of log(CR) between neighbouring grid points."""
    ebs: np.ndarray                       # ascending error-bound grid
    models: list                          # CRPredictor per eb
    name: str = ""
    cfg: P.PredictorConfig = dataclasses.field(default_factory=P.PredictorConfig)

    @staticmethod
    def train(
        slices: jnp.ndarray,
        compressor: str,
        ebs: Sequence[float],
        model: str = "spline",
        cfg: P.PredictorConfig = P.PredictorConfig(),
        mesh=None,
        ndim: int = 2,
    ) -> "EbGridModel":
        """``ndim=2``: (k, m, n) slice stack; ``ndim=3``: (k, d, m, n)
        volume stack (HOSVD featurization -- UC1/UC2 over the
        ``compressors.STUDY_3D`` set run on the resulting model exactly
        like the 2-D path)."""
        if slices.ndim != ndim + 1:
            raise ValueError(
                f"EbGridModel.train(ndim={ndim}) expects a rank-{ndim + 1} "
                f"stack, got {slices.shape}")
        comp = C.get(compressor)
        # ONE fused sweep featurizes every (slice, grid-eb) pair: the
        # SVD/HOSVD runs once per slice and each slice is read once for
        # all ebs, instead of the old per-eb re-featurization.  Under a
        # mesh the sweep shards the slice axis across devices; the per-eb
        # fits are tiny, so features are all-gathered (np.asarray) while
        # the training-time compressor runs execute on local shards only
        # (partitioned over processes, all-gathered as a (k, e) table).
        from repro.dist import sweep as DS
        feats = np.asarray(
            P.get_engine(cfg).sweep(slices, np.asarray(ebs, np.float64),
                                    mesh=mesh))
        # the compressor-run partition reuses the SAME mesh the sweep
        # sharded over (its processes), not an ad-hoc runtime-wide split
        cr_table = DS.training_crs(comp, slices, ebs,
                                   mesh=DS.active_sweep_mesh(mesh))
        models = []
        for i, eps in enumerate(ebs):
            models.append(PL.CRPredictor.train_from_features(
                jnp.asarray(feats[:, i, :]), jnp.asarray(cr_table[:, i]),
                float(eps), model, cfg, ndim))
        return EbGridModel(np.asarray(ebs, np.float64), models, compressor, cfg)

    @property
    def ndim(self) -> int:
        """Training data rank: 2 (slices) or 3 (volumes)."""
        return self.models[0].ndim if self.models else 2

    def _check_rank(self, data) -> None:
        if np.ndim(data) != self.ndim:
            raise ValueError(
                f"EbGridModel '{self.name}' was trained on "
                f"{self.ndim}-D data; got rank-{np.ndim(data)} input "
                f"{np.shape(data)}")

    def log_ebs(self) -> np.ndarray:
        """log of the eb grid, computed once per model (every bisection
        probe used to recompute it)."""
        lg = getattr(self, "_log_ebs", None)
        if lg is None:
            lg = self._log_ebs = np.log(self.ebs)
        return lg

    def predict(self, data: jnp.ndarray, eps: float,
                feat_cache=None) -> float:
        """Predicted CR for one slice (or (d, m, n) volume) at an
        arbitrary eb (log-interp).

        ``feat_cache``: a ``predictors.SliceCache`` (or any callable
        eps -> (2,)); reuses the eps-independent SVD/sigma across the
        whole sweep (the paper's UC1 cost structure)."""
        self._check_rank(data)
        if feat_cache is None:
            # featurize under the SAME config the models were trained with
            feat_cache = P.get_engine(self.cfg).cached(data)
        le = np.log(eps)
        lg = self.log_ebs()
        if le <= lg[0]:
            i0, i1, t = 0, 0, 0.0
        elif le >= lg[-1]:
            i0, i1, t = len(lg) - 1, len(lg) - 1, 0.0
        else:
            i1 = int(np.searchsorted(lg, le))
            if le == lg[i1]:
                # exact interior grid point: one model evaluation
                # suffices (t would come out 1.0 and cost two)
                i0, t = i1, 0.0
            else:
                i0 = i1 - 1
                t = (le - lg[i0]) / (lg[i1] - lg[i0])
        # q-ent is eb-dependent -> evaluate features at the grid ebs
        f0 = feat_cache(self.ebs[i0])[None]
        c0 = _clamp_cr(predict_fast(self.models[i0].model, f0)[0])
        if i1 == i0:
            return c0
        f1 = feat_cache(self.ebs[i1])[None]
        c1 = _clamp_cr(predict_fast(self.models[i1].model, f1)[0])
        return float(np.exp((1 - t) * np.log(c0) + t * np.log(c1)))


def find_error_bound_for_cr(
    grid_model: EbGridModel,
    data: jnp.ndarray,
    target_cr: float,
    tol: float = 0.02,
    max_iters: int = 32,
    feat_cache=None,
) -> tuple[float, float]:
    """UC1: bisection on log(eps) using the statistical model only.

    Returns (eps, predicted_cr).  CR(eps) is monotone nondecreasing, so
    bisection converges; the model evaluation replaces compressor runs.

    ``feat_cache``: an externally supplied eps -> (2,) feature source
    (e.g. a :class:`predictors.SliceCache` seeded by the coalescing sweep
    service from a shared batched launch or its cross-request cache); it
    must already cover the model-grid ebs.  When None, ONE fused sweep up
    front covers every probe: SVD once, the slice read once, all grid
    q-ents from a single kernel launch.
    """
    # Bisection only ever evaluates features at the model-grid ebs.
    grid_model._check_rank(data)
    if feat_cache is None:
        feat_cache = P.get_engine(grid_model.cfg).cached(data)
        feat_cache.prefetch(grid_model.ebs)

    lo, hi = float(grid_model.ebs[0]), float(grid_model.ebs[-1])
    cr_lo = grid_model.predict(data, lo, feat_cache)
    cr_hi = grid_model.predict(data, hi, feat_cache)
    if target_cr <= cr_lo:
        return lo, cr_lo
    if target_cr >= cr_hi:
        return hi, cr_hi
    # max_iters=0 must still return a finite probe (mirrors
    # find_error_bound_exhaustive), not NameError on unbound loop vars
    mid, cr_mid = hi, cr_hi
    for _ in range(max_iters):
        mid = float(np.exp(0.5 * (np.log(lo) + np.log(hi))))
        cr_mid = grid_model.predict(data, mid, feat_cache)
        if abs(cr_mid - target_cr) / target_cr < tol:
            return mid, cr_mid
        if cr_mid < target_cr:
            lo = mid
        else:
            hi = mid
    return mid, cr_mid


def find_error_bound_exhaustive(
    compressor: str,
    data: jnp.ndarray,
    target_cr: float,
    lo: float,
    hi: float,
    tol: float = 0.02,
    max_iters: int = 32,
) -> tuple[float, float, int]:
    """UC1 baseline: same bisection but *running the compressor* per probe
    (what OptZConfig does).  Returns (eps, cr, num_compressor_runs)."""
    comp = C.get(compressor)
    runs = 0
    cr_lo = comp.cr(data, lo); runs += 1
    cr_hi = comp.cr(data, hi); runs += 1
    if target_cr <= cr_lo:
        return lo, cr_lo, runs
    if target_cr >= cr_hi:
        return hi, cr_hi, runs
    mid, cr_mid = hi, cr_hi
    for _ in range(max_iters):
        mid = float(np.exp(0.5 * (np.log(lo) + np.log(hi))))
        cr_mid = comp.cr(data, mid); runs += 1
        if abs(cr_mid - target_cr) / target_cr < tol:
            break
        if cr_mid < target_cr:
            lo = mid
        else:
            hi = mid
    return mid, cr_mid, runs


def best_compressor(
    models: Dict[str, object],
    data: jnp.ndarray,
    eps: float,
    feats=None,
) -> tuple[str, Dict[str, float]]:
    """UC2: rank compressors by predicted CR; no compressor executions.

    ``models``: name -> trained CRPredictor at this eps.  The expensive
    featurization (SVD + q-ent) is shared across compressors -- computed
    once by the engine, fed to every model (the paper's key UC2 cost
    structure).  ``feats``: an externally supplied (1, 2) feature matrix
    for ``data`` at ``eps`` (coalescing sweep service / cross-request
    cache); when None the engine featurizes here.
    """
    if not models:
        raise ValueError(
            "best_compressor needs at least one trained model; got an "
            "empty models dict (train CRPredictors per compressor first)")
    ndims = {m.ndim for m in models.values()}
    if len(ndims) > 1:
        raise ValueError(
            f"best_compressor models mix training ndims {sorted(ndims)}; "
            "features are shared across models, so all must be trained "
            "on the same data rank")
    model_ndim = ndims.pop()
    if np.ndim(data) != model_ndim:
        raise ValueError(
            f"best_compressor models were trained on {model_ndim}-D data; "
            f"got rank-{np.ndim(data)} input {np.shape(data)}")
    if feats is None:
        # featurize under the config the models were trained with
        cfg = next(iter(models.values())).cfg
        feats = P.get_engine(cfg).features(data[None], eps)
    preds = {name: float(predict_fast(m.model, feats)[0])
             for name, m in models.items()}
    return max(preds, key=preds.get), preds


def best_compressor_exhaustive(
    names: Sequence[str],
    data: jnp.ndarray,
    eps: float,
) -> tuple[str, Dict[str, float]]:
    """UC2 baseline: run every compressor (Tao et al. 2019b procedure)."""
    crs = {n: C.get(n).cr(data, eps) for n in names}
    return max(crs, key=crs.get), crs
