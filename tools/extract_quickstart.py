"""Extract the README quickstart snippet(s), verbatim, for CI execution.

Prints every fenced ```python block of README.md concatenated in order
(the quickstart plus the mesh follow-on, which shares its variables), so
the docs-smoke job runs exactly what the README shows:

    python tools/extract_quickstart.py > /tmp/quickstart.py
    PYTHONPATH=src python /tmp/quickstart.py
"""
import os
import re
import sys

README = os.path.join(os.path.dirname(__file__), "..", "README.md")


def extract(text: str) -> str:
    blocks = re.findall(r"```python\n(.*?)```", text, flags=re.DOTALL)
    if not blocks:
        raise SystemExit("README.md has no ```python blocks")
    return "\n\n".join(blocks)


if __name__ == "__main__":
    with open(sys.argv[1] if len(sys.argv) > 1 else README) as f:
        sys.stdout.write(extract(f.read()))
