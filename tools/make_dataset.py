"""Deterministic multi-variable synthetic dataset writer.

Streaming tests and ``bench_stream`` need a FILE-BACKED fixture larger
than a (virtual) device's memory budget; this tool writes one from the
``repro.data.scientific`` field generators without ever materializing a
whole variable (chunked ``GeneratorSource`` -> ``write_dataset`` copy,
bounded by ``--budget-mb``).  Seeded and fully deterministic: the same
spec always produces byte-identical files, and 2-D slice variables are
bit-equal to ``scientific.field_slices(field, count, seed, n)``.

    PYTHONPATH=src python tools/make_dataset.py OUT \\
        --var miranda-vx:24:96 --var cesm-cloud:16:128 \\
        --var qmcpack:4:8:32:32 --format memmap --dtype float64

``--var field:count:n`` adds ``count`` rows of (n, n) 2-D slices;
``--var field:count:d:m:n`` adds ``count`` independent (d, m, n)
volumes (a rank-4 variable, written as ``<field>-vol``).  ``--format
memmap`` (default) writes a manifest directory readable by
``repro.data.source.MemmapSource``; ``--format npz`` writes a single
archive.  ``--dtype float64`` models real archives (readers pay the
f64->f32 ingest conversion).
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def parse_var(spec: str, seed: int):
    from repro.data import source as SRC
    parts = spec.split(":")
    if len(parts) not in (3, 5):
        raise SystemExit(
            f"--var {spec!r}: expected field:count:n (2-D slices) or "
            "field:count:d:m:n (volumes)")
    field, count = parts[0], int(parts[1])
    shape = tuple(int(p) for p in parts[2:])
    return SRC.FieldVariable(field, count, shape, seed=seed)


def main(argv=None) -> str:
    ap = argparse.ArgumentParser(
        prog="python tools/make_dataset.py",
        description="Write a deterministic multi-variable synthetic "
                    "dataset (memmap dir or .npz) for streaming sweeps.")
    ap.add_argument("out", help="output dataset path")
    ap.add_argument("--var", action="append", default=[],
                    help="field:count:n (slices) or field:count:d:m:n "
                         "(volumes); repeatable")
    ap.add_argument("--format", choices=("memmap", "npz"), default="memmap")
    ap.add_argument("--dtype", choices=("float32", "float64"),
                    default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-mb", type=float, default=64.0,
                    help="per-chunk byte budget while writing")
    args = ap.parse_args(argv)
    if not args.var:
        raise SystemExit("need at least one --var spec")

    from repro.data import source as SRC
    gen = SRC.GeneratorSource([parse_var(s, args.seed) for s in args.var])
    path = SRC.write_dataset(
        args.out, gen, fmt=args.format, dtype=args.dtype,
        budget_bytes=int(args.budget_mb * 2**20), seed=args.seed)
    total = sum(gen.meta(n).nbytes_f32 for n in gen.variables())
    print(f"wrote {path}: {len(gen.variables())} variables, "
          f"{total / 2**20:.1f} MiB (f32 equivalent)")
    for n in gen.variables():
        print(f"  {n}: shape={gen.meta(n).shape} dtype={args.dtype}")
    return path


if __name__ == "__main__":
    main()
