"""Shared benchmark utilities: timing, CSV emission, cached field data."""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Callable, Dict

import numpy as np
import jax
import jax.numpy as jnp

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
os.makedirs(RESULTS_DIR, exist_ok=True)

_ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def rows():
    return list(_ROWS)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5,
            **kwargs) -> float:
    """Compile-excluded median seconds per call (kernels.tune.time_fn).

    ``jax.block_until_ready`` on the full result pytree both in warmup
    (so compile time never leaks into the measurement) and per iter.
    """
    from repro.kernels import tune as _tune
    return _tune.time_fn(fn, *args, warmup=warmup, iters=iters, **kwargs)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (post-warmup)."""
    return time_fn(fn, *args, warmup=warmup, iters=iters) * 1e6


@functools.lru_cache(maxsize=32)
def field_slices_cached(name: str, count: int, n: int):
    from repro.data import scientific
    return scientific.field_slices(name, count=count, n=n)


@functools.lru_cache(maxsize=8)
def gaussian_cached(sample_type: int, count: int, n: int):
    from repro.data import gaussian
    return gaussian.sample_batch(sample_type, count=count, n=n)


@functools.lru_cache(maxsize=512)
def cr_cached(comp: str, field: str, count: int, n: int, eps: float,
              idx: int) -> float:
    from repro import compressors as C
    s = field_slices_cached(field, count, n)[idx]
    return C.get(comp).cr(s, eps)


def crs_for(comp: str, field: str, count: int, n: int, eps: float):
    return np.asarray([cr_cached(comp, field, count, n, eps, i)
                       for i in range(count)])


def free_port() -> int:
    """A free localhost TCP port (jax.distributed coordinator)."""
    import socket
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn_child_module(module: str, args, num_devices: int):
    """Start ``python -m module *args`` detached, with ``num_devices``
    virtual CPU devices (jax locks the device count at first init, so
    multi-device configurations cannot run in the parent).  Combine with
    :func:`wait_children`; multi-process fabrics spawn one child per
    process against a :func:`free_port` coordinator."""
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.dirname(os.path.dirname(__file__)),
         env.get("PYTHONPATH", "")])
    return subprocess.Popen(
        [sys.executable, "-m", module, *map(str, args)], env=env,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def wait_children(procs, timeout: int = 560) -> list:
    """Wait for :func:`spawn_child_module` children; on timeout every
    child is reaped (a hung collective must not leak processes).
    Asserts zero exits and returns the per-child (stdout, stderr)."""
    import subprocess
    try:
        outs = [p.communicate(timeout=timeout) for p in procs]
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        outs = [p.communicate() for p in procs]
        raise AssertionError("children timed out (hung collective?):\n" +
                             "\n".join(o + "\n" + e for o, e in outs))
    assert all(p.returncode == 0 for p in procs), "\n".join(
        f"rc={p.returncode}\n{o}\n{e}"
        for p, (o, e) in zip(procs, outs))
    return outs


def run_child_module(module: str, args, num_devices: int,
                     timeout: int = 560):
    """Run ``python -m module *args`` in one child interpreter (see
    :func:`spawn_child_module`); asserts a zero exit and returns the
    child's (stdout, stderr)."""
    proc = spawn_child_module(module, args, num_devices)
    return wait_children([proc], timeout=timeout)[0]


def save_json(name: str, obj):
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)
