"""Fig 4 + section 3.1.1 timing claim: predictor-vs-CR association and
the SVD-vs-variogram speed argument (we time SVD vs the Pallas-backed
Gram path; the paper reports SVD 0.44s vs variogram 17s on 1200^2)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro import compressors as C
from repro.core import pipeline as PL, predictors as P


def main() -> dict:
    out = {}
    field = "miranda-vx"
    slices = common.field_slices_cached(field, 28, 160)
    rng = float(jnp.max(slices) - jnp.min(slices))
    eps = 1e-4 * rng
    feats = np.asarray(PL.featurize_slices(slices, eps))
    for comp in ("sz2", "zfp"):
        crs = common.crs_for(comp, field, 28, 160, eps)
        logcr = np.log(crs)
        corr_ratio = float(np.corrcoef(feats[:, 1], logcr)[0, 1])
        corr_qent = float(np.corrcoef(feats[:, 0], logcr)[0, 1])
        out[comp] = {"corr_svd_sigma": corr_ratio, "corr_qent": corr_qent}
        common.emit(f"fig4/{field}/{comp}", 0.0,
                    f"corr_log_svdsigma={corr_ratio:.3f} "
                    f"corr_log_qent={corr_qent:.3f}")

    # SVD timing: jnp full SVD vs Gram+eigh (TPU-native path, Pallas kernel)
    x = common.field_slices_cached("scale-u", 1, 600)[0]
    t_full = common.timeit(
        lambda: jnp.linalg.svd(x, compute_uv=False), warmup=1, iters=2)
    t_gram = common.timeit(
        lambda: P.svd_trunc(x, use_kernel=False), warmup=1, iters=2)
    t_gram_k = common.timeit(
        lambda: P.svd_trunc(x, use_kernel=True), warmup=1, iters=2)
    out["svd_timing_us"] = {"full_svd": t_full, "gram_eigh": t_gram,
                            "gram_pallas": t_gram_k}
    common.emit("fig4/svd_timing", t_gram,
                f"full_svd_us={t_full:.0f} gram_eigh_us={t_gram:.0f} "
                f"gram_pallas_us={t_gram_k:.0f} "
                f"speedup_vs_full={t_full / t_gram:.1f}x")

    # Sweep engine: full (slices x error-bounds) predictor tensor in one
    # pass (see bench_sweep.py for the looped-baseline comparison)
    ebs = jnp.asarray([r * rng for r in (1e-4, 1e-3, 1e-2, 1e-1)])
    t_sweep = common.timeit(
        lambda: P.features_sweep(slices, ebs), warmup=1, iters=3)
    out["sweep_us"] = {"k": int(slices.shape[0]), "e": int(ebs.shape[0]),
                       "features_sweep": t_sweep}
    common.emit("fig4/sweep", t_sweep,
                f"k={slices.shape[0]} e={ebs.shape[0]} "
                f"us_per_pair={t_sweep / (slices.shape[0] * ebs.shape[0]):.0f}")
    common.save_json("fig4_predictors", out)
    return out


if __name__ == "__main__":
    main()
