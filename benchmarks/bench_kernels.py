"""Kernel microbenchmarks: Pallas (interpret on CPU / native on TPU) vs the
pure-jnp oracle, per shape."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common


def main() -> dict:
    out = {}
    x = common.field_slices_cached("miranda-vx", 1, 384)[0]
    eps = 1e-3 * float(jnp.max(x) - jnp.min(x))

    from repro.kernels.gram import ops as gops, ref as gref
    t_k = common.timeit(lambda: gops.gram(x), warmup=1, iters=2)
    t_r = common.timeit(lambda: gref.gram_xtx(x), warmup=1, iters=2)
    common.emit("kernels/gram_384", t_k, f"ref_us={t_r:.0f}")
    out["gram"] = {"kernel_us": t_k, "ref_us": t_r}

    from repro.kernels.qent import ops as qops, ref as qref
    t_k = common.timeit(lambda: qops.quantized_entropy(x, eps), warmup=1, iters=2)
    t_r = common.timeit(lambda: qref.quantized_entropy(x, eps), warmup=1, iters=2)
    common.emit("kernels/qent_384", t_k, f"ref_us={t_r:.0f}")
    out["qent"] = {"kernel_us": t_k, "ref_us": t_r}

    from repro.kernels.lorenzo import ops as lops, ref as lref
    t_k = common.timeit(lambda: lops.lorenzo2d(x, eps), warmup=1, iters=2)
    t_r = common.timeit(lambda: lref.lorenzo2d(x, eps), warmup=1, iters=2)
    common.emit("kernels/lorenzo_384", t_k, f"ref_us={t_r:.0f}")
    out["lorenzo"] = {"kernel_us": t_k, "ref_us": t_r}

    from repro.kernels.zfp_block import ops as zops, ref as zref
    t_k = common.timeit(lambda: zops.zfp_forward2d(x)[0], warmup=1, iters=2)
    t_r = common.timeit(lambda: zref.zfp_forward2d(x)[0], warmup=1, iters=2)
    common.emit("kernels/zfp_block_384", t_k, f"ref_us={t_r:.0f}")
    out["zfp_block"] = {"kernel_us": t_k, "ref_us": t_r}

    common.save_json("kernels", out)
    return out


if __name__ == "__main__":
    main()
