"""Table 3 / Fig 8: predictor importance (LASSO) and linear-regression
coefficients across error bounds."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core import pipeline as PL, regression as R

CASES = {
    "miranda-vx": 1e-5,
    "cesm-cloud": 1e-5,
    "scale-pressure": 1e-3,
}
EBS_REL = (1e-5, 1e-4, 1e-3, 1e-2)   # Fig 8 sweep
FIG8_COMPRESSORS = ["sz2", "sz3-lorenzo", "sz3-regression", "sz3-interp",
                    "zfp", "mgard", "bitgrooming", "digitrounding"]


def main() -> dict:
    out = {"table3": {}, "fig8": {}}
    # ---- Table 3: LASSO importances for SZ2 per dataset ------------------
    for field, eps_rel in CASES.items():
        slices = common.field_slices_cached(field, 24, 160)
        rng = float(jnp.max(slices) - jnp.min(slices))
        eps = eps_rel * rng
        feats = PL.featurize_slices(slices, eps)
        crs = common.crs_for("sz2", field, 24, 160, eps)
        imp = np.asarray(R.lasso_importance(feats, jnp.asarray(crs), k=6))
        out["table3"][field] = imp.tolist()
        common.emit(f"table3/{field}", 0.0,
                    f"qent={imp[0]:.3f} svd_sigma={imp[1]:.3f} "
                    f"interaction={imp[2]:.3f}")

    # ---- Fig 8: linear coefficients across error bounds (Gaussian-1) -----
    slices = common.gaussian_cached(1, 16, 192)
    from repro import compressors as C
    for comp in FIG8_COMPRESSORS:
        coefs = []
        for eps in EBS_REL:
            feats = PL.featurize_slices(slices, eps)
            crs = jnp.asarray([C.get(comp).cr(s, eps) for s in slices])
            m = R.LinearCRModel.fit(feats, crs)
            coefs.append(np.asarray(m.coef).tolist())
        out["fig8"][comp] = coefs
        a, b, c, d = zip(*coefs)
        common.emit(f"fig8/{comp}", 0.0,
                    f"intercept_trend={a[0]:.2f}->{a[-1]:.2f} "
                    f"qent={b[0]:.2f}->{b[-1]:.2f} "
                    f"svd={c[0]:.2f}->{c[-1]:.2f} "
                    f"inter={d[0]:.2f}->{d[-1]:.2f}")
    # mean log-CR (the intercept) must grow smoothly with looser bounds,
    # the paper's smooth-coefficient-transition claim
    ok = all(out["fig8"][c][-1][0] >= out["fig8"][c][0][0] - 0.25
             for c in FIG8_COMPRESSORS)
    common.emit("fig8/overall", 0.0,
                f"intercept_monotone_claim pass={ok}")
    common.save_json("table3_fig8_lasso", out)
    return out


if __name__ == "__main__":
    main()
