"""Sweep-service benchmark: coalesced vs serial dispatch + the bit-equal
and cross-request-cache gates (ISSUE 3 acceptance).

Scenarios (run in a child interpreter with 8 virtual CPU devices, since
the device count is locked at jax init):

* ``mixed``     -- a hot-field session: ROUNDS x 8 concurrent mixed
                   UC1/UC2 requests over 2 hot slices (1 target-CR search
                   + 3 best-compressor rankings per slice per round, UC1
                   targets varying by round).  Serial baseline = today's
                   per-request dispatch (``find_error_bound_for_cr`` /
                   ``best_compressor`` called one at a time, each
                   featurizing on its own, every round).  The service
                   coalesces round 1 into ONE launch of 2 deduplicated
                   rows and serves later rounds from the cross-request
                   cache with zero launches.  GATED: >= 3x session
                   throughput, bit-equal results.  The cold first round
                   alone (pure coalescing+dedup, no cache) is reported as
                   ``cold_speedup`` -- on a 2-core CI host its compute
                   parallelism is limited, the cache is what pays here.
* ``fanin``     -- 8 concurrent featurize requests of 2 slices each under
                   the mesh.  Serial = one auto-sharded launch per
                   request (each padded 2 -> 8 rows, the waste named in
                   the ROADMAP follow-on); coalesced = ONE packed 16-row
                   ``gather=False`` launch.  GATED: >= 1.5x, bit-equal.
* ``cache``     -- resubmitting a UC1 on a hot slice after the mixed run:
                   GATED: zero additional sweep launches.
* ``load_sweep``-- open-loop paced arrivals at 1x/3x/10x the mixed run's
                   measured request rate, mixing UC1/UC2/kv_gate over hot
                   fields against a warm cache.  Per-method p50/p95 (from
                   ``stats()["methods"]``) land in the JSON per rate.
                   GATED: worst per-method p95 at 10x stays bounded (the
                   adaptive micro-batch window must shrink under load
                   instead of letting queueing delay compound).

Writes machine-readable ``results/BENCH_serve.json`` (throughput, p50/p95
latency, cache hit rate, per-method load-sweep tails) so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np

FIELD = "miranda-vx"
N = 160                  # slice side
GRID_RELS = (1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 1e-2)
TRAIN = 10               # training slices for the grid / UC2 models
REPS = 3                 # timed repetitions (median)
ROUNDS = 4               # hot-field session rounds of 8 requests
DEVICES = 8

MIXED_GATE = 3.0
FANIN_GATE = 2.0
LOAD_REQS = 40           # paced requests per load-sweep rate
LOAD_MULTS = (1, 3, 10)
# p95 bound at 10x: generous absolute ceiling OR a multiple of the idle
# p50 -- CI hosts are 2-core, the gate is about tails not compounding
LOAD_P95_ABS_MS = 1500.0
LOAD_P95_REL = 20.0


def _percentiles(lat_s):
    ms = np.sort(np.asarray(lat_s)) * 1e3
    return float(np.percentile(ms, 50)), float(np.percentile(ms, 95))


def _child(out_path: str) -> None:
    import jax
    import jax.numpy as jnp
    from repro import compressors as C
    from repro.core import pipeline as PL, predictors as P, usecases as UC
    from repro.data import scientific
    from repro.dist import sharding as S
    from repro.launch import mesh as M
    from repro.serve.sweep_service import ServiceConfig, SweepService

    assert len(jax.devices()) == DEVICES, jax.devices()
    mesh = M.make_sweep_mesh()

    slices = scientific.field_slices(FIELD, count=TRAIN + 18, n=N)
    rng = float(jnp.max(slices) - jnp.min(slices))
    ebs = [r * rng for r in GRID_RELS]
    train = slices[:TRAIN]
    gm = UC.EbGridModel.train(train, "zfp", ebs)
    eps = ebs[3]
    uc2 = {}
    for name in ("zfp", "bitgrooming"):
        comp = C.get(name)
        crs = jnp.asarray([comp.cr(s, eps) for s in train])
        uc2[name] = PL.CRPredictor.train(train, crs, eps)

    hot = [slices[TRAIN], slices[TRAIN + 1]]
    round_targets = [(5.0, 8.0), (4.0, 9.5), (6.5, 7.0), (5.5, 8.5)][:ROUNDS]

    # ---- mixed UC1/UC2 hot-field session: ROUNDS x 8 requests ---------
    def serial_round(targets):
        out = []
        for x, t in zip(hot, targets):
            out.append(("uc1", UC.find_error_bound_for_cr(gm, x, t)))
            for _ in range(3):
                out.append(("uc2", UC.best_compressor(uc2, x, eps)))
        return out

    serial_ref = []

    def serial_session():
        serial_ref[:] = [serial_round(t) for t in round_targets]

    from benchmarks import common as BC
    serial_s = BC.time_fn(serial_session, warmup=1, iters=REPS)

    def coalesced_round(svc, targets, lat):
        results = [None] * 8

        def one(i, kind, fn):
            t0 = time.perf_counter()
            results[i] = (kind, fn())
            lat.append(time.perf_counter() - t0)

        threads, i = [], 0
        for x, t in zip(hot, targets):
            threads.append(threading.Thread(
                target=one, args=(i, "uc1",
                                  lambda x=x, t=t: svc.find_eb(gm, x, t))))
            i += 1
            for _ in range(3):
                threads.append(threading.Thread(
                    target=one, args=(i, "uc2",
                                      lambda x=x: svc.best_compressor(
                                          uc2, x, eps))))
                i += 1
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        return results

    scfg = ServiceConfig(max_batch_slices=8, max_wait_ms=5.0)
    # warm the coalesced executables once (persistent across services)
    with SweepService(scfg, mesh=mesh) as svc:
        svc.warmup([(N, N)], grid_sizes=(len(ebs),), row_buckets=(2,))
        coalesced_round(svc, round_targets[0], [])
    coal_times, cold_times, lat = [], [], []
    results = cache_stats = cache_extra_launches = None
    for rep in range(REPS):
        with SweepService(scfg, mesh=mesh) as svc:   # cold cache each rep
            lat = []
            t0 = time.perf_counter()
            results = []
            for r, targets in enumerate(round_targets):
                results.append(coalesced_round(svc, targets, lat))
                if r == 0:
                    cold_times.append(time.perf_counter() - t0)
            coal_times.append(time.perf_counter() - t0)
            if rep == REPS - 1:
                # cache gate: one more UC1 on a hot slice -> zero launches
                before = svc.launches
                again = svc.find_eb(gm, hot[0], round_targets[0][0])
                cache_extra_launches = svc.launches - before
                assert again == results[0][0][1]
                cache_stats = svc.stats()["cache"]
                launches_session = svc.launches
    coal_s = float(np.median(coal_times))
    cold_s = float(np.median(cold_times))

    mixed_equal = all(
        (rk == sk) and (rv == sv)
        for rnd, srnd in zip(results, serial_ref)
        for (rk, rv), (sk, sv) in zip(rnd, srnd))
    p50, p95 = _percentiles(lat)
    n_req = 8 * ROUNDS

    # ---- featurize fan-in: 8 x (k=2) requests under the mesh ----------
    stacks = [slices[TRAIN + 2 + 2 * i: TRAIN + 4 + 2 * i] for i in range(8)]
    epss = np.asarray(ebs, np.float32)

    def serial_fanin():
        # today's behavior: one auto-sharded launch per request, each
        # padded from 2 rows to the 8-device extent
        with S.use_mesh(mesh):
            return [np.asarray(P.features_sweep(st, epss)) for st in stacks]

    fan_serial_ref = serial_fanin()                  # warm
    fan_serial_s = BC.time_fn(serial_fanin, warmup=0, iters=1)

    fan_scfg = ServiceConfig(max_batch_slices=16, max_wait_ms=5.0)
    with SweepService(fan_scfg, mesh=mesh) as svc:   # warm executables
        svc.warmup([(N, N)], grid_sizes=(len(ebs),), row_buckets=(16,))

    def coalesced_fanin(svc):
        futs = [svc.submit_featurize(st, epss) for st in stacks]
        return [f.result(timeout=300) for f in futs]

    fan_walls, fan_res, fan_stats = [], None, None
    for _ in range(REPS):
        with SweepService(fan_scfg, mesh=mesh) as svc:  # cold cache
            t0 = time.perf_counter()
            fan_res = coalesced_fanin(svc)
            fan_walls.append(time.perf_counter() - t0)
            fan_stats = svc.stats()
    fan_coal_s = float(np.median(fan_walls))
    fan_equal = all(np.array_equal(a, b)
                    for a, b in zip(fan_res, fan_serial_ref))

    # ---- load sweep: open-loop paced arrivals at 1x/3x/10x ------------
    base_rps = n_req / coal_s
    rnd = np.random.default_rng(0)
    kv_leaves = [np.asarray(rnd.standard_normal((4, 4, 32, 32)), np.float32)
                 for _ in range(4)]
    load = {}
    for mult in LOAD_MULTS:
        rate = base_rps * mult
        with SweepService(scfg, mesh=mesh) as svc:
            svc.warmup([(N, N)], grid_sizes=(len(ebs),), row_buckets=(2,))
            svc.kv_gate(kv_leaves[:1])               # compile the gate jit
            coalesced_round(svc, round_targets[0], [])  # warm feature cache
            futs = []
            t0 = time.perf_counter()
            for i in range(LOAD_REQS):
                target = t0 + i / rate
                now = time.perf_counter()
                if target > now:
                    time.sleep(target - now)
                x, j = hot[i % 2], i % 5
                if j < 2:
                    futs.append(svc.submit_find_eb(
                        gm, x, round_targets[i % ROUNDS][i % 2]))
                elif j < 4:
                    futs.append(svc.submit_best_compressor(uc2, x, eps))
                else:
                    futs.append(svc.submit_kv_gate(
                        [kv_leaves[i % len(kv_leaves)]]))
            for fut in futs:
                fut.result(timeout=300)
            wall = time.perf_counter() - t0
            st = svc.stats()
        load[f"{mult}x"] = {
            "offered_rps": rate,
            "achieved_rps": LOAD_REQS / wall,
            "window_ms": st["window_ms"],
            "window_shrinks": st["window_shrinks"],
            "launches": st["launches"],
            "methods": {name: {k: m[k] for k in
                               ("completed", "rows", "p50_ms", "p95_ms")}
                        for name, m in st["methods"].items()},
        }
    p50_1x = max(m["p50_ms"] for m in load["1x"]["methods"].values())
    p95_10x = max(m["p95_ms"]
                  for m in load[f"{LOAD_MULTS[-1]}x"]["methods"].values())
    load_sweep = {
        "base_rps": base_rps,
        "requests_per_rate": LOAD_REQS,
        "rates": load,
        "p50_1x_ms": p50_1x,
        "p95_10x_ms": p95_10x,
        "p95_10x_limit_ms": max(LOAD_P95_ABS_MS, LOAD_P95_REL * p50_1x),
    }

    with open(out_path, "w") as f:
        json.dump({
            "load_sweep": load_sweep,
            "mixed": {
                "requests": n_req,
                "rounds": ROUNDS,
                "serial_s": serial_s,
                "coalesced_s": coal_s,
                "speedup": serial_s / coal_s,
                "cold_round_s": cold_s,
                "cold_speedup": (serial_s / ROUNDS) / cold_s,
                "throughput_rps": n_req / coal_s,
                "serial_throughput_rps": n_req / serial_s,
                "p50_ms": p50, "p95_ms": p95,
                "launches": launches_session,
                "bitequal": bool(mixed_equal),
                "cache": cache_stats,
                "cache_hit_rate": cache_stats["hits"] / max(
                    cache_stats["hits"] + cache_stats["misses"], 1),
            },
            "fanin": {
                "requests": 8,
                "serial_s": fan_serial_s,
                "coalesced_s": fan_coal_s,
                "speedup": fan_serial_s / fan_coal_s,
                "throughput_rps": 8 / fan_coal_s,
                "bitequal": bool(fan_equal),
                "launches": fan_stats["launches"],
                "rows_launched": fan_stats["rows_launched"],
            },
            "cache_second_uc1_extra_launches": cache_extra_launches,
        }, f, indent=1)


def main() -> dict:
    from benchmarks import common

    if "--child" in sys.argv:
        _child(sys.argv[sys.argv.index("--child") + 1])
        return {}

    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "serve.json")
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src"),
             os.path.dirname(os.path.dirname(__file__)),
             env.get("PYTHONPATH", "")])
        subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_serve", "--child", out],
            env=env, check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        with open(out) as f:
            res = json.load(f)

    mixed, fanin = res["mixed"], res["fanin"]
    nr = mixed["requests"]
    common.emit("serve_mixed_serial", mixed["serial_s"] * 1e6 / nr,
                f"{nr} reqs in {mixed['serial_s'] * 1e3:.1f}ms")
    common.emit("serve_mixed_coalesced", mixed["coalesced_s"] * 1e6 / nr,
                f"speedup={mixed['speedup']:.2f}x "
                f"(cold {mixed['cold_speedup']:.2f}x) "
                f"p50={mixed['p50_ms']:.1f}ms p95={mixed['p95_ms']:.1f}ms "
                f"hit_rate={mixed['cache_hit_rate']:.2f} "
                f"bitequal={mixed['bitequal']}")
    common.emit("serve_fanin_serial", fanin["serial_s"] * 1e6 / 8,
                f"8 reqs in {fanin['serial_s'] * 1e3:.1f}ms")
    common.emit("serve_fanin_coalesced", fanin["coalesced_s"] * 1e6 / 8,
                f"speedup={fanin['speedup']:.2f}x launches="
                f"{fanin['launches']} bitequal={fanin['bitequal']}")
    ls = res["load_sweep"]
    common.emit("serve_load_p95_10x", ls["p95_10x_ms"] * 1e3,
                f"p95@10x={ls['p95_10x_ms']:.1f}ms "
                f"(limit {ls['p95_10x_limit_ms']:.0f}ms, "
                f"p50@1x={ls['p50_1x_ms']:.1f}ms, "
                f"window@10x={ls['rates']['10x']['window_ms']:.3f}ms)")
    common.save_json("BENCH_serve", res)

    assert mixed["bitequal"], "coalesced mixed results != serial dispatch"
    assert fanin["bitequal"], "coalesced featurize results != serial"
    assert res["cache_second_uc1_extra_launches"] == 0, \
        f"second UC1 on a hot field launched sweeps: {res}"
    assert mixed["speedup"] >= MIXED_GATE, \
        f"coalesced mixed speedup {mixed['speedup']:.2f}x < {MIXED_GATE}x"
    assert fanin["speedup"] >= FANIN_GATE, \
        f"coalesced fan-in speedup {fanin['speedup']:.2f}x < {FANIN_GATE}x"
    assert ls["p95_10x_ms"] <= ls["p95_10x_limit_ms"], \
        (f"load sweep: p95 at 10x = {ls['p95_10x_ms']:.1f}ms exceeds "
         f"{ls['p95_10x_limit_ms']:.0f}ms -- adaptive window failed to "
         f"keep the tail bounded")
    print(f"# mixed {mixed['speedup']:.2f}x (gate {MIXED_GATE}x), "
          f"fanin {fanin['speedup']:.2f}x (gate {FANIN_GATE}x), "
          f"cache hit rate {mixed['cache_hit_rate']:.2%}, "
          f"load p95@10x {ls['p95_10x_ms']:.1f}ms "
          f"(limit {ls['p95_10x_limit_ms']:.0f}ms) -- OK")
    return res


if __name__ == "__main__":
    main()
