"""Table 2 / Fig 2: per-(dataset, compressor) CR-prediction accuracy.

MedAPE (with 10/90% quantiles) + correlation from 8-fold CV spline
regression, across four compressor principles and six field stand-ins."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core import pipeline as PL

FIELDS = {  # field -> (count, n, eps_rel)  [paper's Table 2 datasets]
    "miranda-vx": (32, 160, 1e-5),
    "miranda-de": (32, 160, 1e-5),
    "nyx-vx": (32, 160, 1e-2),
    "scale-u": (32, 160, 1e-3),
    "cesm-cloud": (32, 160, 1e-5),
    "hurricane-u": (32, 160, 1e-2),
}
COMPRESSORS = ["sz2", "zfp", "mgard", "digitrounding"]


def main() -> dict:
    table = {}
    for field, (count, n, eps_rel) in FIELDS.items():
        slices = common.field_slices_cached(field, count, n)
        rng = float(jnp.max(slices) - jnp.min(slices))
        eps = eps_rel * rng
        import time
        t0 = time.perf_counter()
        feats = np.asarray(PL.featurize_slices(slices, eps))
        t_feat = (time.perf_counter() - t0) / count * 1e6
        for comp in COMPRESSORS:
            crs = common.crs_for(comp, field, count, n, eps)
            res = PL.kfold_evaluate(feats, crs, model="spline", k=8)
            key = f"{field}|{comp}"
            table[key] = {
                "medape": res.medape, "q10": res.medape_q10,
                "q90": res.medape_q90, "corr": res.correlation,
                "cr_min": float(crs.min()), "cr_max": float(crs.max()),
            }
            common.emit(
                f"table2/{field}/{comp}", t_feat,
                f"medape_pct={res.medape:.2f} corr={res.correlation:.3f} "
                f"cr_range=[{crs.min():.1f};{crs.max():.1f}]")
    common.save_json("table2_prediction", table)
    meds = [v["medape"] for v in table.values()]
    common.emit("table2/overall", 0.0,
                f"median_medape_pct={np.median(meds):.2f} "
                f"max_medape_pct={np.max(meds):.2f} "
                f"claim=paper<12pct pass={np.median(meds) < 12.0}")
    return table


if __name__ == "__main__":
    main()
