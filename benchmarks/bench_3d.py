"""Table 4 / Fig 9 / section 4.5: 3-D CR prediction with HOSVD predictors,
including TTHRESH (the hardest case in the paper)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro import compressors as C
from repro.core import pipeline as PL, predictors as P
from repro.data import scientific

COMPRESSORS = ["sz2", "zfp", "mgard", "bitgrooming", "tthresh"]


def main() -> dict:
    vols = jnp.stack([scientific.volume("qmcpack", shape=(24, 64, 64), seed=s)
                      for s in range(16)])
    rng = float(jnp.max(vols) - jnp.min(vols))
    eps = 1e-2 * rng
    feats = np.asarray(jnp.stack([P.features_3d(v, eps) for v in vols]))
    out = {}
    for comp in COMPRESSORS:
        c = C.get(comp)
        crs = np.asarray([c.cr(v, eps) for v in vols])
        res = PL.kfold_evaluate(feats, crs, model="spline", k=8)
        out[comp] = {"medape": res.medape, "q10": res.medape_q10,
                     "q90": res.medape_q90, "mean_cr": float(np.mean(crs))}
        common.emit(f"table4/qmcpack3d/{comp}", 0.0,
                    f"medape_pct={res.medape:.2f} "
                    f"[{res.medape_q10:.1f},{res.medape_q90:.1f}] "
                    f"mean_cr={np.mean(crs):.1f}")
    # paper claims: SZ2/ZFP/MGARD competitive; TTHRESH worst but << prior work
    non_t = max(v["medape"] for k, v in out.items() if k != "tthresh")
    common.emit("table4/overall", 0.0,
                f"non_tthresh_max_medape={non_t:.2f} "
                f"tthresh_medape={out['tthresh']['medape']:.2f} "
                f"pass={non_t < 15.0}")
    common.save_json("table4_3d", out)
    return out


if __name__ == "__main__":
    main()
