"""Batched + sharded 3-D/HOSVD featurization sweeps (gates) + the paper's
Table 4 / Fig 9 / section 4.5 study (3-D CR prediction incl. TTHRESH).

Gates (acceptance):
  * batched (k, d, m, n) sweep >= 3x vs the looped per-(volume, eb)
    ``features_3d`` baseline, outputs matching to f32 tolerance;
  * 8-virtual-device sharded volume sweep == single-device engine to f32
    tolerance (divisible and non-divisible k) -- each device count runs in
    a child interpreter because XLA_FLAGS is locked at jax init;
  * writes machine-readable ``results/BENCH_3d.json``.

The MedAPE study (SZ2/ZFP/MGARD/bitgrooming/TTHRESH over volumes) now
featurizes through the batched engine: ONE rank-4 sweep instead of the
old per-volume Python loop.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

import numpy as np

K, SHAPE = 12, (16, 64, 64)
K_RAGGED = 11          # non-divisible volume count: exercises pad + drop
EB_RELS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 1e-1)
DEVICE_COUNTS = (1, 8)
SPEEDUP_GATE = 3.0


def _volumes():
    import jax.numpy as jnp
    from repro.data import scientific
    return jnp.stack([scientific.volume("qmcpack", shape=SHAPE, seed=s)
                      for s in range(K)])


def _child(num_devices: int, out_prefix: str) -> None:
    import jax
    import jax.numpy as jnp
    from benchmarks import common
    from repro.core import predictors as P
    from repro.dist import sharding as S
    from repro.launch import mesh as M

    assert len(jax.devices()) == num_devices, jax.devices()
    vols = _volumes()
    rng = float(jnp.max(vols) - jnp.min(vols))
    epss = jnp.asarray([r * rng for r in EB_RELS], jnp.float32)

    def run(stack):
        if num_devices == 1:
            return P.features_sweep(stack, epss, sharded=False)
        with S.use_mesh(M.make_sweep_mesh()):
            return P.features_sweep(stack, epss)

    t_full = common.timeit(lambda: run(vols), warmup=1, iters=5)
    out_full = np.asarray(run(vols))
    t_ragged = common.timeit(lambda: run(vols[:K_RAGGED]), warmup=1, iters=5)
    out_ragged = np.asarray(run(vols[:K_RAGGED]))

    np.save(out_prefix + ".full.npy", out_full)
    np.save(out_prefix + ".ragged.npy", out_ragged)
    with open(out_prefix + ".json", "w") as f:
        json.dump({"devices": num_devices, "full_us": t_full,
                   "ragged_us": t_ragged}, f)


def _batched_vs_looped(out: dict) -> None:
    """Gate 1: the rank-4 sweep vs the looped per-(volume, eb) baseline."""
    import jax
    import jax.numpy as jnp
    from benchmarks import common
    from repro.core import predictors as P

    vols = _volumes()
    rng = float(jnp.max(vols) - jnp.min(vols))
    epss = jnp.asarray([r * rng for r in EB_RELS], jnp.float32)
    e = len(EB_RELS)

    # looped baseline: one jitted featurization call per (volume, eb) --
    # the old pipeline/bench path (HOSVD recomputed at every eb)
    feat3 = jax.jit(lambda v, eb: P.features_3d(v, eb))

    def looped():
        return jnp.stack([jnp.stack([feat3(vols[i], epss[j])
                                     for j in range(e)]) for i in range(K)])

    def sweep():
        return P.features_sweep(vols, epss)

    t_loop = common.timeit(looped, warmup=1, iters=5)
    t_sweep = common.timeit(sweep, warmup=1, iters=5)
    diff = float(jnp.max(jnp.abs(looped() - sweep())))
    speedup = t_loop / max(t_sweep, 1e-9)
    common.emit("sweep3d/featurize", t_sweep,
                f"k={K} shape={SHAPE} e={e} looped_us={t_loop:.0f} "
                f"sweep_us={t_sweep:.0f} speedup={speedup:.1f}x "
                f"maxdiff={diff:.2e}")
    out["batched"] = {"k": K, "shape": SHAPE, "e": e, "looped_us": t_loop,
                      "sweep_us": t_sweep, "speedup": speedup,
                      "max_abs_diff": diff}
    assert diff < 1e-4, f"3-D sweep diverged from looped baseline: {diff}"
    assert speedup >= SPEEDUP_GATE, \
        f"3-D sweep speedup {speedup:.2f}x below {SPEEDUP_GATE}x gate"


def _sharded_equivalence(out: dict) -> None:
    """Gate 2: 1-vs-8-virtual-device sharded volume sweeps (children)."""
    from benchmarks import common

    results = {}
    with tempfile.TemporaryDirectory() as tmp:
        for d in DEVICE_COUNTS:
            prefix = os.path.join(tmp, f"dev{d}")
            common.run_child_module(
                "benchmarks.bench_3d", ["--child", d, prefix], d)
            with open(prefix + ".json") as f:
                results[d] = json.load(f)
            results[d]["full"] = np.load(prefix + ".full.npy")
            results[d]["ragged"] = np.load(prefix + ".ragged.npy")

    base = results[DEVICE_COUNTS[0]]
    for d in DEVICE_COUNTS[1:]:
        diff_full = float(np.abs(results[d]["full"] - base["full"]).max())
        diff_ragged = float(
            np.abs(results[d]["ragged"] - base["ragged"]).max())
        common.emit(
            f"sweep3d_sharded/{d}dev", results[d]["full_us"],
            f"k={K} e={len(EB_RELS)} single_us={base['full_us']:.0f} "
            f"sharded_us={results[d]['full_us']:.0f} "
            f"ragged_single_us={base['ragged_us']:.0f} "
            f"ragged_sharded_us={results[d]['ragged_us']:.0f} "
            f"maxdiff={diff_full:.2e} maxdiff_ragged={diff_ragged:.2e}")
        out[f"dev{d}"] = {
            "single_us": base["full_us"],
            "sharded_us": results[d]["full_us"],
            "ragged_single_us": base["ragged_us"],
            "ragged_sharded_us": results[d]["ragged_us"],
            "max_abs_diff": diff_full,
            "max_abs_diff_ragged": diff_ragged,
        }
        assert diff_full < 1e-5, \
            f"sharded 3-D sweep diverged: {diff_full}"
        assert diff_ragged < 1e-5, \
            f"sharded ragged 3-D sweep diverged: {diff_ragged}"


def _table4_study(out: dict) -> None:
    """Paper section 4.5: MedAPE per 3-D compressor (featurized by ONE
    batched rank-4 sweep)."""
    import jax.numpy as jnp
    from benchmarks import common
    from repro import compressors as C
    from repro.core import pipeline as PL

    vols = _volumes()
    rng = float(jnp.max(vols) - jnp.min(vols))
    eps = 1e-2 * rng
    feats = np.asarray(PL.featurize_slices(vols, eps))
    study = {}
    for comp in C.STUDY_3D:
        c = C.get(comp)
        crs = np.asarray([c.cr(v, eps) for v in vols])
        res = PL.kfold_evaluate(feats, crs, model="spline", k=8)
        study[comp] = {"medape": res.medape, "q10": res.medape_q10,
                       "q90": res.medape_q90, "mean_cr": float(np.mean(crs))}
        common.emit(f"table4/qmcpack3d/{comp}", 0.0,
                    f"medape_pct={res.medape:.2f} "
                    f"[{res.medape_q10:.1f},{res.medape_q90:.1f}] "
                    f"mean_cr={np.mean(crs):.1f}")
    # paper claims: SZ2/ZFP/MGARD competitive; TTHRESH worst but << prior
    non_t = max(v["medape"] for k, v in study.items() if k != "tthresh")
    common.emit("table4/overall", 0.0,
                f"non_tthresh_max_medape={non_t:.2f} "
                f"tthresh_medape={study['tthresh']['medape']:.2f} "
                f"pass={non_t < 15.0}")
    out["table4"] = study


def main() -> dict:
    from benchmarks import common

    out: dict = {}
    _batched_vs_looped(out)
    _sharded_equivalence(out)
    _table4_study(out)
    common.save_json("BENCH_3d", out)
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(int(sys.argv[2]), sys.argv[3])
    else:
        res = main()
        print(f"PASS: batched {res['batched']['speedup']:.2f}x >= "
              f"{SPEEDUP_GATE}x, sharded maxdiff "
              f"{res['dev8']['max_abs_diff']:.2e}")
