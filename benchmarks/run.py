"""Benchmark driver: one module per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

  python -m benchmarks.run            # everything
  python -m benchmarks.run fig5 uc    # substring filter
"""
from __future__ import annotations

import sys
import time
import traceback

SUITES = [
    ("table2_fig2_prediction", "benchmarks.bench_prediction"),
    ("fig4_predictors", "benchmarks.bench_predictors"),
    ("fig5_gaussian", "benchmarks.bench_gaussian"),
    ("fig6_sz_schemes", "benchmarks.bench_sz_schemes"),
    ("table3_fig8_lasso", "benchmarks.bench_lasso"),
    ("table4_fig9_3d", "benchmarks.bench_3d"),
    ("table5_prior", "benchmarks.bench_prior"),
    ("fig10_usecases", "benchmarks.bench_usecases"),
    ("serve_methods_coalescing", "benchmarks.bench_serve"),
    ("stream_advisor", "benchmarks.bench_stream"),
    ("quality_frontier", "benchmarks.bench_quality"),
    ("multihost_fabric", "benchmarks.bench_multihost"),
    ("fault_recovery", "benchmarks.bench_fault"),
    ("kernels", "benchmarks.bench_kernels"),
    ("tune", "benchmarks.bench_tune"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    failures = []
    for name, module in SUITES:
        if filters and not any(f in name for f in filters):
            continue
        print(f"# ==== {name} ({module}) ====", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# ---- {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
