"""Table 5: accuracy of our method vs prior estimation approaches on SZ2
(block sampling, Lu-et-al-style white box, OptZConfig-style warm-start
surrogate)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core import baselines as B
from repro.core import pipeline as PL

CASES = {"miranda-vx": 1e-5, "cesm-cloud": 1e-5}


def main() -> dict:
    out = {}
    for field, eps_rel in CASES.items():
        count, n = 24, 160
        slices = common.field_slices_cached(field, count, n)
        rng = float(jnp.max(slices) - jnp.min(slices))
        eps = eps_rel * rng
        true = common.crs_for("sz2", field, count, n, eps)

        # ours: spline CV
        feats = np.asarray(PL.featurize_slices(slices, eps))
        res = PL.kfold_evaluate(feats, true, model="spline", k=8)
        methods = {"ours": res.medape}

        # block sampling
        ape = [100 * abs(B.block_sampling(slices[i], eps) - true[i]) / true[i]
               for i in range(0, count, 3)]
        methods["block_sampling"] = float(np.median(ape))

        # Lu-style white box
        ape = [100 * abs(B.lu_model(slices[i], eps) - true[i]) / true[i]
               for i in range(0, count, 3)]
        methods["lu_model"] = float(np.median(ape))

        # OptZConfig warm-start surrogate: the surrogate is built from
        # *previously seen* data of the field -- a distant slice, as the
        # warm start predates the query (adjacent slices would leak the
        # smooth synthetic structure); costs 2 compressor runs per query
        ape = [100 * abs(B.optzconfig_probe(
                   slices[(i + count // 2) % count], eps) - true[i])
               / true[i] for i in range(1, count, 3)]
        methods["optzconfig"] = float(np.median(ape))

        out[field] = methods
        common.emit(
            f"table5/{field}", 0.0,
            " ".join(f"{k}_medape={v:.1f}" for k, v in methods.items()))
    ok = all(m["ours"] < min(m["block_sampling"], m["lu_model"],
                             m["optzconfig"]) for m in out.values())
    common.emit("table5/overall", 0.0,
                f"ours_beats_all_priors pass={ok}")
    common.save_json("table5_prior", out)
    return out


if __name__ == "__main__":
    main()
