"""Fig 6 / section 4.2: CR prediction across SZ compressor-prediction
schemes -- SZ2 (dynamic Lorenzo/regression) vs SZ3 exclusive Lorenzo /
regression / interpolation -- plus the regression-block-fraction statistic."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro import compressors as C
from repro.core import pipeline as PL

SCHEMES = ["sz2", "sz3-lorenzo", "sz3-regression", "sz3-interp"]
CASES = {  # field -> eps_rel (Fig 6 panels)
    "miranda-vx": 1e-5,
    "cesm-cloud": 1e-5,
    "scale-pressure": 1e-3,
}


def main() -> dict:
    out = {}
    for field, eps_rel in CASES.items():
        slices = common.field_slices_cached(field, 28, 160)
        rng = float(jnp.max(slices) - jnp.min(slices))
        eps = eps_rel * rng
        feats = np.asarray(PL.featurize_slices(slices, eps))
        for scheme in SCHEMES:
            crs = common.crs_for(scheme, field, 28, 160, eps)
            res = PL.kfold_evaluate(feats, crs, model="spline", k=8)
            out[f"{field}|{scheme}"] = {"medape": res.medape,
                                        "corr": res.correlation,
                                        "mean_cr": float(np.mean(crs))}
            common.emit(f"fig6/{field}/{scheme}", 0.0,
                        f"medape_pct={res.medape:.2f} mean_cr={np.mean(crs):.2f}")
        # section 4.2's regression-use statistic for SZ2 dynamic selection
        sz2 = C.get("sz2")
        fr = [sz2.regression_fraction(s, eps) for s in slices[:8]]
        out[f"{field}|sz2_regression_fraction"] = float(np.median(fr))
        common.emit(f"fig6/{field}/sz2_regression_fraction", 0.0,
                    f"median_fraction={np.median(fr):.3f}")
    # robustness claim: SZ2 dynamic predicted as well as exclusive schemes
    diffs = []
    for field in CASES:
        base = out[f"{field}|sz2"]["medape"]
        for scheme in SCHEMES[1:]:
            diffs.append(abs(out[f"{field}|{scheme}"]["medape"] - base))
    common.emit("fig6/overall", 0.0,
                f"max_scheme_medape_gap_pct={max(diffs):.2f} "
                f"claim=paper<5pct_gap pass={max(diffs) < 8.0}")
    common.save_json("fig6_sz_schemes", out)
    return out


if __name__ == "__main__":
    main()
