"""Multi-process sweep fabric gate: 1-process-8-device vs
2-process-4-device equivalence (ISSUE 5 acceptance).

A single-process child with 8 virtual CPU devices runs the sharded sweep
over 2-D slice stacks and rank-4 volume stacks (divisible and ragged k);
two ``jax.distributed`` children with 4 virtual devices each (joined on
a free localhost port, gloo collectives) run the SAME sweeps through the
multi-process path -- identical-global-stack ingestion AND process-local
ingestion -- and process 0 saves its gathered tensors.  The parent
asserts every multi-process tensor is BIT-EXACT against the
single-process one (the per-device shard body is identical, only the
fabric changed) and records the timings side by side.

Virtual CPU devices share the same cores, so multi-process wall-clock
speedup is not the acceptance signal here (that comes on real multi-node
hardware); the gate is exactness across the process boundary plus a
record of the fabric overhead.  Writes ``results/BENCH_multihost.json``.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

K2D, K2D_RAGGED, N = 16, 11, 96
KV, KV_RAGGED = 8, 3
VOL_SHAPE = (8, 32, 32)
EB_RELS = (1e-4, 1e-3, 1e-2)
DEVICES_TOTAL = 8
NPROCS = 2

CASES = ("2d_full", "2d_ragged", "vol_full", "vol_ragged")


def _stacks():
    import jax.numpy as jnp
    from repro.data import scientific

    slices = scientific.field_slices("miranda-vx", count=K2D, n=N)
    vols = scientific.volume("miranda-vx", shape=(KV,) + VOL_SHAPE)
    rng = float(jnp.max(slices) - jnp.min(slices))
    epss = np.asarray([r * rng for r in EB_RELS], np.float32)
    return {
        "2d_full": slices,
        "2d_ragged": slices[:K2D_RAGGED],
        "vol_full": vols,
        "vol_ragged": vols[:KV_RAGGED],
    }, epss


def _child_single(out_prefix: str) -> None:
    import jax
    from repro.dist import sweep as DS
    from repro.launch import mesh as M

    assert len(jax.devices()) == DEVICES_TOTAL, jax.devices()
    mesh = M.make_sweep_mesh()
    stacks, epss = _stacks()
    times = {}
    for name, stack in stacks.items():
        t0 = time.perf_counter()
        out = np.asarray(DS.features_sweep_sharded(stack, epss, mesh=mesh))
        times[name] = time.perf_counter() - t0
        np.save(f"{out_prefix}.{name}.npy", out)
    with open(out_prefix + ".json", "w") as f:
        json.dump({"devices": DEVICES_TOTAL, "processes": 1,
                   "times_s": times}, f)


def _child_multi(pid: int, port: int, out_prefix: str) -> None:
    from repro.launch import mesh as M
    M.dist_init(f"127.0.0.1:{port}", num_processes=NPROCS, process_id=pid)

    import jax
    from repro.dist import sweep as DS

    assert len(jax.devices()) == DEVICES_TOTAL
    assert jax.local_device_count() == DEVICES_TOTAL // NPROCS
    mesh = M.make_sweep_mesh()
    stacks, epss = _stacks()
    times, times_local = {}, {}
    outs = {}
    for name, stack in stacks.items():
        t0 = time.perf_counter()
        outs[name] = np.asarray(
            DS.features_sweep_sharded(stack, epss, mesh=mesh))
        times[name] = time.perf_counter() - t0
        # process-local ingestion: each process feeds only its block
        host = np.asarray(stack)
        lo, hi = DS.process_block(len(host), mesh)
        t0 = time.perf_counter()
        local = np.asarray(DS.features_sweep_sharded(
            host[lo:hi], epss, mesh=mesh, process_local=True,
            global_k=len(host)))
        times_local[name] = time.perf_counter() - t0
        assert np.array_equal(local, outs[name]), \
            f"{name}: process-local ingestion diverged"
    if pid == 0:
        for name, out in outs.items():
            np.save(f"{out_prefix}.{name}.npy", out)
        with open(out_prefix + ".json", "w") as f:
            json.dump({"devices": DEVICES_TOTAL, "processes": NPROCS,
                       "times_s": times, "times_local_s": times_local}, f)


def main() -> dict:
    from benchmarks import common

    with tempfile.TemporaryDirectory() as tmp:
        single = os.path.join(tmp, "p1")
        multi = os.path.join(tmp, "p2")
        common.run_child_module(
            "benchmarks.bench_multihost", ["--child-single", single],
            DEVICES_TOTAL)
        port = common.free_port()
        common.wait_children([
            common.spawn_child_module(
                "benchmarks.bench_multihost",
                ["--child-multi", pid, port, multi],
                DEVICES_TOTAL // NPROCS)
            for pid in range(NPROCS)])

        with open(single + ".json") as f:
            meta1 = json.load(f)
        with open(multi + ".json") as f:
            meta2 = json.load(f)
        out = {"devices": DEVICES_TOTAL, "processes": NPROCS,
               "eb_count": len(EB_RELS), "cases": {}}
        for name in CASES:
            a = np.load(f"{single}.{name}.npy")
            b = np.load(f"{multi}.{name}.npy")
            diff = float(np.abs(a - b).max())
            bitexact = bool(np.array_equal(a, b))
            out["cases"][name] = {
                "k": int(a.shape[0]),
                "single_process_s": meta1["times_s"][name],
                "two_process_s": meta2["times_s"][name],
                "two_process_local_ingest_s": meta2["times_local_s"][name],
                "max_abs_diff": diff,
                "bitexact": bitexact,
            }
            common.emit(
                f"multihost/{name}", meta2["times_s"][name] * 1e6,
                f"k={a.shape[0]} 1proc_s={meta1['times_s'][name]:.2f} "
                f"2proc_s={meta2['times_s'][name]:.2f} "
                f"bitexact={bitexact}")
            # acceptance: crossing the process boundary changes NOTHING
            assert bitexact, \
                f"{name}: 2-process sweep diverged (maxdiff {diff})"
    common.save_json("BENCH_multihost", out)
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child-single":
        _child_single(sys.argv[2])
    elif len(sys.argv) > 1 and sys.argv[1] == "--child-multi":
        _child_multi(int(sys.argv[2]), int(sys.argv[3]), sys.argv[4])
    else:
        res = main()
        print("PASS: 2-process sweep fabric bit-exact vs single process;",
              json.dumps(res["cases"], indent=1))
