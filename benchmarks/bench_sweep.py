"""Sweep-engine benchmark (the tentpole perf claim): featurizing
k slices x e error bounds through the batched fused engine vs the looped
per-(slice, eb) baseline on the same backend.

The looped baseline calls the vmapped single-eb featurizer once per error
bound: e batched SVDs and e full passes over the data.  The sweep engine
computes the eb-independent SVD once (one batched Gram + one batched
eigvalsh) and histograms every error bound from a single read of each
slice.  Acceptance: >= 3x at k=28, e >= 4, outputs matching to f32
tolerance.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import predictors as P

K, N = 28, 160
# relative error bounds inside the injective-binning regime (code range
# < 2^16), where the looped baseline's hashed histogram is itself exact
EB_RELS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 1e-1)


def main() -> dict:
    slices = common.field_slices_cached("miranda-vx", K, N)
    rng = float(jnp.max(slices) - jnp.min(slices))
    epss = jnp.asarray([r * rng for r in EB_RELS], jnp.float32)
    e = len(EB_RELS)

    # looped baseline: one jitted per-eb featurization call per error
    # bound (eps traced -> a single compile serves the whole loop)
    feat_batch = jax.jit(lambda s, eb: P.features_batch(s, eb))

    def looped():
        return jnp.stack([feat_batch(slices, epss[i]) for i in range(e)],
                         axis=1)

    def sweep():
        return P.features_sweep(slices, epss)

    t_loop = common.timeit(looped, warmup=1, iters=5)
    t_sweep = common.timeit(sweep, warmup=1, iters=5)
    diff = float(jnp.max(jnp.abs(looped() - sweep())))
    speedup = t_loop / max(t_sweep, 1e-9)
    common.emit("sweep/featurize", t_sweep,
                f"k={K} e={e} looped_us={t_loop:.0f} sweep_us={t_sweep:.0f} "
                f"speedup={speedup:.1f}x maxdiff={diff:.2e}")

    # stage split: where the win comes from
    t_svd_loop = common.timeit(
        lambda: jax.vmap(P.svd_trunc)(slices), warmup=1, iters=5)
    t_svd_batch = common.timeit(
        lambda: P.svd_trunc_batch(slices), warmup=1, iters=5)
    t_qent_sweep = common.timeit(
        lambda: P.quantized_entropy_sweep(slices, epss), warmup=1, iters=5)
    common.emit("sweep/stages", t_svd_batch,
                f"svd_vmap_us={t_svd_loop:.0f} svd_batch_us={t_svd_batch:.0f} "
                f"qent_sweep_us={t_qent_sweep:.0f}")

    out = {"k": K, "e": e, "looped_us": t_loop, "sweep_us": t_sweep,
           "speedup": speedup, "max_abs_diff": diff,
           "svd_vmap_us": t_svd_loop, "svd_batch_us": t_svd_batch,
           "qent_sweep_us": t_qent_sweep}
    common.save_json("bench_sweep", out)
    assert diff < 1e-4, f"sweep output diverged from looped baseline: {diff}"
    return out


if __name__ == "__main__":
    res = main()
    print(f"speedup {res['speedup']:.2f}x "
          f"({'PASS' if res['speedup'] >= 3.0 else 'FAIL'} vs 3x acceptance)")
