"""Quality-frontier benchmark: the fused quality sweep + the UC3 joint
search (ratio-quality frontier, docs/quality.md).

Three gates:

1. PERF -- the fused one-pass quality sweep (every (slice, eb) SSE from
   one read of the data) must be >= 3x over the looped per-(slice, eb)
   baseline (one jitted single-pair PSNR/NRMSE call per cell).
2. BIT-EQUALITY -- the quality tensor must be bitwise identical across
   the single-device route, the sharded multi-device launch (when > 1
   device is up), and the served ``quality`` method.
3. UC3 GRID-COMPLETENESS (Table-4-style study) -- across a sweep of
   (cr_floor, psnr_floor) pairs, ``usecases.find_setting`` returns a
   feasible setting on EVERY grid where a brute-force scan of the
   monotonized per-compressor frontiers finds a jointly feasible point,
   and a typed infeasible result everywhere else.

Writes ``results/BENCH_quality.json``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import predictors as P
from repro.core import usecases as UC
from repro.kernels.quality import quality_sweep

K, N = 28, 160
EB_RELS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 1e-1)


def _bits(a):
    return np.asarray(a, np.float32).view(np.uint32)


def main() -> dict:
    slices = common.field_slices_cached("miranda-vx", K, N)
    rng = float(jnp.max(slices) - jnp.min(slices))
    epss = np.asarray([r * rng for r in EB_RELS], np.float32)
    e = len(EB_RELS)

    # looped baseline: one jitted single-(slice, eb) quality call per
    # cell (eps traced, slice batched away -> ONE compile serves all
    # k * e calls; the fused sweep's win is the single data read)
    pair = jax.jit(lambda s, eb: quality_sweep(s[None], eb[None])[0, 0])

    def looped():
        out = np.empty((K, e, 2), np.float32)
        for si in range(K):
            for ei in range(e):
                out[si, ei] = np.asarray(
                    pair(slices[si], jnp.float32(epss[ei])))
        return out

    def fused():
        return np.asarray(P.quality_sweep(slices, epss))

    t_loop = common.timeit(looped, warmup=1, iters=3)
    t_fused = common.timeit(fused, warmup=1, iters=5)
    base, one = looped(), fused()
    speedup = t_loop / max(t_fused, 1e-9)
    # the looped baseline runs the same jitted pipeline on (1, 1)
    # shapes, so it is bit-equal too (batch-shape invariance)
    bit_equal_loop = bool(np.array_equal(_bits(base), _bits(one)))
    common.emit("quality/fused_vs_looped", t_fused,
                f"k={K} e={e} looped_us={t_loop:.0f} fused_us={t_fused:.0f} "
                f"speedup={speedup:.1f}x bit_equal={bit_equal_loop}")

    # route bit-equality: sharded (when available) + served
    n_dev = len(jax.devices())
    bit_equal_sharded = None
    if n_dev > 1:
        from repro.launch import mesh as M
        sharded = np.asarray(P.quality_sweep(
            slices, epss, mesh=M.make_sweep_mesh(n_dev)))
        bit_equal_sharded = bool(np.array_equal(_bits(sharded), _bits(one)))
        common.emit("quality/sharded", 0.0,
                    f"devices={n_dev} bit_equal={bit_equal_sharded}")
    from repro.serve.sweep_service import ServiceConfig, SweepService
    with SweepService(ServiceConfig(max_wait_ms=20.0)) as svc:
        served = svc.quality(np.asarray(slices), epss)
    bit_equal_served = bool(np.array_equal(_bits(served), _bits(one)))
    common.emit("quality/served", 0.0, f"bit_equal={bit_equal_served}")

    # UC3 study: grid-completeness across a floor sweep
    ebs = [float(x) for x in epss[1:-1]]
    models = {name: UC.EbGridModel.train(slices[:8], name, ebs)
              for name in ("zfp", "sz2", "sz3-interp")}
    x = np.asarray(slices[10])
    frontiers = {}
    for name, gm in models.items():
        pg = np.minimum.accumulate(
            [gm.predict_psnr(x, float(b)) for b in gm.ebs])
        cg = np.maximum.accumulate(
            [gm.predict(x, float(b)) for b in gm.ebs])
        frontiers[name] = (pg, cg)
    crs = sorted({float(c) for _, cg in frontiers.values() for c in cg})
    psnrs = sorted({float(p) for pg, _ in frontiers.values() for p in pg})
    cases = checked = feasible_hits = 0
    study = []
    for cr_floor in [0.5 * crs[0]] + crs + [2.0 * crs[-1]]:
        for psnr_floor in [psnrs[0] - 10.0] + psnrs + [psnrs[-1] + 10.0]:
            brute = any(
                p >= psnr_floor and c >= cr_floor
                for pg, cg in frontiers.values() for p, c in zip(pg, cg))
            res = UC.find_setting(models, x, cr_floor=cr_floor,
                                  psnr_floor=psnr_floor)
            cases += 1
            ok = res.feasible if brute else (not res.feasible
                                            and bool(res.reason))
            checked += bool(ok)
            feasible_hits += bool(res.feasible)
            if res.feasible:
                ok = ok and res.predicted_cr >= cr_floor \
                    and res.predicted_psnr >= psnr_floor - 1e-6
                checked -= not ok
            study.append({"cr_floor": float(cr_floor),
                          "psnr_floor": float(psnr_floor),
                          "brute_feasible": bool(brute),
                          "feasible": bool(res.feasible),
                          "compressor": res.compressor, "ok": bool(ok)})
    grid_complete = checked == cases
    common.emit("quality/uc3_study", 0.0,
                f"cases={cases} feasible={feasible_hits} "
                f"grid_complete={grid_complete}")

    out = {"k": K, "e": e, "looped_us": t_loop, "fused_us": t_fused,
           "speedup": speedup, "bit_equal_looped": bit_equal_loop,
           "bit_equal_sharded": bit_equal_sharded,
           "bit_equal_served": bit_equal_served, "devices": n_dev,
           "uc3_cases": cases, "uc3_feasible": feasible_hits,
           "uc3_grid_complete": grid_complete, "uc3_study": study}
    common.save_json("BENCH_quality", out)
    assert bit_equal_loop, "fused quality diverged from looped baseline"
    assert bit_equal_served, "served quality diverged from direct sweep"
    assert bit_equal_sharded in (None, True), "sharded quality diverged"
    assert grid_complete, "UC3 missed a jointly feasible grid"
    assert speedup >= 3.0, \
        f"fused quality sweep only {speedup:.2f}x vs looped (need >= 3x)"
    return out


if __name__ == "__main__":
    res = main()
    print(f"speedup {res['speedup']:.2f}x "
          f"({'PASS' if res['speedup'] >= 3.0 else 'FAIL'} vs 3x), "
          f"uc3 {res['uc3_cases']} cases grid_complete="
          f"{res['uc3_grid_complete']}")
