"""Streaming sweep gates: advisor at dataset scale, chunked bit-equality,
double-buffering overlap (ISSUE 9 acceptance).

Three gates, all in-process:

1. **Advisor at scale** -- ``tools/make_dataset.py``-equivalent synthetic
   multi-field dataset whose f32 payload is >= 2x a defined virtual
   device budget; ``launch.advise.advise_dataset`` must complete within
   that chunk budget (no chunk exceeds it) and cover every variable and
   CR target.
2. **Bit-equality** -- streamed features == in-memory ``features_sweep``
   on a small dataset, bit for bit, across budgets that don't divide k
   (and through a device mesh when more than one device is visible).
3. **Overlap** -- against a throttled source calibrated so one chunk's
   read time matches one chunk's measured compute time (modeling
   archival-storage bandwidth), the double-buffered stream
   (``prefetch=2``) must beat the strictly synchronous loop
   (``prefetch=0``) by >= 1.3x; the pipeline bound is ~2x.

Writes ``results/BENCH_stream.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

# one virtual device's memory budget for this gate (f32 chunk bytes);
# the advisor dataset must NOT fit in two of these
DEVICE_BUDGET = 1 << 21
EB_RELS = (1e-4, 1e-3, 1e-2)
MIN_OVERLAP_SPEEDUP = 1.3


class ThrottledSource:
    """Delay every ``read_rows`` by a fixed time: a dataset living on
    storage whose bandwidth roughly matches featurization throughput."""

    def __init__(self, inner, delay_s: float):
        self.inner, self.delay_s = inner, delay_s

    def variables(self):
        return self.inner.variables()

    def meta(self, name):
        return self.inner.meta(name)

    def read_rows(self, name, lo, hi):
        time.sleep(self.delay_s)
        return self.inner.read_rows(name, lo, hi)


def _gate_advisor(tmp: str, out: dict) -> None:
    from benchmarks import common
    from repro.data import source as SRC
    from repro.launch import advise as ADV
    from repro.core import stream as ST

    gen = SRC.GeneratorSource([
        SRC.FieldVariable("miranda-vx", 36, (128,)),
        SRC.FieldVariable("cesm-cloud", 28, (128,)),
        SRC.FieldVariable("qmcpack", 3, (8, 48, 48)),
    ])
    path = SRC.write_dataset(os.path.join(tmp, "ds"), gen, fmt="memmap",
                             dtype="float64", budget_bytes=DEVICE_BUDGET)
    ds = SRC.open_dataset(path)
    total = sum(ds.meta(n).nbytes_f32 for n in ds.variables())
    assert total >= 2 * DEVICE_BUDGET, \
        f"gate dataset too small: {total} < 2x{DEVICE_BUDGET}"
    for n in ds.variables():
        meta = ds.meta(n)
        chunk = SRC.rows_per_chunk(meta, DEVICE_BUDGET)
        assert chunk * meta.row_nbytes_f32 <= DEVICE_BUDGET or chunk == 1

    t0 = time.perf_counter()
    stream = ST.StreamConfig(budget_bytes=DEVICE_BUDGET)
    report = ADV.advise_dataset(
        ds, fields=["miranda-vx", "cesm-cloud"],
        compressors=("sz3-interp", "zfp"), train_rows=4, stream=stream)
    report["variables"].update(ADV.advise_dataset(
        ds, fields=["qmcpack-vol"], compressors=("zfp", "bitgrooming"),
        train_rows=2, stream=stream)["variables"])
    dt = time.perf_counter() - t0
    assert set(report["variables"]) == set(ds.variables())
    for name, var in report["variables"].items():
        assert "targets" in var, f"{name} skipped: {var}"
        for rec in var["targets"].values():
            assert rec["eb"] > 0 and rec["predicted_cr"] > 0
    out["advisor"] = {
        "dataset_f32_bytes": int(total),
        "device_budget_bytes": DEVICE_BUDGET,
        "oversubscription": total / DEVICE_BUDGET,
        "variables": {n: ds.meta(n).shape for n in ds.variables()},
        "wall_s": dt,
    }
    common.emit("stream/advisor", dt * 1e6,
                f"vars={len(ds.variables())} "
                f"bytes={total / 2**20:.1f}MiB "
                f"budget={DEVICE_BUDGET / 2**20:.1f}MiB")


def _gate_bitequal(tmp: str, out: dict) -> None:
    import jax
    from benchmarks import common
    from repro.core import predictors as P
    from repro.core import stream as ST
    from repro.data import source as SRC

    gen = SRC.GeneratorSource([SRC.FieldVariable("miranda-vx", 13, (96,))])
    path = SRC.write_dataset(os.path.join(tmp, "small"), gen,
                             fmt="memmap", dtype="float64")
    ds = SRC.MemmapSource(path)
    stack = ds.read("miranda-vx")
    rng = float(stack.max() - stack.min())
    ebs = [r * rng for r in EB_RELS]
    ref = np.asarray(P.features_sweep(stack, ebs, sharded=False))
    row = ds.meta("miranda-vx").row_nbytes_f32
    cases = {}
    meshes = [("nomesh", None)]
    if len(jax.devices()) > 1:
        from repro.launch import mesh as M
        meshes.append((f"mesh{len(jax.devices())}", M.make_sweep_mesh()))
    for label, mesh in meshes:
        for rows in (4, 13, 1):
            got = ST.stream_features(
                ds, "miranda-vx", ebs, mesh=mesh,
                stream=ST.StreamConfig(budget_bytes=rows * row))
            exact = bool(np.array_equal(got, ref))
            cases[f"{label}/chunk{rows}"] = exact
            assert exact, f"streamed != in-memory ({label}, chunk={rows})"
    out["bitequal"] = {"k": int(ref.shape[0]), "cases": cases}
    common.emit("stream/bitequal", 0.0,
                f"cases={len(cases)} all_bitexact=True")


def _gate_overlap(tmp: str, out: dict) -> None:
    from benchmarks import common
    from repro.core import stream as ST
    from repro.data import source as SRC

    gen = SRC.GeneratorSource([SRC.FieldVariable("miranda-vx", 64, (96,))])
    path = SRC.write_dataset(os.path.join(tmp, "overlap"), gen,
                             fmt="memmap", dtype="float32")
    ds = SRC.MemmapSource(path)
    meta = ds.meta("miranda-vx")
    chunk_rows = 8
    budget = chunk_rows * meta.row_nbytes_f32
    n_chunks = (meta.rows + chunk_rows - 1) // chunk_rows
    ebs = [1e-3, 1e-2, 1e-1]

    def run(source, prefetch: int) -> float:
        t0 = time.perf_counter()
        ST.stream_features(source, "miranda-vx", ebs,
                           stream=ST.StreamConfig(budget_bytes=budget,
                                                  prefetch=prefetch))
        return time.perf_counter() - t0

    run(ds, 0)                                   # compile warmup
    # calibrate: one chunk's compute (launch + drain) on the unthrottled
    # synchronous loop, then throttle reads to match it -- the regime
    # where overlap pays exactly its pipeline bound
    compute = min(run(ds, 0) for _ in range(3)) / n_chunks
    delay = float(np.clip(compute, 5e-3, 0.25))
    slow = ThrottledSource(ds, delay)

    sync_s = min(run(slow, 0) for _ in range(2))
    stream_s = min(run(slow, 2) for _ in range(2))
    speedup = sync_s / stream_s
    bound = (n_chunks * (delay + compute)) / (n_chunks * max(delay, compute)
                                              + min(delay, compute))
    out["overlap"] = {
        "chunks": n_chunks, "chunk_rows": chunk_rows,
        "compute_per_chunk_s": compute, "read_delay_s": delay,
        "sync_s": sync_s, "streamed_s": stream_s,
        "speedup": speedup, "pipeline_bound": bound,
        "min_required": MIN_OVERLAP_SPEEDUP,
    }
    common.emit("stream/overlap", stream_s * 1e6,
                f"sync_s={sync_s:.2f} streamed_s={stream_s:.2f} "
                f"speedup={speedup:.2f}x bound={bound:.2f}x")
    assert speedup >= MIN_OVERLAP_SPEEDUP, \
        f"double-buffering speedup {speedup:.2f}x < " \
        f"{MIN_OVERLAP_SPEEDUP}x (sync {sync_s:.2f}s, " \
        f"streamed {stream_s:.2f}s, bound {bound:.2f}x)"


def main() -> dict:
    from benchmarks import common

    out: dict = {}
    with tempfile.TemporaryDirectory() as tmp:
        _gate_bitequal(tmp, out)
        _gate_advisor(tmp, out)
        _gate_overlap(tmp, out)
    common.save_json("BENCH_stream", out)
    return out


if __name__ == "__main__":
    res = main()
    print("PASS: streamed sweeps bit-exact, advisor ran at "
          f"{res['advisor']['oversubscription']:.1f}x device budget, "
          f"overlap speedup {res['overlap']['speedup']:.2f}x;",
          json.dumps({k: v for k, v in res.items() if k != 'bitequal'},
                     indent=1, default=str))
