"""Fig 10 / section 5: wall-clock for UC1 (target-CR search) and UC2
(best-compressor selection): statistical model vs running real compressors.

The paper uses SCALE-LetKF V (largest buffers) as the worst case for the
SVD; we use the largest slice our CPU budget allows and report per-stage
times exactly as Fig 10 does (svd / qent / inference / compressor runs)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro import compressors as C
from repro.core import pipeline as PL, predictors as P, usecases as UC

FIELD, COUNT, N = "scale-u", 14, 256
UC2_COMPRESSORS = ["sz2", "sz3-lorenzo", "sz3-interp", "zfp", "mgard",
                   "bitgrooming", "digitrounding"]


def main() -> dict:
    slices = common.field_slices_cached(FIELD, COUNT, N)
    rng = float(jnp.max(slices) - jnp.min(slices))
    ebs = [1e-5 * rng, 1e-4 * rng, 1e-3 * rng, 1e-2 * rng]
    test = slices[-1]
    out = {}

    # ---------------- stage timings (Fig 10 cost structure) ---------------
    t_svd = common.timeit(lambda: P.svd_trunc(test), warmup=1, iters=3)
    t_qent = common.timeit(lambda: P.quantized_entropy(test, ebs[2]),
                           warmup=1, iters=3)
    t_comp = {c: common.timeit(lambda c=c: C.get(c).cr(test, ebs[2]),
                               warmup=0, iters=1) for c in UC2_COMPRESSORS}
    common.emit("fig10/stages", t_svd,
                f"svd_us={t_svd:.0f} qent_us={t_qent:.0f} "
                + " ".join(f"{k}_us={v:.0f}" for k, v in t_comp.items()))

    # ---------------- UC1: find eb achieving target CR --------------------
    gm = UC.EbGridModel.train(slices[:10], "sz2", ebs)   # warm start
    # deploy-time (warm) regime: jit caches already populated
    UC.find_error_bound_for_cr(gm, slices[0], target_cr=8.0)
    # model path: SVD once + qent/inference per probe
    t0 = time.perf_counter()
    eps_m, cr_m = UC.find_error_bound_for_cr(gm, test, target_cr=8.0)
    t_model = time.perf_counter() - t0
    t0 = time.perf_counter()
    eps_x, cr_x, runs = UC.find_error_bound_exhaustive(
        "sz2", test, 8.0, ebs[0], ebs[-1])
    t_exh = time.perf_counter() - t0
    true_m = C.get("sz2").cr(test, eps_m)
    out["uc1"] = {"model_s": t_model, "exhaustive_s": t_exh,
                  "speedup": t_exh / max(t_model, 1e-9),
                  "compressor_runs_saved": runs,
                  "achieved_cr": true_m, "target": 8.0}
    common.emit("fig10/uc1", t_model * 1e6,
                f"speedup={t_exh / max(t_model, 1e-9):.1f}x "
                f"runs_saved={runs} achieved_cr={true_m:.2f} target=8.0")

    # ---------------- UC2: best compressor at fixed eb --------------------
    eps = ebs[2]
    models = {}
    for name in UC2_COMPRESSORS:
        crs = jnp.asarray([common.cr_cached(name, FIELD, COUNT, N, eps, i)
                           for i in range(10)])
        models[name] = PL.CRPredictor.train(slices[:10], crs, eps)
    # warm: featurize once, eval every model
    UC.best_compressor(models, slices[0], eps)       # warm jit
    t0 = time.perf_counter()
    best_pred, preds = UC.best_compressor(models, test, eps)
    t_model2 = time.perf_counter() - t0
    t0 = time.perf_counter()
    best_true, crs_true = UC.best_compressor_exhaustive(
        UC2_COMPRESSORS, test, eps)
    t_exh2 = time.perf_counter() - t0
    ok = crs_true[best_pred] >= 0.9 * crs_true[best_true]
    out["uc2"] = {"model_s": t_model2, "exhaustive_s": t_exh2,
                  "speedup": t_exh2 / max(t_model2, 1e-9),
                  "pred_best": best_pred, "true_best": best_true,
                  "within_10pct": bool(ok)}
    common.emit("fig10/uc2", t_model2 * 1e6,
                f"speedup={t_exh2 / max(t_model2, 1e-9):.1f}x "
                f"pred_best={best_pred} true_best={best_true} good={ok}")
    common.save_json("fig10_usecases", out)
    return out


if __name__ == "__main__":
    main()
