"""Autotuner gate: the committed tuned table must be bit-safe and fast.

For every cell in this backend's tuned table (``kernels/tuned/<backend>
.json``), re-time the default and tuned configurations through the same
ops-level entry points the production paths use and enforce:

* **bit-equality** -- the tuned configuration's output is bitwise
  identical to the default's (the tuner's bit-safety filter must hold
  on this machine too, not just the one that generated the table);
* **no regression** -- tuned <= default x 1.05 on every cell (5% noise
  allowance for CI timer jitter; the tuner's 2% hysteresis means real
  entries should clear this easily);
* **a real win** -- on the CPU backend, the best qent cell must hit
  >= 1.15x (the speedup that justifies shipping the table).

Writes ``results/BENCH_tune.json`` with per-cell timings, speedups, and
achieved-vs-roofline fractions (cost models from benchmarks.roofline,
peaks from the backend HW table; CPU bandwidth is the measured STREAM
number).
"""
from __future__ import annotations

import numpy as np

QENT_GATE = 1.15      # best CPU qent cell must beat the default by this
NOISE = 1.05          # per-cell regression allowance (timer jitter)
ITERS = 5


def main() -> dict:
    from benchmarks import common
    from benchmarks import roofline as RF
    from repro.kernels import tune as KT
    from repro.kernels.gram import gram as GK
    from repro.kernels.gram import ops as gram_ops
    from repro.kernels.qent import qent as QK
    from repro.kernels.qent import ops as qent_ops

    backend = KT.backend_kind()
    KT.invalidate_table_cache()
    table = KT.load_table(backend)
    assert table is not None, (
        f"no tuned table for backend {backend!r} "
        f"({KT.table_path(backend)}) -- run python -m repro.kernels.tune")
    hw = RF.backend_hw(backend)

    cells = {}
    qent_best = 0.0
    for key in sorted(table["cells"]):
        cell = table["cells"][key]
        shape = tuple(cell["shape"])
        if key.startswith("gram:"):
            k, m, n = shape
            x = KT._rng((k, m, n))
            default = {"bn": GK.DEFAULT_BN, "bk": GK.DEFAULT_BK}
            tuned = {"bn": cell["bn"], "bk": cell["bk"]}

            def run(bn, bk, x=x):
                return gram_ops.gram_batched(x, bn=bn, bk=bk)
        else:
            k, n, bins, e = shape
            x = KT._rng((k, n), seed=1)
            epss = np.geomspace(1e-3, 1e-1, e).astype(np.float32)
            default = {"tile": QK.DEFAULT_TILE}
            tuned = {"tile": cell["tile"]}

            def run(tile, x=x, epss=epss, bins=bins):
                return qent_ops.quantized_entropy_sweep(
                    x, epss, bins, tile=tile)

        ref = np.asarray(run(*default.values()))
        out = np.asarray(run(*tuned.values()))
        bitequal = bool(np.array_equal(ref, out))
        t_def = common.time_fn(run, *default.values(), iters=ITERS)
        if tuned == default:
            # identical config -> identical executable; timing it twice
            # and comparing would gate on pure timer jitter
            t_tun = t_def
        else:
            t_tun = common.time_fn(run, *tuned.values(), iters=ITERS)
        speedup = t_def / t_tun
        roof = RF.kernel_cell(key.split(":")[0], shape, t_tun, hw)
        if key.startswith("qent:"):
            qent_best = max(qent_best, speedup)
        cells[key] = {
            "shape": list(shape), "default": default, "tuned": tuned,
            "t_default_s": t_def, "t_tuned_s": t_tun,
            "speedup": speedup, "bitequal": bitequal,
            "table_speedup": cell.get("speedup"),
            "frac_peak_flops": roof["frac_peak_flops"],
            "frac_peak_bw": roof["frac_peak_bw"], "bound": roof["bound"],
        }
        common.emit(
            f"tune/{key}", t_tun * 1e6,
            f"speedup={speedup:.2f}x (table {cell.get('speedup', 1):.2f}x) "
            f"bitequal={bitequal} bound={roof['bound']} "
            f"bw_frac={roof['frac_peak_bw']*100:.1f}pct")

    res = {"backend": backend, "schema_version": table["schema_version"],
           "hw": hw, "cells": cells, "qent_best_speedup": qent_best}
    common.save_json("BENCH_tune", res)

    bad_bits = [k for k, c in cells.items() if not c["bitequal"]]
    assert not bad_bits, f"tuned configs changed numerics: {bad_bits}"
    slow = [k for k, c in cells.items()
            if c["t_tuned_s"] > c["t_default_s"] * NOISE]
    assert not slow, (
        f"tuned config slower than default (> {NOISE}x noise) on: "
        + ", ".join(f"{k} ({cells[k]['speedup']:.2f}x)" for k in slow))
    if backend == "cpu":
        assert qent_best >= QENT_GATE, (
            f"best CPU qent speedup {qent_best:.2f}x < {QENT_GATE}x -- "
            "the committed table no longer pays; re-run the tuner")
    print(f"# tune: {len(cells)} cells bit-equal, "
          f"best qent {qent_best:.2f}x (gate {QENT_GATE}x on cpu) -- OK")
    return res


if __name__ == "__main__":
    main()
