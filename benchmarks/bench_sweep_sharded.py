"""Sharded sweep benchmark: one featurization sweep spanning N CPU devices
vs the single-device engine, with an exactness gate.

The device count is locked at jax init, so each configuration runs in a
child interpreter with ``XLA_FLAGS=--xla_force_host_platform_device_count``
set before jax is imported.  Every child featurizes the SAME (k, e) sweep
(deterministic synthetic field), saves the (k, e, 2) tensor and its
timing; the parent asserts the multi-device outputs match the 1-device
engine to f32 tolerance (the sharded body is the single-device body run
per shard, so on CPU the match is typically exact) and records the
single- vs multi-device timings side by side.

Virtual CPU devices share the same cores, so multi-device *wall-clock*
speedup is not the acceptance signal here (that comes on real multi-chip
hardware); the benchmark's job is the equivalence gate + a record of the
sharding overhead.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

import numpy as np

K, N = 32, 160
K_RAGGED = 27          # non-divisible slice count: exercises pad + drop
EB_RELS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 1e-1)
DEVICE_COUNTS = (1, 8)


def _child(num_devices: int, out_prefix: str) -> None:
    import jax
    import jax.numpy as jnp
    from benchmarks import common
    from repro.core import predictors as P
    from repro.dist import sharding as S
    from repro.launch import mesh as M

    assert len(jax.devices()) == num_devices, jax.devices()
    slices = common.field_slices_cached("miranda-vx", K, N)
    rng = float(jnp.max(slices) - jnp.min(slices))
    epss = jnp.asarray([r * rng for r in EB_RELS], jnp.float32)

    def run(stack):
        if num_devices == 1:
            return P.features_sweep(stack, epss, sharded=False)
        with S.use_mesh(M.make_sweep_mesh()):
            return P.features_sweep(stack, epss)

    t_full = common.timeit(lambda: run(slices), warmup=1, iters=5)
    out_full = np.asarray(run(slices))
    t_ragged = common.timeit(lambda: run(slices[:K_RAGGED]), warmup=1, iters=5)
    out_ragged = np.asarray(run(slices[:K_RAGGED]))

    np.save(out_prefix + ".full.npy", out_full)
    np.save(out_prefix + ".ragged.npy", out_ragged)
    with open(out_prefix + ".json", "w") as f:
        json.dump({"devices": num_devices, "full_us": t_full,
                   "ragged_us": t_ragged}, f)


def main() -> dict:
    from benchmarks import common

    results = {}
    with tempfile.TemporaryDirectory() as tmp:
        for d in DEVICE_COUNTS:
            prefix = os.path.join(tmp, f"dev{d}")
            common.run_child_module(
                "benchmarks.bench_sweep_sharded", ["--child", d, prefix], d)
            with open(prefix + ".json") as f:
                results[d] = json.load(f)
            results[d]["full"] = np.load(prefix + ".full.npy")
            results[d]["ragged"] = np.load(prefix + ".ragged.npy")

    base = results[DEVICE_COUNTS[0]]
    out = {"k": K, "k_ragged": K_RAGGED, "e": len(EB_RELS)}
    for d in DEVICE_COUNTS[1:]:
        diff_full = float(np.abs(results[d]["full"] - base["full"]).max())
        diff_ragged = float(
            np.abs(results[d]["ragged"] - base["ragged"]).max())
        common.emit(
            f"sweep_sharded/{d}dev", results[d]["full_us"],
            f"k={K} e={len(EB_RELS)} single_us={base['full_us']:.0f} "
            f"sharded_us={results[d]['full_us']:.0f} "
            f"ragged_single_us={base['ragged_us']:.0f} "
            f"ragged_sharded_us={results[d]['ragged_us']:.0f} "
            f"maxdiff={diff_full:.2e} maxdiff_ragged={diff_ragged:.2e}")
        out[f"dev{d}"] = {
            "single_us": base["full_us"],
            "sharded_us": results[d]["full_us"],
            "ragged_single_us": base["ragged_us"],
            "ragged_sharded_us": results[d]["ragged_us"],
            "max_abs_diff": diff_full,
            "max_abs_diff_ragged": diff_ragged,
        }
        # f32 tolerance gate (acceptance): the sharded sweep must be a
        # drop-in replacement for the single-device engine
        assert diff_full < 1e-5, f"sharded sweep diverged: {diff_full}"
        assert diff_ragged < 1e-5, \
            f"sharded ragged sweep diverged: {diff_ragged}"
    common.save_json("bench_sweep_sharded", out)
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(int(sys.argv[2]), sys.argv[3])
    else:
        res = main()
        print("PASS: sharded == single-device to f32 tolerance;",
              json.dumps({k: v for k, v in res.items() if k.startswith("dev")},
                         indent=1))
