"""Fig 5: absolute-percentage-error distributions on the four Gaussian
sample types x five compressors (the paper's proof-of-concept study)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core import pipeline as PL

COMPRESSORS = ["sz2", "zfp", "mgard", "digitrounding", "bitgrooming"]
EPS = 1e-3   # the paper's Gaussian-sample error bound


def main() -> dict:
    out = {}
    for stype in (1, 2, 3, 4):
        slices = common.gaussian_cached(stype, 20, 256)
        feats = np.asarray(PL.featurize_slices(slices, EPS))
        for comp in COMPRESSORS:
            from repro import compressors as C
            c = C.get(comp)
            crs = np.asarray([c.cr(s, EPS) for s in slices])
            res = PL.kfold_evaluate(feats, crs, model="spline", k=8)
            ape = PL.ape(res.true_cr, res.pred_cr)
            out[f"type{stype}|{comp}"] = {
                "medape": res.medape, "mean_ape": float(np.mean(ape)),
                "max_ape": float(np.max(ape)),
            }
            common.emit(f"fig5/type{stype}/{comp}", 0.0,
                        f"medape_pct={res.medape:.2f} mean={np.mean(ape):.2f} "
                        f"max={np.max(ape):.2f}")
    common.save_json("fig5_gaussian", out)
    meds = [v["medape"] for v in out.values()]
    import numpy as _np
    within = sum(1 for m in meds if m <= 10.0)
    # the paper's <=8% applies at 1028^2 samples with larger training sets;
    # at 256^2/n=20 the hardest synthetic type (4: random ranges + spatial
    # weights) on spatially-blind compressors has a heavier tail -- matching
    # the paper's own observation that type 4 + rounding compressors are
    # the worst cells (their Fig 5 whiskers)
    common.emit("fig5/overall", 0.0,
                f"median_medape_pct={_np.median(meds):.2f} "
                f"cells_within_10pct={within}/{len(meds)} "
                f"max_medape_pct={max(meds):.2f} (type4) "
                f"pass={_np.median(meds) <= 8.0 and within >= 16}")
    return out


if __name__ == "__main__":
    main()
