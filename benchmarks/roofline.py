"""Roofline analysis: compiled dry-run artifacts + measured sweep kernels.

Per (arch x shape) on the single-pod production mesh:
  compute term    = HLO_FLOPs / (chips x peak FLOP/s)
  memory term     = HLO_bytes / (chips x peak bytes/s)
  collective term = wire_bytes / (chips x 50 GB/s)

Peaks are keyed on the backend HW table in ``repro.kernels.tune``
(tpu-v5e: 197 TF/s / 819 GB/s); the CPU entry's bandwidth is *measured*
on this host with a STREAM-style add (``measured_stream_bw``) so the
fractions mean something on CI boxes today.

FLOP / byte / collective numbers come from the *unrolled* cost-accounting
build (``dryrun --unroll``: identical math, no while loops, so XLA cost
analysis sees every layer); HBM-fit evidence comes from the production
scan+microbatch build's memory_analysis.  HLO numbers are per-partition
(SPMD), so terms are already per-chip.

The sweep kernels (gram, qent) get a *measured* roofline: per (kernel,
shape) cell, achieved bytes/s and FLOP/s from a timed run of the tuned
configuration vs the backend peaks (``kernel_table``).  bench_tune
reuses these cost models for its achieved-vs-roofline fractions.

Emits the EXPERIMENTS.md section Roofline table + per-cell bottleneck.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.kernels import tune as KT

# Interconnect + HBM capacity are mesh-level numbers, not in the
# per-backend kernel table; compute/bandwidth peaks come from it.
_V5E = KT.BACKEND_HW["tpu-v5e"]
HW = {"peak_flops": _V5E["peak_flops"], "hbm_bw": _V5E["mem_bw"],
      "ici_bw": 50e9, "hbm_bytes": 16e9}

DRYRUN = os.path.join(os.path.dirname(__file__), "results", "dryrun")

_STREAM_BW: Optional[float] = None


def measured_stream_bw(n: int = 1 << 24, iters: int = 5) -> float:
    """STREAM-style add bandwidth (bytes/s) on this host: ``a = b + c``
    over three f64 arrays well past LLC (3 x 128 MB at the default n),
    best-of-N.  Cached per process."""
    global _STREAM_BW
    if _STREAM_BW is None:
        b, c = np.ones(n), np.ones(n)
        a = np.empty(n)
        best = float("inf")
        for _ in range(max(1, iters)):
            t0 = time.perf_counter()
            np.add(b, c, out=a)
            best = min(best, time.perf_counter() - t0)
        _STREAM_BW = 3 * 8 * n / best
    return _STREAM_BW


def backend_hw(kind: Optional[str] = None) -> Dict[str, float]:
    """Roofline peaks for a backend kind.  The CPU entry's nominal
    bandwidth is replaced with the measured STREAM number."""
    kind = kind or KT.backend_kind()
    entry = dict(KT.hw_for(kind), kind=kind)
    if kind == "cpu":
        entry["mem_bw"] = measured_stream_bw()
        entry["mem_bw_source"] = "measured-stream-add"
    else:
        entry["mem_bw_source"] = "nominal"
    return entry


# ---------------------------------------------------------------------------
# Sweep-kernel cost models (bench_tune imports these)


def gram_cost(k: int, m: int, n: int) -> Dict[str, float]:
    """Batched X^T X on a (k, m, n) stack: 2mn^2 FLOPs per slice; the
    memory floor is one read of X + one write of the (n, n) output."""
    return {"flops": 2.0 * k * m * n * n,
            "bytes": 4.0 * k * (m * n + n * n)}


def qent_cost(k: int, n: int, bins: int, e: int) -> Dict[str, float]:
    """Fused quantize+histogram sweep of (k, n) values over e bounds:
    ~4 ops per element per bound (scale, round, clip, scatter-add); the
    fused kernel re-reads the tile once per bound and writes one
    (bins,) histogram per (row, bound)."""
    return {"flops": 4.0 * k * e * n,
            "bytes": 4.0 * k * e * (n + bins)}


def kernel_cell(name: str, shape: Tuple[int, ...], t_s: float,
                hw: Dict[str, float]) -> Dict[str, float]:
    """Achieved-vs-peak fractions for one timed (kernel, shape) cell."""
    cost = gram_cost(*shape[:3]) if name == "gram" else qent_cost(*shape)
    flops_s = cost["flops"] / t_s
    bytes_s = cost["bytes"] / t_s
    ff = flops_s / hw["peak_flops"]
    fb = bytes_s / hw["mem_bw"]
    return {"kernel": name, "shape": list(shape), "time_s": t_s,
            "achieved_flops_s": flops_s, "achieved_bytes_s": bytes_s,
            "frac_peak_flops": ff, "frac_peak_bw": fb,
            "bound": "memory" if fb > ff else "compute"}


def kernel_table(iters: int = 3) -> Dict[str, dict]:
    """Measured roofline for the sweep kernels on this backend: time the
    tuned configuration (table-resolved) of every full-search cell."""
    from repro.kernels.gram import ops as gram_ops
    from repro.kernels.qent import ops as qent_ops
    hw = backend_hw()
    out: Dict[str, dict] = {"hw": hw}
    for k, m, n in KT.FULL_GRAM_CELLS:
        x = np.asarray(
            np.random.default_rng(0).standard_normal((k, m, n)), np.float32)
        t = KT.time_fn(gram_ops.gram_batched, x, iters=iters)
        out[KT.gram_key(m, n)] = kernel_cell("gram", (k, m, n), t, hw)
    for k, n, bins, e in KT.FULL_QENT_CELLS:
        x = np.asarray(
            np.random.default_rng(1).standard_normal((k, n)), np.float32)
        epss = np.geomspace(1e-3, 1e-1, e).astype(np.float32)
        t = KT.time_fn(
            qent_ops.quantized_entropy_sweep, x, epss, bins, iters=iters)
        out[KT.qent_key(n, bins)] = kernel_cell(
            "qent", (k, n, bins, e), t, hw)
    return out


def load(arch: str, shape: str, mesh: str = "single",
         tag: str = "") -> Optional[dict]:
    p = os.path.join(DRYRUN, f"{arch}__{shape}__{mesh}{tag}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def cell_terms(arch: str, shape: str) -> Optional[dict]:
    """Roofline terms for one cell (single-pod)."""
    cost = load(arch, shape, "single", "__unroll")
    prod = load(arch, shape, "single")
    if prod is None:
        return None
    if prod.get("status") == "skipped":
        return {"arch": arch, "shape": shape, "status": "skipped",
                "reason": prod.get("reason", "")}
    if cost is None or cost.get("status") != "ok":
        cost = prod  # fallback: scan-counted (understates; flagged)
        accounting = "scan(understated)"
    else:
        accounting = "unrolled"

    flops = cost["flops_per_device"]
    bytes_acc = cost["bytes_per_device"]
    wire = cost["collectives"]["total_wire_bytes"]
    mem = prod.get("memory", {})
    hbm_used = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0))
    t_comp = flops / HW["peak_flops"]
    # XLA "bytes accessed" assumes zero fusion (every HLO op round-trips
    # HBM) -- an upper bound.  One pass over the live working set is the
    # matching lower bound; a fused TPU step sits between the two.
    t_mem_ub = bytes_acc / HW["hbm_bw"]
    t_mem_lb = hbm_used / HW["hbm_bw"]
    t_mem = t_mem_lb
    # XLA-CPU promotes every bf16 all-reduce to f32 (verified with a probe
    # psum; TPU keeps bf16), so measured AR bytes are 2x what the TPU would
    # ship.  All gradient/activation ARs in these models are bf16 -> halve.
    ar_wire = cost["collectives"]["all-reduce"]["wire_bytes"]
    wire_tpu = wire - ar_wire / 2
    t_coll = wire_tpu / HW["ici_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())          # no-overlap bound
    model_flops_dev = cost["model_flops_total"] / cost["chips"]
    mfu = model_flops_dev / HW["peak_flops"] / max(step_time, 1e-12)
    return {
        "arch": arch, "shape": shape, "status": "ok",
        "accounting": accounting,
        "compute_s": t_comp, "memory_s": t_mem,
        "memory_unfused_s": t_mem_ub, "collective_s": t_coll,
        "dominant": dominant,
        "roofline_step_s": step_time,
        "model_flops_total": cost["model_flops_total"],
        "hlo_flops_per_dev": flops,
        "useful_ratio": model_flops_dev / max(flops, 1),
        "mfu_bound": mfu,
        "hbm_used_bytes": hbm_used,
        "hbm_fits": hbm_used < HW["hbm_bytes"],
        "collectives": cost["collectives"],
        "wire_bytes_tpu": wire_tpu,
        "params": cost.get("params"),
    }


def full_table() -> Dict[str, dict]:
    from repro.configs.base import ARCH_IDS, SHAPES
    out = {}
    for arch in ARCH_IDS:
        for shape in SHAPES:
            t = cell_terms(arch, shape)
            if t is not None:
                out[f"{arch}|{shape}"] = t
    return out


def markdown_table(table: Dict[str, dict]) -> str:
    lines = [
        "| arch | shape | acct | compute s | memory s | collective s | "
        "dominant | MFU-bound | useful FLOP ratio | HBM GB (fits) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key, t in table.items():
        arch, shape = key.split("|")
        if t["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | — | N/A "
                         f"(long-context skip) | — | — | — |")
            continue
        lines.append(
            f"| {arch} | {shape} | {t['accounting'][:6]} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['mfu_bound']*100:.1f}% | {t['useful_ratio']:.2f} "
            f"| {t['hbm_used_bytes']/1e9:.1f} ({'Y' if t['hbm_fits'] else 'N'}) |")
    return "\n".join(lines)


def main():
    from benchmarks import common
    table = full_table()
    kernels = kernel_table()
    for key, c in kernels.items():
        if key == "hw":
            continue
        common.emit(
            f"roofline/kernel/{key}", c["time_s"] * 1e6,
            f"bound={c['bound']} "
            f"bw={c['achieved_bytes_s']/1e9:.2f}GB/s "
            f"({c['frac_peak_bw']*100:.1f}pct of "
            f"{kernels['hw']['mem_bw']/1e9:.0f}GB/s "
            f"{kernels['hw']['mem_bw_source']}) "
            f"flops={c['frac_peak_flops']*100:.2f}pct of peak")
    common.save_json("roofline", {**table, "kernels": kernels})
    ok = [t for t in table.values() if t["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda t: t["mfu_bound"])
        coll = max(ok, key=lambda t: (t["collective_s"] /
                                      max(t["roofline_step_s"], 1e-12)))
        for t in ok:
            common.emit(
                f"roofline/{t['arch']}/{t['shape']}", 0.0,
                f"dominant={t['dominant']} mfu_bound={t['mfu_bound']*100:.1f}pct "
                f"useful={t['useful_ratio']:.2f} "
                f"acct={t['accounting']}")
        common.emit("roofline/worst_cell", 0.0,
                    f"{worst['arch']}|{worst['shape']} "
                    f"mfu={worst['mfu_bound']*100:.1f}pct")
        common.emit("roofline/most_collective_bound", 0.0,
                    f"{coll['arch']}|{coll['shape']} "
                    f"coll_s={coll['collective_s']:.3e}")
    print()
    print(markdown_table(table))
    return table


if __name__ == "__main__":
    main()
