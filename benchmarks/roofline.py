"""Roofline analysis from the compiled dry-run artifacts.

Per (arch x shape) on the single-pod production mesh:
  compute term    = HLO_FLOPs / (chips x 197 TF/s)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = wire_bytes / (chips x 50 GB/s)

FLOP / byte / collective numbers come from the *unrolled* cost-accounting
build (``dryrun --unroll``: identical math, no while loops, so XLA cost
analysis sees every layer); HBM-fit evidence comes from the production
scan+microbatch build's memory_analysis.  HLO numbers are per-partition
(SPMD), so terms are already per-chip.

Emits the EXPERIMENTS.md section Roofline table + per-cell bottleneck.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

import numpy as np

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9,
      "hbm_bytes": 16e9}

DRYRUN = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load(arch: str, shape: str, mesh: str = "single",
         tag: str = "") -> Optional[dict]:
    p = os.path.join(DRYRUN, f"{arch}__{shape}__{mesh}{tag}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def cell_terms(arch: str, shape: str) -> Optional[dict]:
    """Roofline terms for one cell (single-pod)."""
    cost = load(arch, shape, "single", "__unroll")
    prod = load(arch, shape, "single")
    if prod is None:
        return None
    if prod.get("status") == "skipped":
        return {"arch": arch, "shape": shape, "status": "skipped",
                "reason": prod.get("reason", "")}
    if cost is None or cost.get("status") != "ok":
        cost = prod  # fallback: scan-counted (understates; flagged)
        accounting = "scan(understated)"
    else:
        accounting = "unrolled"

    flops = cost["flops_per_device"]
    bytes_acc = cost["bytes_per_device"]
    wire = cost["collectives"]["total_wire_bytes"]
    mem = prod.get("memory", {})
    hbm_used = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0))
    t_comp = flops / HW["peak_flops"]
    # XLA "bytes accessed" assumes zero fusion (every HLO op round-trips
    # HBM) -- an upper bound.  One pass over the live working set is the
    # matching lower bound; a fused TPU step sits between the two.
    t_mem_ub = bytes_acc / HW["hbm_bw"]
    t_mem_lb = hbm_used / HW["hbm_bw"]
    t_mem = t_mem_lb
    # XLA-CPU promotes every bf16 all-reduce to f32 (verified with a probe
    # psum; TPU keeps bf16), so measured AR bytes are 2x what the TPU would
    # ship.  All gradient/activation ARs in these models are bf16 -> halve.
    ar_wire = cost["collectives"]["all-reduce"]["wire_bytes"]
    wire_tpu = wire - ar_wire / 2
    t_coll = wire_tpu / HW["ici_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())          # no-overlap bound
    model_flops_dev = cost["model_flops_total"] / cost["chips"]
    mfu = model_flops_dev / HW["peak_flops"] / max(step_time, 1e-12)
    return {
        "arch": arch, "shape": shape, "status": "ok",
        "accounting": accounting,
        "compute_s": t_comp, "memory_s": t_mem,
        "memory_unfused_s": t_mem_ub, "collective_s": t_coll,
        "dominant": dominant,
        "roofline_step_s": step_time,
        "model_flops_total": cost["model_flops_total"],
        "hlo_flops_per_dev": flops,
        "useful_ratio": model_flops_dev / max(flops, 1),
        "mfu_bound": mfu,
        "hbm_used_bytes": hbm_used,
        "hbm_fits": hbm_used < HW["hbm_bytes"],
        "collectives": cost["collectives"],
        "wire_bytes_tpu": wire_tpu,
        "params": cost.get("params"),
    }


def full_table() -> Dict[str, dict]:
    from repro.configs.base import ARCH_IDS, SHAPES
    out = {}
    for arch in ARCH_IDS:
        for shape in SHAPES:
            t = cell_terms(arch, shape)
            if t is not None:
                out[f"{arch}|{shape}"] = t
    return out


def markdown_table(table: Dict[str, dict]) -> str:
    lines = [
        "| arch | shape | acct | compute s | memory s | collective s | "
        "dominant | MFU-bound | useful FLOP ratio | HBM GB (fits) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key, t in table.items():
        arch, shape = key.split("|")
        if t["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | — | N/A "
                         f"(long-context skip) | — | — | — |")
            continue
        lines.append(
            f"| {arch} | {shape} | {t['accounting'][:6]} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['mfu_bound']*100:.1f}% | {t['useful_ratio']:.2f} "
            f"| {t['hbm_used_bytes']/1e9:.1f} ({'Y' if t['hbm_fits'] else 'N'}) |")
    return "\n".join(lines)


def main():
    from benchmarks import common
    table = full_table()
    common.save_json("roofline", table)
    ok = [t for t in table.values() if t["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda t: t["mfu_bound"])
        coll = max(ok, key=lambda t: (t["collective_s"] /
                                      max(t["roofline_step_s"], 1e-12)))
        for t in ok:
            common.emit(
                f"roofline/{t['arch']}/{t['shape']}", 0.0,
                f"dominant={t['dominant']} mfu_bound={t['mfu_bound']*100:.1f}pct "
                f"useful={t['useful_ratio']:.2f} "
                f"acct={t['accounting']}")
        common.emit("roofline/worst_cell", 0.0,
                    f"{worst['arch']}|{worst['shape']} "
                    f"mfu={worst['mfu_bound']*100:.1f}pct")
        common.emit("roofline/most_collective_bound", 0.0,
                    f"{coll['arch']}|{coll['shape']} "
                    f"coll_s={coll['collective_s']:.3e}")
    print()
    print(markdown_table(table))
    return table


if __name__ == "__main__":
    main()
