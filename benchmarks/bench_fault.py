"""Elastic fault-recovery gate for the sweep service (ISSUE 6
acceptance).

A reference child computes every request's feature tensor with the
plain unsharded sweep.  Then a 2-process ``jax.distributed`` fabric
runs the same requests through :class:`repro.serve.sweep_service
.SweepService` -- with the follower armed (via
``repro.dist.faultinject``) to SIGKILL itself on its second collective
launch.  The leader must detect the loss, shrink the fabric, requeue
the in-flight batch, and complete every future on the survivor; the
parent asserts every recovered tensor is BIT-EXACT against the
reference, that exactly the armed child died, that the service
recorded the recovery (``recoveries >= 1``, epoch advanced, KV
transport, survivor-only process set), and that the faulted batch
finished well inside the recovery bound (no reliance on the harness
reaping hung children).

Virtual CPU devices share the same cores, so the timings record fault
*detection + relaunch* overhead rather than hardware speedups.  Writes
``results/BENCH_fault.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

NPROCS = 2
DEVICES_EACH = 2
FAULT_PID = 1
FAULT_NTH = 2                  # die on launch 2: launch 1 warms/compiles
LAUNCH_TIMEOUT_S = 60.0        # must cover the warm launch's compile
EB = (1e-3, 1e-2, 1e-1)


def _payloads():
    """Deterministic request payloads shared by every child."""
    rng = np.random.default_rng(7)
    base = rng.standard_normal((8, 32, 32)).astype(np.float32)
    eps = np.asarray(EB, np.float32)
    reqs = [("warm", base[:4])]
    reqs += [(f"inflight{i}", base[2 * i:2 * i + 2] + np.float32(i))
             for i in range(4)]
    reqs.append(("post", base[4:] * np.float32(0.5)))
    return reqs, eps


def _child_ref(out_prefix: str) -> None:
    from repro.core import predictors as PRED

    reqs, eps = _payloads()
    times = {}
    for name, stack in reqs:
        t0 = time.perf_counter()
        out = np.asarray(PRED.features_sweep(stack, eps, sharded=False))
        times[name] = time.perf_counter() - t0
        np.save(f"{out_prefix}.{name}.npy", out)
    with open(out_prefix + ".json", "w") as f:
        json.dump({"times_s": times}, f)


def _child_svc(pid: int, port: int, out_prefix: str) -> None:
    from repro.launch import mesh as M
    M.dist_init(f"127.0.0.1:{port}", num_processes=NPROCS, process_id=pid)

    from repro.dist import faultinject as FI
    from repro.serve.sweep_service import ServiceConfig, SweepService

    if pid == FAULT_PID:
        FI.configure(f"follower_launch:kill:{FAULT_NTH}")

    mesh = M.make_sweep_mesh()
    scfg = ServiceConfig(launch_timeout_s=LAUNCH_TIMEOUT_S,
                         heartbeat_s=0.25, max_wait_ms=20.0)
    svc = SweepService(scfg, mesh=mesh)
    reqs, eps = _payloads()
    by_name = dict(reqs)

    if pid == 0:
        outs, times = {}, {}
        # launch 1: full 2-process fabric (includes executable compile)
        t0 = time.perf_counter()
        outs["warm"] = np.asarray(
            svc.submit_featurize(by_name["warm"], eps).result(240))
        times["warm_s"] = time.perf_counter() - t0
        # launch 2 kills the follower mid-collective; every one of these
        # in-flight futures must still complete on the shrunken fabric
        inflight = [(n, s) for n, s in reqs if n.startswith("inflight")]
        t0 = time.perf_counter()
        futs = [(n, svc.submit_featurize(s, eps)) for n, s in inflight]
        for n, f in futs:
            outs[n] = np.asarray(f.result(240))
        times["faulted_batch_s"] = time.perf_counter() - t0
        # steady state on the recovered (survivor-only, KV) fabric
        t0 = time.perf_counter()
        outs["post"] = np.asarray(
            svc.submit_featurize(by_name["post"], eps).result(240))
        times["post_recovery_s"] = time.perf_counter() - t0
        st = svc.stats()
        svc.close()
        for name, out in outs.items():
            np.save(f"{out_prefix}.{name}.npy", out)
        with open(out_prefix + ".json", "w") as f:
            json.dump({"times_s": times, "recoveries": st["recoveries"],
                       "epoch": st["epoch"], "transport": st["transport"],
                       "procs": st["procs"]}, f)
    else:
        try:
            svc.serve()        # SIGKILLed mid-launch by the injection
        except Exception:
            pass
        svc.close()
    # skip the jax.distributed atexit shutdown: its barrier would abort
    # against the already-dead peer
    sys.stdout.flush()
    os._exit(0)


def main() -> dict:
    from benchmarks import common

    with tempfile.TemporaryDirectory() as tmp:
        ref = os.path.join(tmp, "ref")
        svc = os.path.join(tmp, "svc")
        common.run_child_module(
            "benchmarks.bench_fault", ["--child-ref", ref], 1)
        port = common.free_port()
        procs = [common.spawn_child_module(
                     "benchmarks.bench_fault",
                     ["--child-svc", pid, port, svc], DEVICES_EACH)
                 for pid in range(NPROCS)]
        try:
            texts = [p.communicate(timeout=560) for p in procs]
        except subprocess.TimeoutExpired:
            for p in procs:
                p.kill()
            texts = [p.communicate() for p in procs]
            raise AssertionError(
                "fault-recovery children hung (recovery never finished?):"
                "\n" + "\n".join(o + "\n" + e for o, e in texts))
        # exactly the armed follower dies; the leader must exit clean
        assert procs[0].returncode == 0, (
            f"leader rc={procs[0].returncode}\n"
            f"{texts[0][0]}\n{texts[0][1]}")
        assert procs[FAULT_PID].returncode != 0, \
            "injected follower survived its own SIGKILL"

        with open(ref + ".json") as f:
            meta_ref = json.load(f)
        with open(svc + ".json") as f:
            meta = json.load(f)

        reqs, _ = _payloads()
        out = {"processes": NPROCS, "devices_each": DEVICES_EACH,
               "fault": f"follower_launch:kill:{FAULT_NTH} on pid "
                        f"{FAULT_PID}",
               "recoveries": meta["recoveries"], "epoch": meta["epoch"],
               "transport": meta["transport"], "procs": meta["procs"],
               "times_s": meta["times_s"], "cases": {}}
        for name, _stack in reqs:
            a = np.load(f"{ref}.{name}.npy")
            b = np.load(f"{svc}.{name}.npy")
            bitexact = bool(np.array_equal(a, b))
            out["cases"][name] = {
                "k": int(a.shape[0]), "bitexact": bitexact,
                "max_abs_diff": float(np.abs(a - b).max()),
            }
            assert bitexact, (
                f"{name}: recovered sweep diverged "
                f"(maxdiff {out['cases'][name]['max_abs_diff']})")

        # acceptance: the fault was survived, attributed, and bounded
        assert meta["recoveries"] >= 1, meta
        assert meta["epoch"] >= 1 and meta["transport"] == "kv", meta
        assert meta["procs"] == [0], meta
        assert meta["times_s"]["faulted_batch_s"] < 3 * LAUNCH_TIMEOUT_S, \
            meta["times_s"]
        common.emit(
            "fault/warm_launch", meta["times_s"]["warm_s"] * 1e6,
            f"procs={NPROCS} ref_s={meta_ref['times_s']['warm']:.2f}")
        common.emit(
            "fault/faulted_batch",
            meta["times_s"]["faulted_batch_s"] * 1e6,
            f"requests=4 recoveries={meta['recoveries']} "
            f"transport={meta['transport']} bitexact=True")
        common.emit(
            "fault/post_recovery",
            meta["times_s"]["post_recovery_s"] * 1e6,
            f"procs={meta['procs']} epoch={meta['epoch']}")
    common.save_json("BENCH_fault", out)
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child-ref":
        _child_ref(sys.argv[2])
    elif len(sys.argv) > 1 and sys.argv[1] == "--child-svc":
        _child_svc(int(sys.argv[2]), int(sys.argv[3]), sys.argv[4])
    else:
        res = main()
        print("PASS: follower loss survived, in-flight batch recovered "
              "bit-exact;", json.dumps(
                  {k: res[k] for k in
                   ("recoveries", "epoch", "transport", "times_s")},
                  indent=1))
