"""End-to-end training driver: train a small LM for a few hundred steps with
the framework's production services -- microbatched AdamW, error-feedback
gradient compression gated by the paper's q-ent predictor, async lossy
checkpoints, and fault-tolerant restart.

    PYTHONPATH=src python examples/train_lm.py                # ~10M params
    PYTHONPATH=src python examples/train_lm.py --preset 100m  # ~100M params
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume
"""
import argparse
import dataclasses
import time

import jax

from repro.configs.base import ModelConfig
from repro.ckpt.checkpoint import LossyPolicy
from repro.data.tokens import make_data_iter
from repro.train import loop as LOOP
from repro.train import optimizer as OPT
from repro.train import train_step as TS
from repro.train.grad_compress import CompressConfig

PRESETS = {
    "10m": ModelConfig(name="lm-10m", family="dense", num_layers=6,
                       d_model=320, num_heads=8, num_kv_heads=4,
                       d_ff=896, vocab_size=8192),
    "100m": ModelConfig(name="lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4,
                        d_ff=2048, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--no-compress", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    from repro.models.model import count_params
    print(f"model {cfg.name}: {count_params(cfg):,} params")

    compress = None if args.no_compress else CompressConfig(
        enabled=True, gate_ratio=2.0)
    state = TS.init_state(cfg, jax.random.PRNGKey(0),
                          compress=compress is not None)
    step = jax.jit(TS.make_train_step(
        cfg, OPT.AdamWConfig(lr=3e-3, warmup_steps=20),
        microbatches=args.microbatches, compress=compress))
    data = make_data_iter(cfg, args.batch, args.seq)

    lc = LOOP.LoopConfig(
        total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
        lossy=LossyPolicy(enabled=True, rel_eb=1e-4, min_size=65536))
    t0 = time.time()
    state, res = LOOP.run(cfg, state, step, data, lc)
    steps_done = sorted(res.losses)
    print(f"steps {steps_done[0]}..{steps_done[-1]} "
          f"loss {res.losses[steps_done[0]]:.3f} -> "
          f"{res.losses[steps_done[-1]]:.3f} "
          f"in {time.time() - t0:.0f}s "
          f"(restarts={res.restarts}, stragglers={res.straggler_steps})")


if __name__ == "__main__":
    main()
