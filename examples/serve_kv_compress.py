"""Batched serving with q-ent-gated KV-cache compression.

The engine scores decode-time KV blocks with the paper's in-graph
quantized-entropy size model and int8-quantizes the ones predicted to
compress well -- UC2 at serving time.

    PYTHONPATH=src python examples/serve_kv_compress.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke
from repro.serve.engine import Engine, ServeConfig
from repro.train import train_step as TS


def main():
    cfg = get_smoke("granite-3-2b")
    params = TS.init_state(cfg, jax.random.PRNGKey(0)).params
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size, dtype=jnp.int32)}

    plain = Engine(cfg, params, ServeConfig(max_len=128))
    comp = Engine(cfg, params, ServeConfig(max_len=128, kv_compress=True,
                                           kv_gate_ratio=1.5))
    out_plain = plain.generate(batch, steps=16)
    out_comp = comp.generate(batch, steps=16)
    agree = float(jnp.mean((out_plain == out_comp).astype(jnp.float32)))
    print(f"tokens generated: {out_comp.shape}")
    print(f"greedy agreement with uncompressed KV: {agree * 100:.1f}%")
    print(f"KV bytes metered: {comp.kv_total_bytes:,} "
          f"saved by int8 gate: {comp.kv_saved_bytes:,} "
          f"({100 * comp.kv_saved_bytes / max(comp.kv_total_bytes, 1):.0f}%)")


if __name__ == "__main__":
    main()
