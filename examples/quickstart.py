"""Quickstart: predict lossy compression ratios without running compressors.

Trains the paper's two-step pipeline on slices of a (synthetic) Miranda
velocity field, then predicts CR for held-out slices and compares with the
measured ratios -- the core loop of the paper in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro import compressors as C
from repro.core import pipeline as PL
from repro.data import scientific


def main():
    # 1. data: a stack of 2-D slices from one field
    slices = scientific.field_slices("miranda-vx", count=28, n=160)
    train, test = slices[:22], slices[22:]
    value_range = float(jnp.max(slices) - jnp.min(slices))
    eps = 1e-4 * value_range          # absolute error bound

    for comp_name in ("sz2", "zfp", "mgard"):
        comp = C.get(comp_name)

        # 2. observed CRs on the training slices (the only compressor use)
        train_crs = jnp.asarray([comp.cr(s, eps) for s in train])

        # 3. fit the compressor-agnostic statistical model
        model = PL.CRPredictor.train(train, train_crs, eps, model="spline")

        # 4. predict held-out slices from their statistics alone
        pred = np.asarray(model.predict(test))
        true = np.asarray([comp.cr(s, eps) for s in test])
        ape = 100 * np.abs(pred - true) / true
        print(f"{comp_name:8s} predicted CR {np.round(pred, 2)} "
              f"true {np.round(true, 2)}  MedAPE {np.median(ape):.1f}%")


if __name__ == "__main__":
    main()
