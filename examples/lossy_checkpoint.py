"""UC2-driven lossy checkpointing: the paper's best-compressor selection
picks the codec per tensor group *without trial compression*, UC1-style
bound selection meets a fidelity target, and predicted vs achieved CR is
reported per tensor.

    PYTHONPATH=src python examples/lossy_checkpoint.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro import compressors as C
from repro.ckpt import checkpoint as CKPT
from repro.configs.base import get_smoke
from repro.core import pipeline as PL
from repro.data import scientific
from repro.data.tokens import make_data_iter
from repro.train import train_step as TS


def main():
    # a briefly-trained model so weights have structure
    cfg = get_smoke("granite-8b")
    state = TS.init_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(TS.make_train_step(cfg))
    it = make_data_iter(cfg, batch=4, seq=64)
    for i in range(10):
        state, m = step(state, it(i))
    print(f"trained smoke model to loss {float(m['loss']):.3f}")

    # UC2 predictors: one CR model per candidate codec
    slices = scientific.field_slices("miranda-vx", count=14, n=96)
    eps = 1e-4 * float(jnp.max(slices) - jnp.min(slices))
    predictors = {}
    for name in ("sz3-lorenzo", "zfp", "bitgrooming"):
        comp = C.get(name)
        crs = jnp.asarray([comp.cr(s, eps) for s in slices])
        predictors[name] = PL.CRPredictor.train(slices, crs, eps)

    with tempfile.TemporaryDirectory() as d:
        policy = CKPT.LossyPolicy(enabled=True, rel_eb=1e-4, min_size=4096,
                                  predictors=predictors)
        manifest = CKPT.save(d, 0, state.params, policy)
        total_raw = total_comp = 0
        for key, t in manifest["tensors"].items():
            if t["codec"] == "raw":
                continue
            total_raw += t["raw_bytes"]
            total_comp += t["metered_bytes"]
            print(f"  {key:40s} codec={t['codec']:12s} "
                  f"pred_cr={t['predicted_cr']:.2f} "
                  f"achieved_cr={t['achieved_cr']:.2f}")
        print(f"checkpoint CR (lossy tensors): {total_raw / total_comp:.2f}x")
        restored = CKPT.load(d, 0, state.params)
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            state.params, restored)))
        print(f"max restore error: {err:.2e} (bound: rel_eb * range)")


if __name__ == "__main__":
    main()
